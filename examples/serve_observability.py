"""One audited, recorded serving run, exported three ways.

Runs the real :class:`repro.serve.ServingEngine` (smoke-size qwen,
CPU greedy decode) with the PR 9 observability stack fully on —
online quality auditing on every step, per-request latency spans,
and a flight recorder — then exports what it observed:

  PYTHONPATH=src python examples/serve_observability.py
  -> metrics.prom  (Prometheus text exposition of every series)
  -> flight.jsonl  (the decision log: schedule/cache/audit events)

and prints the latency/goodput block, the audit verdict counters, and
the flight recorder's postmortem timeline inline.  Served tokens are
bit-identical to an uninstrumented run — every layer here is a pure
observer (property-tested in ``tests/test_audit.py``).
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.obs import FlightRecorder, MetricsRegistry, prometheus_text
from repro.serve import Request, SchedulerPolicy, ServingEngine


def main():
    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    metrics, recorder = MetricsRegistry(), FlightRecorder()
    eng = ServingEngine(
        cfg, params, max_len=64,
        policy=SchedulerPolicy(kind="symbiotic", respect_deps=True,
                               audit_frac=1.0, audit_k=25),
        metrics=metrics, recorder=recorder)
    rng = np.random.default_rng(0)
    eng.submit([Request(i, rng.integers(0, 512, size=4),
                        max_new_tokens=8) for i in range(4)])
    stats = eng.run(arrivals=[
        (3, [Request(10, rng.integers(0, 512, size=4),
                     max_new_tokens=4)])])

    lat = stats["latency"]
    print(f"served {stats['total_new_tokens']} tokens over "
          f"{stats['rounds']} rounds")
    print(f"latency p50 {lat['p50_s'] * 1e3:.1f} ms / "
          f"p99 {lat['p99_s'] * 1e3:.1f} ms, "
          f"goodput {lat['goodput_rps']:.1f} req/s")
    snap = stats["metrics"]
    print(f"audit: {snap['audit_steps']:.0f} steps scored against "
          f"{snap['audit_baselines']:.0f} random orders, "
          f"{snap['audit_below_floor']:.0f} below the 90th-percentile "
          "floor")

    with open("metrics.prom", "w") as f:
        f.write(prometheus_text(metrics))
    recorder.dump("flight.jsonl")
    print("wrote metrics.prom, flight.jsonl")

    tl = FlightRecorder.timeline(FlightRecorder.load("flight.jsonl"))
    print(f"\nflight timeline ({tl['n_events']} events, "
          f"by kind {tl['by_kind']}):")
    for line in tl["lines"][:12]:
        print(f"  {line}")
    if tl["n_events"] > 12:
        print(f"  ... {tl['n_events'] - 12} more")


if __name__ == "__main__":
    main()
