"""Slice an oversized-stage model graph: trace a prefill-heavy
continuous-batching snapshot whose prefill stages exceed the device's
slot budget, schedule it with the unsliced ready-set greedy and with
the lazy slice-aware greedy (Kernelet-style), and compare gated
makespans against random topological launch orders — the paper's
Fig. 1 protocol on the sliced design space.

  PYTHONPATH=src python examples/slice_schedule.py
"""

from repro.configs import get_config
from repro.core import percentile_rank
from repro.core.tpu import make_serving_device
from repro.graph import DagEventSimulator, greedy_order_dag, trace_arch
from repro.slice import SlicePolicy, greedy_order_slices, refine_order_slices

#: two prompts past the 4096-slot round budget mid-prefill, a decode
#: backlog supplying memory-bound work for the slices to co-execute.
REQUESTS = ([("prefill", 8192), ("prefill", 6144)] +
            [("decode", 2048 + 3072 * i) for i in range(12)])


def main():
    # A 4-core serving slice: the slices genuinely co-execute across
    # cores, and gated refinement (model="gated" — the sliced DAG's
    # own scoring currency, no greedy fallback) stacks on top.
    device = make_serving_device(n_units=4)
    for arch in ("mixtral-8x7b", "deepseek-v2-236b"):
        cfg = get_config(arch, "full")
        traced = trace_arch(cfg, REQUESTS, max_stages=8)
        g = traced.graph
        g.validate()

        un = greedy_order_dag(g.kernels, device, edges=g.edges)
        t_un = DagEventSimulator(device, g.edges_by_id()).simulate(un.order)

        res = greedy_order_slices(g.kernels, device, edges=g.edges,
                                  policy=SlicePolicy())
        sim = DagEventSimulator(device, res.edges_by_id())
        t_sl = sim.simulate(res.order)
        order, t_ref, _ = refine_order_slices(res, device, budget=40,
                                              model="gated")

        rand = [sim.simulate(o) for o in
                res.graph().random_topological_orders(200, seed=1)]
        pct = percentile_rank(t_sl, rand)
        med = sorted(rand)[len(rand) // 2]

        print(f"{arch}: {g.n} nodes -> {len(res.kernels)} after slicing "
              f"{len(res.sliced)} oversized stages "
              f"({res.passes} lazy pass(es))")
        print(f"  unsliced greedy   {t_un * 1e3:9.1f} ms")
        print(f"  sliced greedy     {t_sl * 1e3:9.1f} ms  "
              f"({(t_un / t_sl - 1) * 100:+.1f}%, beats {pct:.0f}% of 200 "
              f"random topological orders; median {med * 1e3:.1f} ms)")
        print(f"  + slice refine    {t_ref * 1e3:9.1f} ms")


if __name__ == "__main__":
    main()
