"""Schedule a real model graph: trace an architecture into per-layer
work-item chains, compose rounds with the ready-set greedy, map them
onto launch queues, and compare the gated makespan against random
topological launch orders (the paper's Fig. 1 protocol, generalized
from an independent batch to a kernel DAG).

  PYTHONPATH=src python examples/dag_schedule.py
"""

from repro.configs import get_config
from repro.core import percentile_rank
from repro.core.tpu import make_serving_device
from repro.graph import (DagEventSimulator, assign_streams,
                         greedy_order_dag, refine_order_dag, trace_arch)


def main():
    # A 4-core serving slice: per-core placement and occupancy make
    # the gated makespan order-sensitive beyond round composition,
    # which is where gated refinement beats the ready-set greedy.
    device = make_serving_device(n_units=4)
    for arch in ("qwen1.5-0.5b", "mixtral-8x7b"):
        cfg = get_config(arch, "full")
        traced = trace_arch(cfg, max_stages=16)
        g = traced.graph
        g.validate()
        sim = DagEventSimulator(device, g.edges_by_id())

        sched = greedy_order_dag(g.kernels, device, edges=g.edges)
        t_alg = sim.simulate(sched.order)
        # model="gated": the hill-climb optimizes the gated makespan
        # itself (delta-evaluated), so t_ref IS this order's gated time.
        order, t_ref, _ = refine_order_dag(sched.order, device,
                                           edge_ids=g.edges_by_id(),
                                           budget=60, model="gated",
                                           neighborhood="adjacent")

        rand = [sim.simulate(o)
                for o in g.random_topological_orders(200, seed=1)]
        pct = percentile_rank(t_alg, rand)
        med = sorted(rand)[len(rand) // 2]

        sa = assign_streams(sched, g.edges_by_id(), k=4)
        print(f"{arch}: {g.n} nodes, {len(g.edges)} edges, "
              f"{len(sched.rounds)} rounds")
        print(f"  greedy_order_dag {t_alg * 1e3:8.3f} ms  "
              f"(beats {pct:.0f}% of 200 random topological orders; "
              f"median {med * 1e3:.3f} ms)")
        print(f"  + refine_order_dag {t_ref * 1e3:6.3f} ms")
        print(f"  4 launch queues, per-queue kernels: {sa.occupancy()}")


if __name__ == "__main__":
    main()
