"""Quickstart: the paper's algorithm in 40 lines.

Reorders the paper's 8-kernel mixed workload (EP/BS/ES/SW), compares
the greedy order against the best/worst of the full permutation space,
and shows the TPU adaptation composing a serving round.

  PYTHONPATH=src python examples/quickstart.py
"""

import itertools

from repro.core import (GTX580, EXPERIMENTS, greedy_order_fast, simulate,
                        percentile_rank)
from repro.core.refine import refined_schedule
from repro.core.tpu import compose_rounds, decode_profile, prefill_profile

# --- 1. reproduce the paper's EpBsEsSw-8 experiment --------------------
kernels = EXPERIMENTS["EpBsEsSw-8"]()
sched = greedy_order_fast(kernels, GTX580)
print("Algorithm 1 rounds:", [r.names for r in sched.rounds])

t_alg = simulate(sched.order, GTX580)
times = [simulate([kernels[i] for i in p], GTX580)
         for p in itertools.permutations(range(len(kernels)))]
print(f"algorithm: {t_alg * 1e3:8.2f} ms")
print(f"optimal:   {min(times) * 1e3:8.2f} ms")
print(f"worst:     {max(times) * 1e3:8.2f} ms")
print(f"percentile rank: {percentile_rank(t_alg, times):.1f}%")

# --- 2. beyond-paper: simulator-guided refinement ----------------------
order, t_ref = refined_schedule(kernels, GTX580)
print(f"refined:   {t_ref * 1e3:8.2f} ms "
      f"({percentile_rank(t_ref, times):.1f} percentile)")

# --- 3. TPU adaptation: symbiotic serving round -------------------------
items = [prefill_profile(f"prefill{i}", n_params=7e9, seq_len=2048,
                         kv_bytes_per_token=131072) for i in range(2)]
items += [decode_profile(f"decode{i}", n_params=7e9, kv_len=8192,
                         kv_bytes_per_token=131072) for i in range(6)]
rounds = compose_rounds(items)
print("TPU serving rounds:", [r.names for r in rounds.rounds])
