"""Dump a greedy-vs-refined schedule trace pair for a traced arch.

Traces a real model graph (per-layer work-item chains) onto the 4-core
serving device, runs the gated event simulator once under the
ready-set greedy order and once under the gated-refined order — each
with a live :class:`repro.obs.ScheduleTrace` recorder — and writes
both as Chrome trace-event JSON:

  PYTHONPATH=src python examples/trace_schedule.py
  -> trace_greedy.json, trace_refined.json

Load either file in Perfetto (https://ui.perfetto.dev, "Open trace
file") or chrome://tracing: one track per device unit, one span per
kernel, instant markers for zero-work join retirements.  The same
recorder's plain-text Gantt view is printed inline, so the
reordering's effect — decode spans sliding under prefill spans — is
visible without leaving the terminal.
"""

from repro.configs import get_config
from repro.core.tpu import make_serving_device
from repro.graph import (DagEventSimulator, greedy_order_dag,
                         refine_order_dag, trace_arch)
from repro.obs import ScheduleTrace

ARCH = "qwen1.5-0.5b"


def main():
    device = make_serving_device(n_units=4)
    cfg = get_config(ARCH, "full")
    traced = trace_arch(cfg, [("prefill", 256)] * 2
                        + [("decode", 512)] * 4, max_stages=12)
    g = traced.graph
    g.validate()
    eids = g.edges_by_id()

    sched = greedy_order_dag(g.kernels, device, edges=g.edges)
    order, _, _ = refine_order_dag(sched.order, device, edge_ids=eids,
                                   budget=200, model="gated",
                                   neighborhood="auto")

    pair = []
    for name, o in (("greedy", sched.order), ("refined", order)):
        tr = ScheduleTrace(label=f"{ARCH} {name}")
        t = DagEventSimulator(device, eids).simulate(o, trace=tr)
        path = f"trace_{name}.json"
        tr.dump(path)
        pair.append((name, t, tr, path))

    print(f"{ARCH}: {g.n} nodes, {len(g.edges)} edges, "
          f"{device.n_units} units")
    for name, t, tr, path in pair:
        print(f"\n{name}: gated makespan {t * 1e3:.3f} ms, "
              f"{len(tr.spans)} spans -> {path}")
        print(tr.gantt(width=72))
    t_g, t_r = pair[0][1], pair[1][1]
    print(f"\nrefined / greedy makespan: {t_r / t_g:.3f}x")
    print("open the .json files at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
