"""End-to-end driver: train a ~12M-param qwen-family model for a few
hundred steps on the synthetic pipeline, with checkpoints + auto-resume.

Loss drops from ~6.2 (ln V) to well below within the run, demonstrating
the full substrate (data -> model -> loss -> AdamW -> checkpoint).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()
    out = train("qwen1.5-0.5b", variant="smoke", steps=args.steps,
                global_batch=8, seq_len=128, ckpt_dir=args.ckpt_dir,
                ckpt_every=100)
    print(f"\ntrained {args.steps} steps in {out['seconds']:.0f}s; "
          f"loss {out['first_loss']:.3f} -> {out['last_loss']:.3f}")
    assert out["last_loss"] < out["first_loss"], "loss must decrease"


if __name__ == "__main__":
    main()
