"""Serve a small model with batched requests under three scheduling
policies (FIFO / symbiotic Algorithm-1 / refined), printing the modelled
round times and real generated tokens.

The workload is continuous-arrival: new prompts arrive while earlier
requests are mid-decode, so compute-bound prefill chunks and
memory-bound decode steps coexist in the queue.  The symbiotic policy
mixes them within each round — the paper's reordering insight applied
to TPU serving — so decode steps ride along with prefill's weight
stream instead of paying for it in separate rounds.

  PYTHONPATH=src python examples/serve_symbiotic.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core.tpu import make_serving_device
from repro.models import transformer as T
from repro.serve import Request, SchedulerPolicy, ServingEngine


def make_arrivals(rng):
    """Requests arriving over several iterations."""
    rid = 0
    arrivals = []
    for it in range(4):
        batch = []
        for _ in range(2):   # long prompts (compute-heavy prefill)
            batch.append(Request(rid, rng.integers(0, 512, size=256),
                                 max_new_tokens=4))
            rid += 1
        for _ in range(6):   # short prompts -> mostly decode work
            batch.append(Request(rid, rng.integers(0, 512, size=4),
                                 max_new_tokens=12))
            rid += 1
        arrivals.append((it, batch))
    return arrivals


def main():
    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    # Execution uses the smoke model; the ROUND COST MODEL uses the full
    # qwen1.5-0.5B parameter count so prefill/decode intensities are
    # production-realistic.  Tight token budget so composition matters.
    n_params_full = 464e6
    device = make_serving_device(token_budget=288,
                                 hbm_round_budget=float(2 << 30))
    base = None
    for policy in ("fifo", "symbiotic", "refined"):
        rng = np.random.default_rng(0)
        eng = ServingEngine(cfg, params, max_len=288, device=device,
                            n_params=n_params_full,
                            policy=SchedulerPolicy(kind=policy))
        stats = eng.run(arrivals=make_arrivals(rng))
        t = stats["modelled_time_s"] * 1e3
        if base is None:
            base = t
        print(f"{policy:10s} rounds={stats['rounds']:3d} "
              f"new_tokens={stats['total_new_tokens']:3d} "
              f"modelled_time={t:8.3f} ms "
              f"speedup_vs_fifo={base / t:5.2f}x")
    print("\nsample output (req 0):", stats["outputs"][0][:8])


if __name__ == "__main__":
    main()
