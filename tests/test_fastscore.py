"""Property tests for the vectorized/incremental scheduling hot path:
the fast score matrix, the incremental greedy, delta-evaluated
refinement — each against the pure-Python reference as oracle — plus
the percentile-rank convention and the harmonic-ratio zero guard.

Written with plain ``random`` (no hypothesis dependency in the pinned
toolchain) over seeded draws, so failures reproduce exactly.
"""

import math
import random

import numpy as np
import pytest

from repro.core import (GTX580, DeviceModel, KernelProfile, RoundSimulator,
                        greedy_order, greedy_order_fast, percentile_rank,
                        score_matrix, score_matrix_fast, simulate)
from repro.core.refine import DeltaRoundEvaluator, refine_order
from repro.core.resources import bs_kernel, ep_kernel, es_kernel, sw_kernel
from repro.core.scorer import combined_ratio, pair_score
from repro.core.tpu import (decode_profile, make_serving_device,
                            prefill_profile)

_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]
_TPU = make_serving_device()


def _gpu_kernels(rng: random.Random, n: int) -> list[KernelProfile]:
    return [rng.choice(_FAMS)(f"k{i}",
                              grid=rng.choice([8, 16, 32, 48, 64, 96]),
                              shm=rng.choice([0, 4096, 8192, 16384, 24576]),
                              inst=rng.uniform(1e6, 5e8))
            for i in range(n)]


def _tpu_profiles(rng: random.Random, n: int) -> list[KernelProfile]:
    items = []
    for i in range(n):
        if rng.random() < 0.4:
            items.append(prefill_profile(
                f"p{i}", n_params=7e9,
                seq_len=rng.choice([128, 256, 512, 1024]),
                kv_bytes_per_token=131072))
        else:
            items.append(decode_profile(
                f"d{i}", n_params=7e9, kv_len=rng.randint(1, 8192),
                kv_bytes_per_token=131072))
    return [it.profile() for it in items]


def _round_names(sched) -> list[list[str]]:
    return [rd.names for rd in sched.rounds]


# --------------------------------------------------------------------------
# fast matrix == reference score_matrix
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU, _tpu_profiles)])
def test_fast_matrix_matches_reference(device, maker):
    rng = random.Random(11)
    for _ in range(25):
        ks = maker(rng, rng.randint(2, 24))
        ref = np.asarray(score_matrix(ks, ks, device))
        fast = score_matrix_fast(ks, device)
        assert np.max(np.abs(ref - fast)) <= 1e-9


# --------------------------------------------------------------------------
# incremental greedy == reference greedy (exact round structure)
# --------------------------------------------------------------------------

def test_incremental_greedy_reproduces_reference():
    """>= 50 randomized kernel sets across both device families."""
    rng = random.Random(42)
    checked = 0
    for trial in range(60):
        if trial % 2 == 0:
            ks, dev = _gpu_kernels(rng, rng.randint(1, 20)), GTX580
        else:
            ks, dev = _tpu_profiles(rng, rng.randint(1, 32)), _TPU
        ref = _round_names(greedy_order(ks, dev))
        fast = _round_names(greedy_order_fast(ks, dev))
        assert ref == fast, f"trial {trial}: {ref} != {fast}"
        checked += 1
    assert checked >= 50


def test_incremental_greedy_matches_on_adversarial_dim_orders():
    """Equivalence must not depend on demands-dict order matching
    device.caps order, nor on the device having an "shm" dimension
    (exercises the solo-kernel sort-key fallback)."""
    rng = random.Random(77)
    dev = DeviceModel(name="odd", n_units=4,
                      caps={"a": 100.0, "b": 50.0}, max_resident=4,
                      compute_rate=1e9, mem_bw=1e9, r_balanced=2.0)
    for trial in range(30):
        ks = []
        for i in range(rng.randint(1, 12)):
            da = rng.uniform(1.0, 60.0)
            db = rng.uniform(1.0, 30.0)
            dem = {"b": db, "a": da} if rng.random() < 0.5 else \
                {"a": da, "b": db}
            ks.append(KernelProfile(f"k{i}", n_blocks=rng.randint(1, 16),
                                    demands=dem,
                                    inst_per_block=rng.uniform(1e5, 1e7),
                                    r=rng.uniform(0.5, 8.0)))
        ref = _round_names(greedy_order(ks, dev))
        fast = _round_names(greedy_order_fast(ks, dev))
        assert ref == fast, f"trial {trial}: {ref} != {fast}"
        ref_m = np.asarray(score_matrix(ks, ks, dev))
        assert np.max(np.abs(ref_m - score_matrix_fast(ks, dev))) <= 1e-9


def test_greedy_fast_empty_and_singleton():
    assert greedy_order_fast([], GTX580).rounds == []
    k = ep_kernel("only")
    sched = greedy_order_fast([k], GTX580)
    assert _round_names(sched) == [["only"]]


# --------------------------------------------------------------------------
# delta-evaluated refinement == full re-simulation (exact)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU, _tpu_profiles)])
def test_delta_eval_equals_full_resimulation(device, maker):
    rng = random.Random(5)
    sim = RoundSimulator(device)
    for _ in range(20):
        ks = maker(rng, rng.randint(2, 20))
        n = len(ks)
        ev = DeltaRoundEvaluator(device)
        ev.rebase(ks)
        for _ in range(25):
            i, j = rng.randrange(n), rng.randrange(n)
            if i == j:
                continue
            cand = list(ks)
            cand[i], cand[j] = cand[j], cand[i]
            assert ev.evaluate(cand, min(i, j)) == sim.simulate(cand)
            cand = list(ks)
            cand.insert(j, cand.pop(i))
            assert ev.evaluate(cand, min(i, j)) == sim.simulate(cand)


def test_delta_refine_matches_reference_refine_small_n():
    """With the full move set the delta path retraces the reference
    trajectory exactly (same moves, same order, equal times)."""
    rng = random.Random(9)
    for _ in range(10):
        ks = _gpu_kernels(rng, rng.randint(3, 10))
        sim = RoundSimulator(GTX580)
        # budget high enough that both paths run to a local optimum
        # (the delta path's budget is charged fractionally, so at an
        # exhausted budget the two would stop at different points).
        o_ref, t_ref, _ = refine_order(
            ks, GTX580, time_fn=sim.simulate, budget=3000,
            neighborhood="full")
        o_fast, t_fast, _ = refine_order(
            ks, GTX580, model="round", budget=3000, neighborhood="full")
        assert t_fast == t_ref
        assert [k.name for k in o_fast] == [k.name for k in o_ref]


def test_refine_never_worse_than_input():
    rng = random.Random(3)
    for neighborhood in ("full", "adjacent", "auto"):
        ks = _gpu_kernels(rng, 12)
        t0 = RoundSimulator(GTX580).simulate(ks)
        _, t, _ = refine_order(ks, GTX580, model="round", budget=200,
                               neighborhood=neighborhood)
        assert t <= t0 + 1e-15


# --------------------------------------------------------------------------
# satellite pins
# --------------------------------------------------------------------------

def test_warm_start_insert_prefers_symbiotic_round():
    """The warm-start primitive places a joining decode step into the
    prefill round (compute/memory mixing, the paper's rule) and
    reports no-fit with -1."""
    from repro.core import warm_start_insert
    dev = make_serving_device()
    p = prefill_profile("p", n_params=7e9, seq_len=512,
                        kv_bytes_per_token=131072).profile()
    ds = [decode_profile(f"d{i}", n_params=7e9, kv_len=1024,
                         kv_bytes_per_token=131072).profile()
          for i in range(3)]
    idx = warm_start_insert([[p], [ds[0], ds[1]]], ds[2], dev)
    assert idx == 0
    # nothing fits: a round already at the token budget
    full = prefill_profile("big", n_params=7e9, seq_len=4096,
                           kv_bytes_per_token=131072).profile()
    assert warm_start_insert([[full]], ds[2], dev) == -1
    assert warm_start_insert([], ds[2], dev) == -1


def test_sat_dim_configs_match_reference():
    """_FastRoundSim._eff must mirror DeviceModel.*_efficiency under
    every sat_dim configuration — in caps, empty, and set-but-untracked
    (the audit fix: an untracked sat_dim carries no occupancy signal
    and must run at peak, not degrade to ~0 efficiency)."""
    rng = random.Random(19)
    base = dict(n_units=4, caps={"a": 100.0, "b": 50.0}, max_resident=4,
                compute_rate=1e9, mem_bw=1e9, r_balanced=2.0)
    devs = [DeviceModel(name="insat", sat_dim="a", sat_compute=30.0,
                        sat_memory=80.0, **base),
            DeviceModel(name="nosat", **base),
            DeviceModel(name="oddsat", sat_dim="zz", sat_compute=30.0,
                        sat_memory=80.0, **base)]
    for trial in range(10):
        ks = [KernelProfile(f"k{i}", n_blocks=rng.randint(1, 8),
                            demands={"a": rng.uniform(1, 40),
                                     "b": rng.uniform(1, 20)},
                            inst_per_block=rng.uniform(1e5, 1e7),
                            r=rng.uniform(0.5, 8.0))
              for i in range(rng.randint(2, 12))]
        for dev in devs:
            ref = RoundSimulator(dev).simulate(ks)
            ev = DeltaRoundEvaluator(dev)
            assert ev.rebase(ks) == ref, (trial, dev.name)
            cand = list(ks)
            cand[0], cand[-1] = cand[-1], cand[0]
            assert ev.evaluate(cand, 0) == RoundSimulator(dev).simulate(
                cand), (trial, dev.name)
    # untracked sat_dim == no occupancy model: identical times
    ks = [KernelProfile("k", n_blocks=4, demands={"a": 10.0, "b": 5.0},
                        inst_per_block=1e6, r=2.0)]
    assert (RoundSimulator(devs[2]).simulate(ks)
            == RoundSimulator(devs[1]).simulate(ks))


def test_percentile_rank_convention():
    """percentile_rank returns a 0-100 percentage, not a fraction."""
    assert percentile_rank(1.0, [2.0, 1.5, 1.0, 0.5]) == 75.0
    assert percentile_rank(0.5, [2.0, 1.5, 1.0, 0.5]) == 100.0
    assert percentile_rank(3.0, [2.0, 1.5, 1.0, 0.5]) == 0.0
    assert percentile_rank(1.0, []) == 0.0


def test_harmonic_combined_ratio_zero_r_guard():
    """Pure-memory kernels (r == 0) must not divide by zero; the
    combined intensity degenerates to ~0 (memory-bound limit)."""
    a = KernelProfile("zero", n_blocks=4, demands={"shm": 0.0},
                      inst_per_block=1e6, r=0.0)
    b = KernelProfile("busy", n_blocks=4, demands={"shm": 0.0},
                      inst_per_block=1e6, r=10.0)
    rc = combined_ratio(a, b, mode="harmonic")
    assert math.isfinite(rc)
    assert rc == pytest.approx(0.0, abs=1e-12)
    # and the full scorer path survives it on a harmonic-mode device
    dev = make_serving_device()
    ka = KernelProfile("z", n_blocks=1,
                       demands={"vmem": 1.0, "hbm": 1.0, "slots": 1.0},
                       inst_per_block=1e6, r=0.0)
    kb = KernelProfile("c", n_blocks=1,
                       demands={"vmem": 1.0, "hbm": 1.0, "slots": 1.0},
                       inst_per_block=1e9, r=500.0)
    s = pair_score(ka, kb, dev)
    assert math.isfinite(s) and s >= 0.0
    fast = score_matrix_fast([ka, kb], dev)
    assert np.isfinite(fast).all()


def test_fast_path_end_to_end_quality_not_worse():
    """Fast greedy + delta refine produces modelled (event) times no
    worse than reference greedy + full-eval refine at equal budget."""
    rng = random.Random(21)
    for _ in range(5):
        ks = _gpu_kernels(rng, 10)
        ref_sched = greedy_order(ks, GTX580)
        sim = RoundSimulator(GTX580)
        o_ref, _, _ = refine_order(ref_sched.order, GTX580,
                                   time_fn=sim.simulate, budget=200)
        fast_sched = greedy_order_fast(ks, GTX580)
        o_fast, _, _ = refine_order(fast_sched.order, GTX580,
                                    model="round", budget=200,
                                    neighborhood="auto")
        t_ref = simulate(o_ref, GTX580)
        t_fast = simulate(o_fast, GTX580)
        assert t_fast <= t_ref + 1e-12
