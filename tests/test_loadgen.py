"""Load-generator golden tests (PR 10).

Fixed-seed Poisson/bursty/diurnal traces are pinned as goldens
(arrival instants + the per-request latency summary a frontend run
produces from them), and virtual-clock monotonicity/determinism
properties guarantee no wall-clock nondeterminism can leak into
``BENCH_serving.json``'s ``frontend_bench`` section: every number in a
:class:`LoadGenerator` report derives from seeded draws and modelled
round times only.
"""

import jax
import pytest

from proptest import cases
from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import (ARRIVAL_PROCESSES, LoadGenerator,
                         SchedulerPolicy, ServingFrontend, VirtualClock,
                         bursty_arrivals, diurnal_arrivals,
                         make_workload, poisson_arrivals)

pytestmark = pytest.mark.frontend

_PARAMS_CACHE: dict = {}


def _frontend(arch: str = "qwen1.5-0.5b"):
    if arch not in _PARAMS_CACHE:
        cfg = get_config(arch, "smoke")
        _PARAMS_CACHE[arch] = (cfg, T.init(jax.random.PRNGKey(0), cfg))
    cfg, params = _PARAMS_CACHE[arch]
    return ServingFrontend.build(cfg, params, max_len=32,
                                 policy=SchedulerPolicy())


# --------------------------------------------------------------------------
# golden arrival traces (pure python, bit-stable by seed)
# --------------------------------------------------------------------------

_GOLDEN_TRACES = {
    "poisson": [0.255015071819, 0.261347281579, 0.341753297598,
                0.404899844016, 0.738298012218, 1.020591264417],
    "bursty": [0.025501507182, 0.026134728158, 0.034175329760,
               0.040489984402, 0.073829801222, 0.102059126442],
    "diurnal": [0.141675039899, 0.186345048799, 0.680911822737,
                0.757029344876, 0.791295552648, 0.795030886492],
}


@pytest.mark.parametrize("process", sorted(ARRIVAL_PROCESSES))
def test_arrival_trace_goldens(process):
    got = ARRIVAL_PROCESSES[process](6, 4.0, seed=42)
    assert got == pytest.approx(_GOLDEN_TRACES[process], rel=1e-9)


def test_bursty_shares_poisson_scale():
    """The bursty process is the Poisson gaps compressed by the hot
    rate inside a first burst — the golden shows the 10x on-rate."""
    assert _GOLDEN_TRACES["bursty"] == pytest.approx(
        [t / 10.0 for t in _GOLDEN_TRACES["poisson"]], rel=1e-9)


@pytest.mark.parametrize("process", sorted(ARRIVAL_PROCESSES))
def test_long_run_rate(process):
    """Seeded long traces respect the nominal rate (fixed seed, so a
    tight band is safe)."""
    n, rate = 2000, 8.0
    ts = ARRIVAL_PROCESSES[process](n, rate, seed=1)
    assert n / ts[-1] == pytest.approx(rate, rel=0.15)


@cases(n=25, seed=5)
def test_arrival_processes_monotone(rng):
    """Instants are strictly increasing and after t0 for every
    process, seed, and rate."""
    seed = rng.randrange(1 << 30)
    rate = rng.choice([0.5, 4.0, 1e3, 1e6])
    t0 = rng.choice([0.0, 3.5])
    for fn in (poisson_arrivals, bursty_arrivals, diurnal_arrivals):
        ts = fn(20, rate, seed=seed, t0=t0)
        assert len(ts) == 20 and ts[0] > t0
        assert all(b > a for a, b in zip(ts, ts[1:]))


@cases(n=10, seed=6)
def test_workload_shapes_seeded(rng):
    """Request shapes draw from the same seed as the trace: one seed
    pins both; rids are the arrival order."""
    seed = rng.randrange(1 << 30)
    wl = make_workload("poisson", 12, 4.0, seed=seed,
                       prompt_len=(3, 9), max_new_tokens=(2, 5))
    wl2 = make_workload("poisson", 12, 4.0, seed=seed,
                        prompt_len=(3, 9), max_new_tokens=(2, 5))
    assert [r.rid for _, r in wl] == list(range(12))
    for (t, a), (t2, b) in zip(wl, wl2):
        assert t == t2 and (a.prompt == b.prompt).all()
        assert a.max_new_tokens == b.max_new_tokens
        assert 3 <= len(a.prompt) <= 9 and 2 <= a.max_new_tokens <= 5


# --------------------------------------------------------------------------
# virtual clock: monotone, never wall
# --------------------------------------------------------------------------

def test_virtual_clock_monotone():
    clk = VirtualClock(1.0)
    assert clk.now() == 1.0
    assert clk.advance(0.5) == 1.5
    assert clk.advance_to(1.2) == 1.5      # backwards: no-op
    assert clk.advance_to(2.0) == 2.0
    with pytest.raises(ValueError):
        clk.advance(-1e-9)
    assert clk.now() == 2.0


@cases(n=50, seed=8)
def test_virtual_clock_monotone_under_random_ops(rng):
    clk = VirtualClock()
    prev = clk.now()
    for _ in range(40):
        if rng.random() < 0.5:
            clk.advance(rng.random())
        else:
            clk.advance_to(rng.uniform(-1.0, prev + 1.0))
        assert clk.now() >= prev
        prev = clk.now()


def test_completions_monotone_in_virtual_time():
    """Per replica, completion instants never decrease and never
    precede the request's arrival — the monotonicity property that
    keeps BENCH latency numbers wall-clock-free."""
    fe = _frontend()
    gen = LoadGenerator(process="diurnal", n_requests=8, rate=1e6,
                        seed=3)
    gen.drive(fe)
    arrive = {r.rid: t for t, r in gen.workload()}
    by_replica: dict = {}
    for rid, t, rep in fe.completions:
        assert t >= arrive[rid]
        assert t >= by_replica.get(rep, 0.0)
        by_replica[rep] = t


# --------------------------------------------------------------------------
# golden latency summary + report determinism
# --------------------------------------------------------------------------

_GOLDEN_REPORT = {
    "completed": 6,
    "p50_s": 1.0307835959760038e-05,
    "p99_s": 1.2883820582646234e-05,
    "queue_p50_s": 0.0,
    "queue_p99_s": 0.0,
    "goodput_rps": 430749.4915622427,
    "goodput_tokens_per_s": 1507623.2204678494,
    "virtual_time_s": 1.3929209708963773e-05,
    "rejection_rate": 0.0,
    "queue_depth_max": 1,
}


def test_latency_summary_golden():
    """A seeded run's per-request latency summary is pinned: the
    numbers are pure functions of the seed and the round cost model
    (goodput in the hundreds of thousands rps because virtual seconds
    are modelled roofline time, not wall time)."""
    gen = LoadGenerator(process="poisson", n_requests=6, rate=1e6,
                        seed=42, max_new_tokens=(2, 4))
    rep = gen.drive(_frontend())
    for key, want in _GOLDEN_REPORT.items():
        assert rep[key] == pytest.approx(want, rel=1e-9), key


def test_report_deterministic_across_runs():
    """Two fresh pools, same seed: byte-equal reports (the BENCH
    determinism contract)."""
    gen = LoadGenerator(process="bursty", n_requests=8, rate=1e6,
                        seed=17)
    assert gen.drive(_frontend()) == gen.drive(_frontend())
