"""Property tests for the checkpointable gated simulator and the
gated delta evaluator (ISSUE 5): exact float equality between gated
suffix re-simulation and full gated re-simulation, checkpoint
interchangeability between ``DagEventSimulator`` and
``_FastGatedSim``, slice/join graphs (zero-work markers), the 0-edge
degeneration to the ungated ``EventSimulator`` identity, and the
``refine_order_dag(model="gated")`` / ``refine_order_slices``
integration.

Written with plain ``random`` (no hypothesis dependency in the pinned
toolchain) over seeded draws, so failures reproduce exactly.
"""

import random

import pytest

from repro.core import GTX580, EventSimulator, KernelProfile
from repro.core.refine import DeltaEvaluator, _FastEventSim
from repro.core.resources import bs_kernel, ep_kernel, es_kernel, sw_kernel
from repro.core.tpu import (decode_profile, make_serving_device,
                            prefill_profile)
from repro.graph import (DagEventSimulator, GatedDeltaEvaluator,
                         KernelGraph, greedy_order_dag, refine_order_dag)
from repro.graph.delta import _FastGatedSim
from repro.slice import SlicePolicy, greedy_order_slices, refine_order_slices

_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]
_TPU = make_serving_device()
_TPU4 = make_serving_device(n_units=4)


def _gpu_kernels(rng: random.Random, n: int) -> list[KernelProfile]:
    return [rng.choice(_FAMS)(f"k{i}",
                              grid=rng.choice([8, 16, 32, 48, 64, 96]),
                              shm=rng.choice([0, 4096, 8192, 16384, 24576]),
                              inst=rng.uniform(1e6, 5e8))
            for i in range(n)]


def _tpu_profiles(rng: random.Random, n: int) -> list[KernelProfile]:
    items = []
    for i in range(n):
        if rng.random() < 0.4:
            items.append(prefill_profile(
                f"p{i}", n_params=7e9,
                seq_len=rng.choice([128, 256, 512, 1024]),
                kv_bytes_per_token=131072))
        else:
            items.append(decode_profile(
                f"d{i}", n_params=7e9, kv_len=rng.randint(1, 8192),
                kv_bytes_per_token=131072))
    return [it.profile() for it in items]


def _random_dag_edges(rng: random.Random, n: int,
                      density: float = 1.0) -> set:
    """Random forward edges (u < v): acyclic by construction."""
    edges = set()
    for _ in range(int(density * n)):
        u, v = rng.randrange(n), rng.randrange(n)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return edges


def _sliced_workload(rng: random.Random, device):
    """A chain DAG with oversized prefill stages, expanded by the lazy
    slice greedy — the slice/join graph shape (zero-work join markers)
    the gated evaluator must handle."""
    n = rng.randint(8, 16)
    items = []
    for i in range(n):
        u = rng.random()
        if u < 0.3:
            it = prefill_profile(f"P{i}", n_params=7e9,
                                 seq_len=rng.choice([6144, 8192]),
                                 kv_bytes_per_token=131072)
        else:
            it = decode_profile(f"d{i}", n_params=7e9,
                                kv_len=rng.randint(64, 8192),
                                kv_bytes_per_token=131072)
        items.append(it.profile())
    edges = set()
    chains: list[list[int]] = [[] for _ in range(4)]
    for i in range(n):
        c = chains[rng.randrange(4)]
        if c:
            edges.add((c[-1], i))
        c.append(i)
    return greedy_order_slices(items, device, edges=edges,
                               policy=SlicePolicy())


# --------------------------------------------------------------------------
# fast gated sim == reference gated sim (full runs, random DAGs)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU, _tpu_profiles),
                                          (_TPU4, _tpu_profiles)])
def test_fast_gated_sim_matches_reference(device, maker):
    rng = random.Random(13)
    for _ in range(12):
        n = rng.randint(2, 18)
        ks = maker(rng, n)
        g = KernelGraph(ks, _random_dag_edges(rng, n,
                                              rng.uniform(0.0, 2.0)))
        order = g.random_topological_order(rng)
        eids = g.edges_by_id()
        t_ref = DagEventSimulator(device, eids).simulate(order)
        t_fast = _FastGatedSim(device, eids).simulate(order)[0]
        assert t_fast == t_ref


def test_zero_edge_gated_degenerates_to_event_sim():
    """With no edges the gated pipeline replays the ungated event
    model's float accumulation exactly — reference and fast alike."""
    rng = random.Random(7)
    for _ in range(10):
        ks = _gpu_kernels(rng, rng.randint(2, 16))
        t_event = EventSimulator(GTX580).simulate(ks)
        assert DagEventSimulator(GTX580, set()).simulate(ks) == t_event
        assert _FastGatedSim(GTX580, set()).simulate(ks)[0] == t_event
        assert _FastEventSim(GTX580).simulate(ks)[0] == t_event


# --------------------------------------------------------------------------
# checkpoint resume == full simulation, both implementations, both ways
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU4, _tpu_profiles)])
def test_gated_checkpoint_resume_equals_full(device, maker):
    rng = random.Random(11)
    for _ in range(8):
        n = rng.randint(2, 14)
        ks = maker(rng, n)
        g = KernelGraph(ks, _random_dag_edges(rng, n, 1.0))
        order = g.random_topological_order(rng)
        eids = g.edges_by_id()
        ref = DagEventSimulator(device, eids)
        fast = _FastGatedSim(device, eids)
        t_full = ref.simulate(order)
        t_rec, ref_ck = ref.simulate(order, record=True)
        t_fast, fast_ck = fast.simulate(order, record=True)
        assert t_rec == t_full == t_fast
        assert [c.pos for c in ref_ck] == list(range(n))
        assert [c.pos for c in fast_ck] == list(range(n))
        for p in {0, n // 2, n - 1}:
            # resume from own checkpoints
            assert ref.simulate(order, start_state=ref_ck[p]) == t_full
            assert fast.simulate(order,
                                 start_state=fast_ck[p])[0] == t_full
            # checkpoints are interchangeable between implementations
            assert ref.simulate(order, start_state=fast_ck[p]) == t_full
            assert fast.simulate(order,
                                 start_state=ref_ck[p])[0] == t_full


def test_gated_checkpoints_interchange_with_ungated_on_zero_edges():
    """On an empty edge set the gated simulators produce checkpoints
    the ungated fast event sim can consume and vice versa — the
    'layered on EventCheckpoint' design, pinned."""
    rng = random.Random(3)
    ks = _gpu_kernels(rng, 10)
    t_full = EventSimulator(GTX580).simulate(ks)
    _, ev_ck = _FastEventSim(GTX580).simulate(ks, record=True)
    _, gt_ck = _FastGatedSim(GTX580, set()).simulate(ks, record=True)
    for p in (0, 5, 9):
        assert _FastGatedSim(GTX580, set()).simulate(
            ks, start_state=ev_ck[p])[0] == t_full
        assert _FastEventSim(GTX580).simulate(
            ks, start_state=gt_ck[p])[0] == t_full


# --------------------------------------------------------------------------
# delta evaluation == full gated re-simulation (exact)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU, _tpu_profiles),
                                          (_TPU4, _tpu_profiles)])
def test_gated_delta_equals_full_resimulation(device, maker):
    rng = random.Random(5)
    for _ in range(8):
        n = rng.randint(3, 16)
        ks = maker(rng, n)
        g = KernelGraph(ks, _random_dag_edges(rng, n, 1.0))
        order = g.random_topological_order(rng)
        eids = g.edges_by_id()
        ev = GatedDeltaEvaluator(device, eids)
        ev.rebase(order)
        ref = DagEventSimulator(device, eids)
        checked = 0
        for _ in range(40):
            i, j = rng.randrange(n), rng.randrange(n)
            if i == j:
                continue
            cand = list(order)
            cand[i], cand[j] = cand[j], cand[i]
            if not ev.legal(cand):
                continue
            assert ev.evaluate(cand, min(i, j)) == ref.simulate(cand)
            checked += 1
        # move-style candidates too (remove + reinsert)
        for _ in range(20):
            i, j = rng.randrange(n), rng.randrange(n)
            if i == j:
                continue
            cand = list(order)
            cand.insert(j, cand.pop(i))
            if not ev.legal(cand):
                continue
            assert ev.evaluate(cand, min(i, j)) == ref.simulate(cand)
            checked += 1


def test_gated_delta_slice_join_graphs_exact():
    """Sliced workloads (slice diamonds + zero-work joins): delta
    evaluation and checkpoint resume stay bit-exact through instant
    join retirement."""
    rng = random.Random(17)
    for _ in range(4):
        sl = _sliced_workload(rng, _TPU)
        assert sl.sliced, "workload must actually trigger slicing"
        eids = sl.edges_by_id()
        order = sl.order
        n = len(order)
        ref = DagEventSimulator(_TPU, eids)
        fast = _FastGatedSim(_TPU, eids)
        t_full = ref.simulate(order)
        t_fast, fck = fast.simulate(order, record=True)
        assert t_fast == t_full
        for p in (0, n // 3, n // 2, n - 1):
            assert fast.simulate(order, start_state=fck[p])[0] == t_full
            assert ref.simulate(order, start_state=fck[p]) == t_full
        ev = GatedDeltaEvaluator(_TPU, eids)
        ev.rebase(order)
        for _ in range(25):
            i, j = rng.randrange(n), rng.randrange(n)
            if i == j:
                continue
            cand = list(order)
            cand[i], cand[j] = cand[j], cand[i]
            if not ev.legal(cand):
                continue
            assert ev.evaluate(cand, min(i, j)) == ref.simulate(cand)


def test_gated_delta_rebase_incremental_matches_full_rebase():
    """Accepted-move rebase (checkpoint-prefix stitching) leaves the
    evaluator bit-identical to a cold rebase on the new order."""
    rng = random.Random(23)
    for _ in range(6):
        n = rng.randint(4, 14)
        ks = _gpu_kernels(rng, n)
        g = KernelGraph(ks, _random_dag_edges(rng, n, 1.0))
        order = g.random_topological_order(rng)
        eids = g.edges_by_id()
        ev = GatedDeltaEvaluator(GTX580, eids)
        ev.rebase(order)
        for _ in range(10):
            i = rng.randrange(n - 1)
            cand = list(order)
            cand[i], cand[i + 1] = cand[i + 1], cand[i]
            if not ev.legal(cand):
                continue
            t_inc = ev.rebase_incremental(cand, i)
            cold = GatedDeltaEvaluator(GTX580, eids)
            t_cold = cold.rebase(cand)
            assert t_inc == t_cold
            assert len(ev._ckpts) == len(cold._ckpts)
            order = cand


def test_gated_delta_costs_suffix_fraction():
    rng = random.Random(2)
    n = 12
    ks = _gpu_kernels(rng, n)
    g = KernelGraph(ks, {(i, i + 4) for i in range(n - 4)})
    order = g.random_topological_order(rng)
    eids = g.edges_by_id()
    ev = GatedDeltaEvaluator(GTX580, eids)
    ev.rebase(order)
    cand = list(order)
    cand[n - 2], cand[n - 1] = cand[n - 1], cand[n - 2]
    if ev.legal(cand):
        t, frac = ev.evaluate_costed(cand, n - 2)
        assert t == DagEventSimulator(GTX580, eids).simulate(cand)
        assert frac == pytest.approx(2 / n)
    # gated model: every position is an admission boundary
    assert ev.boundaries() is None


def test_gated_delta_legality_filter_and_deadlock_guard():
    ks = _gpu_kernels(random.Random(1), 4)
    eids = {(id(ks[0]), id(ks[1]))}
    ev = GatedDeltaEvaluator(GTX580, eids)
    assert ev.legal(ks)
    bad = [ks[1], ks[0], ks[2], ks[3]]
    assert not ev.legal(bad)
    # the simulator itself is the backstop: a non-topological order
    # deadlocks the gate and raises instead of returning a bogus time
    with pytest.raises(ValueError):
        _FastGatedSim(GTX580, eids).simulate(bad)


# --------------------------------------------------------------------------
# refine_order_dag(model="gated") / refine_order_slices integration
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU4, _tpu_profiles)])
def test_refine_gated_never_worse_exact_topological(device, maker):
    rng = random.Random(9)
    for _ in range(6):
        n = rng.randint(4, 14)
        ks = maker(rng, n)
        edges = _random_dag_edges(rng, n, 1.0)
        g = KernelGraph(ks, edges)
        sched = greedy_order_dag(ks, device, edges=edges)
        eids = g.edges_by_id()
        t0 = DagEventSimulator(device, eids).simulate(sched.order)
        order, t, _ = refine_order_dag(sched.order, device,
                                       edge_ids=eids, budget=40,
                                       model="gated",
                                       neighborhood="adjacent")
        assert g.is_topological(order)
        assert t <= t0 + 1e-15
        # the returned time is the true gated makespan, exactly
        assert t == DagEventSimulator(device, eids).simulate(order)


def test_refine_gated_full_moveset_matches_full_evaluation_trajectory():
    """With the full move set the gated delta path retraces the
    full-evaluation (time_fn=DagEventSimulator) trajectory exactly."""
    rng = random.Random(19)
    for _ in range(4):
        n = rng.randint(3, 8)
        ks = _gpu_kernels(rng, n)
        edges = _random_dag_edges(rng, n, 0.8)
        g = KernelGraph(ks, edges)
        order = g.random_topological_order(rng)
        eids = g.edges_by_id()
        sim = DagEventSimulator(GTX580, eids)
        o_ref, t_ref, _ = refine_order_dag(
            order, GTX580, edge_ids=eids, time_fn=sim.simulate,
            budget=2000, neighborhood="full")
        o_fast, t_fast, _ = refine_order_dag(
            order, GTX580, edge_ids=eids, model="gated", budget=2000,
            neighborhood="full")
        assert t_fast == t_ref
        assert [k.name for k in o_fast] == [k.name for k in o_ref]


def test_refine_order_slices_gated_never_worse_and_exact():
    rng = random.Random(29)
    sl = _sliced_workload(rng, _TPU4)
    sim = DagEventSimulator(_TPU4, sl.edges_by_id())
    t_sl = sim.simulate(sl.order)
    order, t, _ = refine_order_slices(sl, _TPU4, budget=40,
                                      model="gated",
                                      neighborhood="adjacent")
    assert sl.graph().is_topological(order)
    assert t <= t_sl + 1e-15
    assert t == sim.simulate(order)


# --------------------------------------------------------------------------
# slow sweep (ISSUE-5 CI satellite)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_gated_refine_n512_sweep():
    """n=512 chain-structured DAG: gated refinement completes within a
    small budget, emits a topological order no worse than the greedy,
    and its delta-evaluated makespan equals full gated re-simulation
    at this scale."""
    rng = random.Random(41)
    ks = _gpu_kernels(rng, 512)
    edges = set()
    chains: list[list[int]] = [[] for _ in range(64)]
    for i in range(512):
        c = chains[rng.randrange(64)]
        if c:
            edges.add((c[-1], i))
        c.append(i)
    g = KernelGraph(ks, edges)
    sched = greedy_order_dag(ks, GTX580, edges=edges)
    eids = g.edges_by_id()
    t0 = DagEventSimulator(GTX580, eids).simulate(sched.order)
    order, t, evals = refine_order_dag(sched.order, GTX580,
                                       edge_ids=eids, budget=10,
                                       model="gated",
                                       neighborhood="adjacent")
    assert g.is_topological(order)
    assert t <= t0 + 1e-15
    assert t == DagEventSimulator(GTX580, eids).simulate(order)
    assert evals >= 10
