"""Hypothesis property tests on the model zoo's core invariant:
autoregressive decode with a cache reproduces the full forward pass,
across randomly drawn architectures (family, widths, patterns)."""

import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not available in the pinned toolchain")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models import transformer as T
from repro.models.common import ModelConfig


@st.composite
def config_strategy(draw):
    family = draw(st.sampled_from(["dense", "swa", "moe", "mla",
                                   "hybrid", "xlstm"]))
    n_heads = draw(st.sampled_from([2, 4]))
    kv = draw(st.sampled_from([1, 2])) if family != "mla" else n_heads
    kv = min(kv, n_heads)
    hd = draw(st.sampled_from([8, 16]))
    d = n_heads * hd
    kw = dict(name=f"h-{family}", n_layers=draw(st.sampled_from([2, 3])),
              d_model=d, n_heads=n_heads, n_kv_heads=kv, head_dim=hd,
              d_ff=2 * d, vocab=64, dtype="float32",
              qkv_bias=draw(st.booleans()))
    if family == "swa":
        kw["sliding_window"] = draw(st.sampled_from([4, 6]))
    elif family == "moe":
        # capacity_factor high enough that no token is ever dropped:
        # capacity-based MoE only matches decode-vs-forward when both
        # paths route without drops (a known train/serve divergence).
        kw.update(n_experts=4, top_k=2, moe_d_ff=d,
                  n_shared_experts=draw(st.sampled_from([0, 1])),
                  capacity_factor=4.0)
    elif family == "mla":
        kw.update(attn_type="mla", kv_lora_rank=d // 2,
                  q_lora_rank=draw(st.sampled_from([0, d // 2])),
                  qk_nope_head_dim=hd, qk_rope_head_dim=8, v_head_dim=hd)
    elif family == "hybrid":
        kw.update(block_pattern=("mamba", "attn"), mamba_d_state=8,
                  n_layers=2)
    elif family == "xlstm":
        kw.update(block_pattern=("slstm", "mlstm"), d_ff=0, n_layers=2,
                  n_kv_heads=n_heads)
    return ModelConfig(**kw)


@given(config_strategy(), st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=12, deadline=None)
def test_decode_matches_forward(cfg, seed):
    key = jax.random.PRNGKey(seed)
    params = T.init(key, cfg)
    B, S = 2, 9
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    logits, _ = T.forward(params, cfg, toks)
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for s in range(S):
        lg, cache = T.decode_step(params, cfg, toks[:, s], cache, s)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(logits))) + 1e-6
    np.testing.assert_allclose(np.asarray(dec) / scale,
                               np.asarray(logits) / scale,
                               rtol=0, atol=3e-4)


@given(config_strategy(), st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_unrolled_decode_matches_scanned(cfg, seed):
    """decode_step(unroll=True) (serving path) == scanned decode."""
    key = jax.random.PRNGKey(seed)
    params = T.init(key, cfg)
    B = 2
    cache1 = T.init_cache(cfg, B, 4, dtype=jnp.float32)
    cache2 = T.init_cache(cfg, B, 4, dtype=jnp.float32)
    tok = jax.random.randint(key, (B,), 0, cfg.vocab)
    l1, _ = T.decode_step(params, cfg, tok, cache1, 0, unroll=False)
    l2, _ = T.decode_step(params, cfg, tok, cache2, 0, unroll=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)
