"""Hypothesis property tests for the paper's core invariants."""

import math

import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not available in the pinned toolchain")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (GTX580, DeviceModel, KernelProfile, greedy_order,
                        pair_score, profile_combine, simulate)
from repro.core.refine import refined_schedule
from repro.core.scorer import combined_ratio, fits_together


def kernel_strategy(name_idx: int = 0):
    return st.builds(
        lambda g, b, r, s, inst: KernelProfile(
            name=f"k{name_idx}-{g}-{b}-{s}",
            n_blocks=g,
            demands={"shm": float(s), "reg": float(20 * b), "warp": b / 32},
            inst_per_block=inst,
            r=r),
        st.sampled_from([16, 32, 48, 64, 96]),
        st.sampled_from([64, 128, 256, 512]),
        st.floats(min_value=0.5, max_value=30.0),
        st.sampled_from([0, 4096, 8192, 16384, 24576]),
        st.floats(min_value=1e6, max_value=5e8),
    )


def kernels_strategy(n_min=2, n_max=7):
    return st.lists(kernel_strategy(), min_size=n_min, max_size=n_max,
                    unique_by=lambda k: k.name)


@given(kernels_strategy())
@settings(max_examples=60, deadline=None)
def test_schedule_is_permutation(kernels):
    """Every kernel appears exactly once in the greedy schedule."""
    sched = greedy_order(kernels, GTX580)
    assert sorted(k.name for k in sched.order) == \
        sorted(k.name for k in kernels)


@given(kernels_strategy())
@settings(max_examples=60, deadline=None)
def test_rounds_sorted_by_shm(kernels):
    """Within each round kernels are in decreasing shm order (paper
    line 6/10)."""
    sched = greedy_order(kernels, GTX580)
    for rd in sched.rounds:
        shms = [k.per_unit_demand(GTX580).get("shm", 0.0)
                for k in rd.kernels]
        assert shms == sorted(shms, reverse=True)


@given(kernels_strategy(2, 6))
@settings(max_examples=40, deadline=None)
def test_refined_never_worse_than_greedy(kernels):
    sched = greedy_order(kernels, GTX580)
    t_greedy = simulate(sched.order, GTX580)
    _, t_ref = refined_schedule(kernels, GTX580, budget=300)
    assert t_ref <= t_greedy + 1e-12


@given(kernel_strategy(0), kernel_strategy(1))
@settings(max_examples=60, deadline=None)
def test_pair_score_symmetric_nonnegative(a, b):
    s_ab = pair_score(a, b, GTX580)
    s_ba = pair_score(b, a, GTX580)
    assert s_ab >= 0.0
    assert math.isclose(s_ab, s_ba, rel_tol=1e-9, abs_tol=1e-12)


@given(kernel_strategy(0), kernel_strategy(1))
@settings(max_examples=60, deadline=None)
def test_unfit_pairs_score_zero(a, b):
    if not fits_together(a, b, GTX580):
        assert pair_score(a, b, GTX580) == 0.0


@given(kernel_strategy(0), kernel_strategy(1))
@settings(max_examples=60, deadline=None)
def test_profile_combine_conserves(a, b):
    """ProfileCombine: demands add (per unit), work adds, ratio is the
    block-weighted mean (between min and max)."""
    c = profile_combine(a, b, GTX580)
    da, db = a.per_unit_demand(GTX580), b.per_unit_demand(GTX580)
    dc = c.per_unit_demand(GTX580)
    for dim in da:
        assert math.isclose(dc[dim], da[dim] + db[dim], rel_tol=1e-9)
    assert math.isclose(c.inst_per_block,
                        a.inst_per_block + b.inst_per_block, rel_tol=1e-9)
    assert min(a.r, b.r) - 1e-9 <= c.r <= max(a.r, b.r) + 1e-9
    assert math.isclose(c.r, combined_ratio(a, b), rel_tol=1e-9)


@given(kernels_strategy(2, 6))
@settings(max_examples=30, deadline=None)
def test_simulator_time_positive_and_bounded(kernels):
    """Total time is at least the roofline lower bound of the whole
    workload and at most the sum of standalone times (work conserving
    vs fully serial), up to occupancy effects on the upper side."""
    t = simulate(kernels, GTX580)
    dev = GTX580
    total_c = sum(k.inst_per_block * k.n_blocks for k in kernels) \
        / dev.n_units
    total_m = sum(k.mem_per_block() * k.n_blocks for k in kernels) \
        / dev.n_units
    lower = max(total_c / dev.compute_rate, total_m / dev.mem_bw)
    assert t >= lower * 0.99
    serial = sum(simulate([k], dev) for k in kernels)
    # Not strictly work-conserving: the common-rate coupling plus an
    # under-occupied tail round can exceed the serial sum slightly
    # (never by more than the occupancy penalty bound).
    assert t <= serial * 1.5


@given(kernels_strategy(2, 5), st.randoms())
@settings(max_examples=30, deadline=None)
def test_simulator_order_invariant_total_work(kernels, rnd):
    """Shuffling the order never changes total executed work — only
    time; and every order terminates."""
    import random
    p = list(kernels)
    rnd.shuffle(p)
    t1 = simulate(kernels, GTX580)
    t2 = simulate(p, GTX580)
    assert t1 > 0 and t2 > 0
