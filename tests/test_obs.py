"""Property tests for ``repro.obs`` (PR 8): trace identity (recorder
on vs off is bit-identical on modelled floats and served tokens
across the round / event / gated models, the fast refiner twins,
slicing, and the serving engine), trace conservation (per-unit span
interval unions equal the dispatcher's independently accumulated busy
time; resident blocks never exceed what the device caps admit), valid
Chrome-trace-event JSON structure, and the MetricsRegistry /
ScheduleCache counter-migration surface.

Written with plain ``random`` (no hypothesis dependency in the pinned
toolchain) over seeded draws, so failures reproduce exactly.
"""

import json
import math
import random
import re

import pytest

from repro.core import (GTX580, EventSimulator, KernelProfile,
                        RoundSimulator, refine_order)
from repro.core.refine import (DeltaEvaluator, _FastEventSim,
                               _FastRoundSim)
from repro.core.resources import (bs_kernel, ep_kernel, es_kernel,
                                  sw_kernel)
from repro.core.tpu import (decode_profile, make_serving_device,
                            prefill_profile)
from repro.graph.delta import _FastGatedSim
from repro.graph.streams import DagEventSimulator
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       PHASES, FlightRecorder, ScheduleTrace,
                       parse_prometheus_text, phase_breakdown,
                       prometheus_text)
from repro.serve.cache import ScheduleCache
from repro.slice import SlicePolicy, greedy_order_slices

_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]
_TPU = make_serving_device()
_TPU4 = make_serving_device(n_units=4)

#: relative tolerance for busy-time vs span-union conservation: both
#: are sums of the same float dts in different orders
_CONS_RTOL = 1e-9


def _gpu_kernels(rng: random.Random, n: int) -> list[KernelProfile]:
    return [rng.choice(_FAMS)(f"k{i}",
                              grid=rng.choice([8, 16, 32, 48, 64, 96]),
                              shm=rng.choice([0, 4096, 8192, 16384]),
                              inst=rng.uniform(1e6, 5e8))
            for i in range(n)]


def _tpu_profiles(rng: random.Random, n: int) -> list[KernelProfile]:
    out = []
    for i in range(n):
        if rng.random() < 0.4:
            out.append(prefill_profile(
                f"p{i}", n_params=7e9,
                seq_len=rng.choice([128, 512, 2048, 8192]),
                kv_bytes_per_token=131072).profile())
        else:
            out.append(decode_profile(
                f"d{i}", n_params=7e9, kv_len=rng.randint(1, 8192),
                kv_bytes_per_token=131072).profile())
    return out


def _random_dag_edges(rng: random.Random, n: int,
                      density: float = 1.0) -> set:
    """Random forward edges (u < v): acyclic by construction."""
    edges = set()
    for _ in range(int(density * n)):
        u, v = rng.randrange(n), rng.randrange(n)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return edges


def _assert_conserved(tr: ScheduleTrace) -> None:
    """Per-unit span interval union == independently accumulated busy
    time: spans exactly tile the modelled residency."""
    assert tr.spans, "trace recorded no spans"
    for u in tr.units():
        union, busy = tr.span_union(u), tr.busy_of(u)
        assert math.isclose(union, busy, rel_tol=_CONS_RTOL,
                            abs_tol=1e-15), (u, union, busy)


# --------------------------------------------------------------------------
# trace identity: recorder on vs off is bit-identical
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU, _tpu_profiles),
                                          (_TPU4, _tpu_profiles)])
def test_event_trace_identity_and_conservation(device, maker):
    rng = random.Random(5)
    for trial in range(8):
        ks = maker(rng, rng.randint(2, 24))
        t_plain = EventSimulator(device).simulate(ks)
        tr = ScheduleTrace()
        t_traced = EventSimulator(device).simulate(ks, trace=tr)
        assert t_traced == t_plain, trial
        assert tr.makespan == pytest.approx(t_plain, rel=1e-12)
        _assert_conserved(tr)
        # the fast twin emits the identical trace
        tr2 = ScheduleTrace()
        t_fast, _ = _FastEventSim(device).simulate(ks, trace=tr2)
        assert t_fast == t_plain
        assert tr2.spans == tr.spans


@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU, _tpu_profiles)])
def test_round_trace_identity(device, maker):
    rng = random.Random(7)
    for trial in range(8):
        ks = maker(rng, rng.randint(2, 20))
        t_plain = RoundSimulator(device).simulate(ks)
        tr = ScheduleTrace()
        assert RoundSimulator(device).simulate(ks, trace=tr) == t_plain
        # strict rounds: everything lands on unit 0, busy == makespan,
        # and a round-boundary instant closes every round
        assert tr.units() == [0]
        assert tr.busy_of(0) == pytest.approx(t_plain, rel=1e-12)
        rounds = [i for i in tr.instants if i[3] == "round"]
        assert rounds and rounds[-1][1] == pytest.approx(t_plain)
        tr2 = ScheduleTrace()
        t_fast, _ = _FastRoundSim(device).simulate(ks, trace=tr2)
        assert t_fast == t_plain
        assert tr2.spans == tr.spans


@pytest.mark.parametrize("device", [GTX580, _TPU4])
def test_gated_trace_identity_and_conservation(device):
    rng = random.Random(11)
    for trial in range(8):
        n = rng.randint(4, 24)
        ks = (_gpu_kernels(rng, n) if device is GTX580
              else _tpu_profiles(rng, n))
        eids = {(id(ks[u]), id(ks[v]))
                for u, v in _random_dag_edges(rng, n,
                                              rng.uniform(0.5, 2.0))}
        t_plain = DagEventSimulator(device, eids).simulate(ks)
        tr = ScheduleTrace()
        t_traced = DagEventSimulator(device, eids).simulate(ks,
                                                            trace=tr)
        assert t_traced == t_plain, trial
        _assert_conserved(tr)
        tr2 = ScheduleTrace()
        t_fast, _ = _FastGatedSim(device, eids).simulate(ks, trace=tr2)
        assert t_fast == t_plain
        assert tr2.spans == tr.spans


def test_sliced_trace_identity_and_conservation():
    rng = random.Random(13)
    for trial in range(6):
        n = rng.randint(4, 14)
        profs = []
        for i in range(n):
            if rng.random() < 0.4:    # oversized: forces slicing
                profs.append(prefill_profile(
                    f"r{i}:p:L0:attn", n_params=7e9,
                    seq_len=rng.choice([6144, 8192, 12288]),
                    kv_bytes_per_token=131072).profile())
            else:
                profs.append(decode_profile(
                    f"r{i}:d:L0:attn", n_params=7e9,
                    kv_len=rng.randint(256, 8192),
                    kv_bytes_per_token=131072).profile())
        edges = _random_dag_edges(rng, n, rng.uniform(0.0, 1.0))
        res = greedy_order_slices(profs, _TPU4, edges=edges,
                                  policy=SlicePolicy())
        eids = res.edges_by_id()
        t_plain = DagEventSimulator(_TPU4, eids).simulate(res.order)
        tr = ScheduleTrace()
        assert DagEventSimulator(_TPU4, eids).simulate(
            res.order, trace=tr) == t_plain, trial
        _assert_conserved(tr)
        if res.sliced:
            # zero-work joins retire as device-scoped instants, never
            # as spans (they hold no residency)
            joins = [i for i in tr.instants if i[3] == "join"]
            assert joins and all(i[2] is None for i in joins)
            assert not any("#join" in s[1] for s in tr.spans)


def test_delta_evaluator_rebase_forwards_trace():
    rng = random.Random(17)
    ks = _gpu_kernels(rng, 12)
    for model in ("round", "event"):
        ev = DeltaEvaluator(GTX580, model=model)
        t_plain = ev.rebase(ks)
        tr = ScheduleTrace()
        assert DeltaEvaluator(GTX580, model=model).rebase(
            ks, trace=tr) == t_plain
        assert tr.spans and tr.makespan == pytest.approx(t_plain)


def test_max_resident_blocks_within_device_caps():
    """Identical kernels with known per-block demands: the trace's
    peak concurrent residency per unit can never exceed what the unit
    caps admit."""
    rng = random.Random(19)
    for trial in range(6):
        ks = [ep_kernel(f"k{i}", grid=rng.choice([8, 16, 32]),
                        shm=8192, inst=2e7) for i in range(10)]
        dem = ks[0].demands
        cap_blocks = min(
            int(GTX580.cap(d) // v) for d, v in dem.items() if v > 0)
        tr = ScheduleTrace()
        EventSimulator(GTX580).simulate(ks, trace=tr)
        for u in tr.units():
            assert 1 <= tr.max_resident_blocks(u) <= cap_blocks


# --------------------------------------------------------------------------
# Chrome trace-event export
# --------------------------------------------------------------------------

def test_chrome_trace_structure_from_traced_arch():
    """The acceptance artifact: a DagEventSimulator run over a traced
    arch exports structurally valid Chrome trace-event JSON."""
    from repro.configs import get_config
    from repro.graph import greedy_order_dag, trace_arch

    cfg = get_config("qwen1.5-0.5b", "full")
    g = trace_arch(cfg, [("prefill", 128), ("decode", 256),
                         ("decode", 512)], max_stages=8).graph
    g.validate()
    sched = greedy_order_dag(g.kernels, _TPU4, edges=g.edges)
    tr = ScheduleTrace(label="traced-arch")
    t = DagEventSimulator(_TPU4, g.edges_by_id()).simulate(sched.order,
                                                           trace=tr)
    doc = tr.to_chrome()
    # round-trips through the JSON wire format
    doc = json.loads(json.dumps(doc))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert evs
    units = set(tr.units())
    metas = [e for e in evs if e["ph"] == "M"]
    assert {m["pid"] for m in metas} == units
    assert all(m["name"] == "process_name" for m in metas)
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == len(tr.spans) == len(sched.order)
    for e in xs:
        assert e["pid"] in units and e["tid"] == 0
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
        assert e["ts"] + e["dur"] <= t * 1e6 * (1 + 1e-9)
        assert e["args"]["blocks"] >= 1
    for e in (e for e in evs if e["ph"] == "i"):
        assert e["s"] in ("g", "t") and e["ts"] >= 0.0
    assert {e["ph"] for e in evs} <= {"M", "X", "i"}


def test_gantt_renders_every_unit():
    rng = random.Random(23)
    tr = ScheduleTrace(label="gantt")
    EventSimulator(_TPU4).simulate(_tpu_profiles(rng, 12), trace=tr)
    text = tr.gantt(width=40)
    assert "gantt" in text and "legend:" in text
    for u in tr.units():
        assert f"unit {u:>2} |" in text
    assert ScheduleTrace().gantt() == "(empty trace)"


def test_gantt_golden_fixed_schedule():
    """Exact rendering of a hand-built two-unit trace: symbols follow
    span insertion order, overlapping distinct kernels collapse to
    ``*``, the header carries the makespan and per-column unit label,
    and instants list below the legend."""
    tr = ScheduleTrace(label="g")
    tr.span(0, "a", 0.0, 1.0)
    tr.span(0, "b", 1.0, 2.0)
    tr.span(1, "c", 0.0, 2.0)
    tr.span(1, "d", 0.5, 1.0)
    tr.instant("round", 2.0)
    assert tr.gantt(width=8) == (
        "g  (makespan 2s, 1 col = 0.25s)\n"
        "unit  0 |aaaabbbb|\n"
        "unit  1 |cc**cccc|\n"
        "legend: a=a, b=b, c=c, d=d\n"
        "  @2s [device] round")


def test_gantt_width_clamping():
    """A zero-width span sitting exactly at the makespan still renders
    one cell, clamped inside the chart; every row is exactly the asked
    width regardless of rounding."""
    tr = ScheduleTrace(label="clamp")
    tr.span(0, "a", 0.0, 2.0)
    tr.span(0, "z", 2.0, 2.0)      # degenerate span at the right edge
    text = tr.gantt(width=8)
    row = next(ln for ln in text.splitlines() if ln.startswith("unit"))
    assert row == "unit  0 |aaaaaaa*|"
    for w in (1, 3, 72):
        for ln in tr.gantt(width=w).splitlines():
            if ln.startswith("unit"):
                assert len(ln) == len("unit  0 ||") + w


def test_gantt_empty_trace_and_instant_only():
    assert ScheduleTrace().gantt() == "(empty trace)"
    tr = ScheduleTrace()
    tr.instant("round", 1.0)       # events but no residency
    assert tr.gantt() == "(empty trace)"


# --------------------------------------------------------------------------
# MetricsRegistry
# --------------------------------------------------------------------------

def test_registry_labels_are_distinct_series():
    m = MetricsRegistry()
    m.counter("cache_hits", namespace="flat").inc(3)
    m.counter("cache_hits", namespace="dag").inc()
    snap = m.snapshot()
    assert snap["cache_hits{namespace=flat}"] == 3.0
    assert snap["cache_hits{namespace=dag}"] == 1.0
    # same name + labels resolves to the same object
    assert (m.counter("cache_hits", namespace="flat")
            is m.counter("cache_hits", namespace="flat"))


def test_registry_kind_mismatch_raises():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(TypeError):
        m.gauge("x")
    with pytest.raises(TypeError):
        m.histogram("x")


def test_histogram_snapshot_expansion_and_timer():
    m = MetricsRegistry()
    # empty histograms are schema-stable zeros
    m.histogram("phase_refine")
    snap = m.snapshot()
    assert snap["phase_refine.count"] == 0
    assert snap["phase_refine.min_s"] == 0.0
    h = m.histogram("phase_compose")
    for v in (0.25, 0.75):
        h.observe(v)
    snap = m.snapshot()
    assert snap["phase_compose.count"] == 2
    assert snap["phase_compose.total_s"] == pytest.approx(1.0)
    assert snap["phase_compose.mean_s"] == pytest.approx(0.5)
    assert snap["phase_compose.min_s"] == 0.25
    assert snap["phase_compose.max_s"] == 0.75
    with m.timer("phase_guard"):
        pass
    assert m.histogram("phase_guard").count == 1
    assert m.histogram("phase_guard").total >= 0.0


def test_registry_reset_is_prefix_scoped():
    m = MetricsRegistry()
    c = m.counter("cache_hits", namespace="flat")
    c.inc(5)
    m.histogram("phase_compose").observe(1.0)
    m.reset(prefix="cache_")
    assert c.value == 0.0                       # reference stays live
    assert m.histogram("phase_compose").count == 1
    m.reset()
    assert m.histogram("phase_compose").count == 0


def test_phase_breakdown_covers_all_phases():
    m = MetricsRegistry()
    m.histogram("phase_compose").observe(0.5)
    pb = phase_breakdown(m)
    assert set(pb) == set(PHASES)
    assert pb["compose"] == {"calls": 1, "total_s": 0.5, "mean_s": 0.5}
    assert pb["execute"]["calls"] == 0


def test_histogram_reservoir_quantiles():
    """PR 9: histograms keep a seeded fixed-size reservoir, so
    snapshots carry p50/p95/p99 without storing every observation.
    Under the reservoir size the quantiles are exact."""
    m = MetricsRegistry()
    h = m.histogram("request_latency_s")
    for v in range(1, 101):           # 1..100, well under the reservoir
        h.observe(float(v))
    snap = m.snapshot()
    assert snap["request_latency_s.p50_s"] == pytest.approx(50.0, abs=1.5)
    assert snap["request_latency_s.p95_s"] == pytest.approx(95.0, abs=1.5)
    assert snap["request_latency_s.p99_s"] == pytest.approx(99.0, abs=1.5)
    # pre-existing snapshot keys are unchanged by the satellite
    assert snap["request_latency_s.count"] == 100
    assert snap["request_latency_s.mean_s"] == pytest.approx(50.5)
    assert snap["request_latency_s.min_s"] == 1.0
    assert snap["request_latency_s.max_s"] == 100.0


def test_histogram_reservoir_is_deterministic():
    """Over-full reservoirs subsample with a per-series seeded RNG
    (crc32 of the name, not the salted ``hash``), so two registries
    fed the identical stream report identical quantiles — and so does
    the same registry after a reset."""
    def fill(h):
        for v in range(5000):
            h.observe((v * 37 % 5000) / 5000.0)

    a, b = MetricsRegistry(), MetricsRegistry()
    fill(a.histogram("phase_compose"))
    fill(b.histogram("phase_compose"))
    ka = {k: v for k, v in a.snapshot().items() if ".p" in k}
    kb = {k: v for k, v in b.snapshot().items() if ".p" in k}
    assert ka == kb and ka
    # quantiles of a uniform stream land near the ideal even once the
    # reservoir is subsampling 5000 >> 256 points
    assert ka["phase_compose.p50_s"] == pytest.approx(0.5, abs=0.1)
    a.reset()
    fill(a.histogram("phase_compose"))
    assert {k: v for k, v in a.snapshot().items() if ".p" in k} == ka


def test_metric_classes_standalone():
    c, g, h = Counter("c"), Gauge("g"), Histogram("h")
    c.inc(); c.inc(2.5)
    assert c.value == 3.5
    g.set(7)
    assert g.value == 7.0
    assert h.mean == 0.0
    h.observe(2.0)
    assert (h.count, h.total, h.vmin, h.vmax) == (1, 2.0, 2.0, 2.0)


# --------------------------------------------------------------------------
# refinement metrics through the batched backend (the composer path)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["round", "event"])
def test_refine_batched_populates_metrics(model):
    rng = random.Random(7)
    ks = _gpu_kernels(rng, 12)
    m = MetricsRegistry()
    best, best_t, evals = refine_order(
        ks, GTX580, model=model, budget=40, batch_size=4, metrics=m)
    snap = m.snapshot()
    assert snap["refine_evals"] == evals >= 1
    assert snap["refine_cost"] >= 1.0
    assert snap["refine_score_s.count"] == 1
    assert snap["refine_score_s.total_s"] >= 0.0
    # metrics are purely additive: same trajectory with them off
    best2, best_t2, evals2 = refine_order(
        ks, GTX580, model=model, budget=40, batch_size=4)
    assert best_t2 == best_t and evals2 == evals
    assert [k.name for k in best2] == [k.name for k in best]


def test_refine_dag_batched_gated_populates_metrics():
    from repro.graph import refine_order_dag

    rng = random.Random(11)
    n = 16
    ks = _gpu_kernels(rng, n)
    edges = _random_dag_edges(rng, n)
    edge_ids = {(id(ks[u]), id(ks[v])) for u, v in edges}
    m = MetricsRegistry()
    _, t, evals = refine_order_dag(
        ks, GTX580, edge_ids=edge_ids, model="gated", budget=20,
        batch_size=8, metrics=m)
    snap = m.snapshot()
    assert snap["refine_evals"] == evals >= 1
    assert snap["refine_score_s.count"] == 1
    _, t2, evals2 = refine_order_dag(
        ks, GTX580, edge_ids=edge_ids, model="gated", budget=20,
        batch_size=8)
    assert (t2, evals2) == (t, evals)


# --------------------------------------------------------------------------
# ScheduleCache on the registry (satellite: reset + namespace breakdown)
# --------------------------------------------------------------------------

def test_cache_namespace_breakdown_and_legacy_totals():
    c = ScheduleCache()
    c.lookup(("flat", "symbiotic", ("a",)), namespace="flat")   # miss
    c.store(("flat", "symbiotic", ("a",)), (("a",),))
    c.lookup(("flat", "symbiotic", ("a",)), namespace="flat")   # hit
    c.lookup(("dag", "symbiotic", ("b",)), namespace="dag")     # miss
    assert c.hits == 1 and c.misses == 2
    assert c.hit_breakdown() == {"flat": {"hits": 1, "misses": 1},
                                 "dag": {"hits": 0, "misses": 1}}
    st = c.stats()
    assert st["hits"] == 1 and st["misses"] == 2
    assert st["by_namespace"] == c.hit_breakdown()
    # legacy attribute surface still works (registry-backed)
    c.dag_hits += 1
    c.gated_sims_saved += 0.25
    assert c.stats()["dag_hits"] == 1
    assert c.stats()["gated_sims_saved"] == 0.25
    assert c.metrics.counter("cache_dag_hits").value == 1.0


def test_cache_reset_zeroes_own_series_only():
    m = MetricsRegistry()
    c = ScheduleCache(metrics=m)
    c.store(("flat", "symbiotic", ("a",)), (("a",),))
    c.lookup(("flat", "symbiotic", ("a",)), namespace="flat")
    c.incremental_joins += 2
    m.histogram("phase_compose").observe(1.0)   # engine-shared series
    c.reset()
    assert c.hits == c.misses == 0
    assert c.incremental_joins == 0
    assert c.stats()["entries"] == 0
    assert c.lookup(("flat", "symbiotic", ("a",)),
                    namespace="flat") is None   # store dropped
    assert m.histogram("phase_compose").count == 1   # survives
    # store=False keeps patterns while zeroing counters
    c.store(("flat", "symbiotic", ("b",)), (("b",),))
    c.lookup(("flat", "symbiotic", ("b",)), namespace="flat")
    c.reset(store=False)
    assert c.hits == 0
    assert c.lookup(("flat", "symbiotic", ("b",)),
                    namespace="flat") is not None


# --------------------------------------------------------------------------
# serving engine: full instrumentation is invisible to outputs
# --------------------------------------------------------------------------

def test_engine_instrumentation_bit_identical_and_phased():
    jax = pytest.importorskip("jax")
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import Request, SchedulerPolicy, ServingEngine

    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)

    def run(metrics=None, trace=None):
        eng = ServingEngine(cfg, params, max_len=32,
                            policy=SchedulerPolicy(
                                kind="symbiotic", respect_deps=True),
                            metrics=metrics, trace=trace)
        rng = np.random.default_rng(0)
        eng.submit([Request(i, rng.integers(0, 128, size=4),
                            max_new_tokens=3) for i in range(2)])
        return eng.run()

    s_plain = run()
    m, tr = MetricsRegistry(), ScheduleTrace()
    s_inst = run(metrics=m, trace=tr)
    assert s_inst["outputs"] == s_plain["outputs"]
    assert s_inst["total_new_tokens"] == s_plain["total_new_tokens"]
    assert s_inst["modelled_time_s"] == s_plain["modelled_time_s"]
    # phases and the snapshot ride on run() stats
    pb = s_inst["phases"]
    assert pb["compose"]["calls"] > 0 and pb["execute"]["calls"] > 0
    assert s_inst["metrics"]["engine_steps"] >= pb["compose"]["calls"]
    # the served-round trace spans the engine's modelled timeline
    assert tr.spans
    assert tr.makespan == pytest.approx(s_inst["modelled_time_s"],
                                        rel=1e-9)


def test_engine_batched_refine_backend_records_metrics():
    """The composer hands ``cache.metrics`` to every refinement call,
    so the batched backend must accept a live registry end-to-end
    (regression: refine_order_batched once referenced an undefined
    ``t_wall`` whenever metrics were on)."""
    jax = pytest.importorskip("jax")
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import Request, SchedulerPolicy, ServingEngine

    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_len=32,
                        policy=SchedulerPolicy(
                            kind="refined", refine_model="event",
                            refine_backend="batched", refine_batch=8,
                            refine_budget=20))
    rng = np.random.default_rng(0)
    eng.submit([Request(i, rng.integers(0, 128, size=4),
                        max_new_tokens=3) for i in range(2)])
    stats = eng.run()
    assert stats["total_new_tokens"] > 0
    snap = stats["metrics"]
    assert snap["refine_evals"] >= 1
    assert snap["refine_score_s.count"] >= 1


# --------------------------------------------------------------------------
# export layer (PR 9): Prometheus exposition + JSONL flight recorder
# --------------------------------------------------------------------------

def _random_registry(rng: random.Random) -> MetricsRegistry:
    m = MetricsRegistry()
    for i in range(rng.randint(1, 5)):
        m.counter("cache_hits", namespace=rng.choice(["flat", "dag"])
                  ).inc(rng.randint(0, 50))
    m.counter("engine_steps").inc(rng.randint(1, 9))
    m.gauge("cache_entries").set(rng.uniform(0, 100))
    h = m.histogram("phase_compose")
    for _ in range(rng.randint(1, 40)):
        h.observe(rng.uniform(1e-6, 2.0))
    m.histogram("audit_quality_percentile",
                arch="qwen1.5-0.5b", kind="refined").observe(
                    rng.uniform(0, 100))
    return m


def test_prometheus_roundtrip_property():
    """Seeded property: every counter/gauge sample and every histogram
    sum/count survive the text exposition bit-exactly (%.17g), and
    quantile samples match the reservoir's answer."""
    rng = random.Random(29)
    for _ in range(10):
        m = _random_registry(rng)
        text = prometheus_text(m)
        parsed = parse_prometheus_text(text)
        snap = m.snapshot()
        for key, v in snap.items():
            name, _, field = key.partition(".")
            if not field:                       # counter / gauge
                # snapshot key {k=v} -> exposition key {k="v"}
                pk = "repro_" + re.sub(r"=([^,}]*)", r'="\1"', name)
                assert parsed[pk] == v, key
        h = m.histogram("phase_compose")
        assert parsed["repro_phase_compose_count"] == h.count
        assert parsed["repro_phase_compose_sum"] == pytest.approx(
            h.total, rel=1e-15)
        assert parsed['repro_phase_compose{quantile="0.5"}'] == \
            h.quantile(0.5)


def test_prometheus_text_structure():
    m = MetricsRegistry()
    m.counter("cache_hits", namespace="flat").inc(3)
    m.gauge("cache_entries").set(2)
    m.histogram("phase_compose").observe(0.5)
    text = prometheus_text(m)
    assert "# TYPE repro_cache_hits counter" in text
    assert "# TYPE repro_cache_entries gauge" in text
    assert "# TYPE repro_phase_compose summary" in text
    assert 'repro_cache_hits{namespace="flat"} 3' in text
    assert "repro_phase_compose_count 1" in text
    # one TYPE header per base metric, even with several labelled series
    m.counter("cache_hits", namespace="dag").inc()
    text = prometheus_text(m)
    assert text.count("# TYPE repro_cache_hits counter") == 1


def test_flight_recorder_roundtrip_and_timeline(tmp_path):
    rng = random.Random(31)
    rec = FlightRecorder()
    kinds = ("schedule", "cache", "audit", "rebuild")
    want = []
    for i in range(rng.randint(5, 40)):
        kind = rng.choice(kinds)
        fields = {"step": i, "ok": rng.random() < 0.5,
                  "ratio": rng.uniform(0, 2)}
        rec.event(kind, **fields)
        want.append({"seq": i, "kind": kind, **fields})
    assert rec.events == want
    # text round-trip
    assert FlightRecorder.load(rec.to_jsonl()) == want
    # file round-trip
    p = tmp_path / "flight.jsonl"
    rec.dump(str(p))
    assert FlightRecorder.load(str(p)) == want
    tl = FlightRecorder.timeline(want)
    assert tl["n_events"] == len(want)
    assert sum(tl["by_kind"].values()) == len(want)
    assert len(tl["lines"]) == len(want)
    assert tl["lines"][0].startswith("#0 ")


def test_flight_recorder_caps_events():
    rec = FlightRecorder(max_events=10)
    for i in range(25):
        rec.event("schedule", step=i)
    assert len(rec.events) == 10
    assert rec.dropped == 15
    assert rec.events[0]["step"] == 15      # FIFO drop, newest kept
    assert rec.events[-1]["seq"] == 24      # seq keeps counting
