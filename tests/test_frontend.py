"""Frontend property suite (PR 10).

Admission-control invariants (the modelled-cost budget is never
exceeded; deferred requests are never starved), frontend-vs-synchronous
token bit-identity on all three traced archs, replica-routing
determinism, cache-stats conservation across replicas, and the
satellite-4 pin: audit sampling keys on **engine-local** step counts
(each replica's own ``QualityAuditor``), not global dispatch ticks,
and the :class:`FlightRecorder` receives per-dispatch
``frontend_step`` events carrying both counters.

Calibration note: under the round cost model a round's time is
dominated by the weight stream (charged once per round), so admission
pressure is created by *round count*, not item count — the churn tests
use a ``token_budget``-constrained device so a few in-flight prompts
already overflow into extra rounds, and virtual arrival rates around
``1e6`` so seeded Poisson gaps (~1e-6 s) undercut modelled step times
(~1e-5 s).  Virtual seconds are arbitrary units; only these ratios
matter.
"""

import jax
import pytest

from proptest import cases
from repro.configs import get_config
from repro.core.tpu import make_serving_device
from repro.models import transformer as T
from repro.obs import FlightRecorder, QualityAuditor
from repro.serve import (AdmissionPolicy, SchedulerPolicy, ServingEngine,
                         ServingFrontend, make_workload)

pytestmark = pytest.mark.frontend

ARCHS = ("qwen1.5-0.5b", "mixtral-8x7b", "deepseek-v2-236b")
_PARAMS_CACHE: dict = {}
#: high virtual arrival rate: gaps ~1e-6 s vs modelled steps ~1e-5 s,
#: so arrivals genuinely queue behind in-flight work.
_RATE = 1e6


def _cfg_params(arch: str):
    if arch not in _PARAMS_CACHE:
        cfg = get_config(arch, "smoke")
        _PARAMS_CACHE[arch] = (cfg, T.init(jax.random.PRNGKey(0), cfg))
    return _PARAMS_CACHE[arch]


def _tiny_device():
    """~10 prompt tokens per round: admission cost climbs one round
    per couple of live prompts."""
    return make_serving_device(token_budget=10)


def _frontend(arch: str = "qwen1.5-0.5b", *, n_replicas: int = 1,
              policy: SchedulerPolicy | None = None,
              admission: AdmissionPolicy | None = None,
              shared_cache: bool = False, recorder=None, device=None):
    cfg, params = _cfg_params(arch)
    return ServingFrontend.build(
        cfg, params, n_replicas=n_replicas, max_len=32,
        policy=policy or SchedulerPolicy(), admission=admission,
        shared_cache=shared_cache, recorder=recorder, device=device)


def _budget(fe: ServingFrontend, workload, slack: float):
    """``slack`` multiples of the cheapest solo round cost (all solos
    are ~one weight-stream round, so slack≈1.x admits roughly one
    round's worth of work at a time)."""
    return slack * min(fe.solo_cost_s(0, r) for _, r in workload)


# --------------------------------------------------------------------------
# admission invariants
# --------------------------------------------------------------------------

def test_admission_never_exceeds_budget():
    """Every admit event's modelled next-step cost (the fifo-packed
    round_time of the replica's live items plus the candidate) is
    within the budget — the invariant, read off the recorder."""
    wl = make_workload("poisson", 10, _RATE, seed=3, prompt_len=(3, 8))
    probe = _frontend(device=_tiny_device())
    budget = _budget(probe, wl, slack=1.25)
    rec = FlightRecorder()
    fe = _frontend(device=_tiny_device(), recorder=rec,
                   admission=AdmissionPolicy(round_cost_budget_s=budget,
                                             max_defer=4))
    fe.run(wl)
    admits = [e for e in rec.events if e["kind"] == "admit"]
    defers = [e for e in rec.events if e["kind"] == "defer"]
    assert admits, "workload admitted nothing"
    assert defers, "budget not tight enough to exercise deferral"
    for e in admits:
        assert e["est_with"] <= e["budget"] + 1e-12, e
    assert fe.stats()["latency"]["completed"] == len(admits)


@cases(n=3, seed=11)
def test_deferred_never_starved(rng):
    """Bounded wait under seeded Poisson churn: a request deferred
    ``max_defer`` times blocks the queue — no younger request is
    admitted past it — and every admitted request completes."""
    seed = rng.randrange(1 << 16)
    wl = make_workload("poisson", 10, _RATE, seed=seed,
                       prompt_len=(3, 8))
    probe = _frontend(device=_tiny_device())
    rec = FlightRecorder()
    fe = _frontend(device=_tiny_device(), recorder=rec,
                   admission=AdmissionPolicy(
                       round_cost_budget_s=_budget(probe, wl, 1.25),
                       max_defer=2))
    st = fe.run(wl)
    # completion: everything not rejected finishes its full budget
    outs = fe.outputs()
    assert len(outs) == st["admitted"] == st["submitted"] - st["rejected"]
    by_rid = {r.rid: r for _, r in wl}
    for rid, toks in outs.items():
        assert len(toks) == by_rid[rid].max_new_tokens
    # ordering: once rid b is blocked (deferrals hit max_defer), every
    # later admit until b's own is for a request AHEAD of b in FIFO
    # (rids increase with arrival order in make_workload).
    blocked: set[int] = set()
    for e in rec.events:
        if e["kind"] == "defer" and e["deferrals"] >= 2:
            blocked.add(e["rid"])
        elif e["kind"] == "admit":
            blocked.discard(e["rid"])
            for b in blocked:
                assert e["rid"] < b, (
                    f"rid {e['rid']} admitted past blocked {b}")
    assert not blocked, "blocked requests never admitted (starved)"


def test_oversized_and_queue_full_rejections():
    probe = _frontend(device=_tiny_device())
    wl = make_workload("bursty", 6, _RATE, seed=5, prompt_len=(5, 5))
    solo = min(probe.solo_cost_s(0, r) for _, r in wl)
    # budget below every solo cost: nothing can ever be admitted
    fe = _frontend(device=_tiny_device(),
                   admission=AdmissionPolicy(
                       round_cost_budget_s=0.5 * solo))
    st = fe.run(wl)
    assert st["rejected"] == st["submitted"] == 6
    assert st["rejection_rate"] == 1.0 and fe.outputs() == {}
    m = fe.metrics
    assert int(m.counter("frontend_rejected",
                         reason="oversized").value) == 6
    # depth-1 queue under a burst with a one-round budget: the head
    # defers while the replica is busy, so the burst overflows
    fe2 = _frontend(device=_tiny_device(),
                    admission=AdmissionPolicy(
                        round_cost_budget_s=1.05 * solo,
                        max_queue_depth=1))
    st2 = fe2.run(make_workload("bursty", 6, _RATE, seed=5,
                                prompt_len=(5, 5)))
    qf = int(fe2.metrics.counter("frontend_rejected",
                                 reason="queue_full").value)
    assert qf > 0
    assert st2["admitted"] + st2["rejected"] == st2["submitted"]
    assert len(fe2.outputs()) == st2["admitted"]


# --------------------------------------------------------------------------
# bit-identity, routing determinism, cache conservation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_tokens_bit_identical_vs_synchronous(arch):
    """Frontend-served tokens equal the synchronous ``step()`` loop's
    on the traced incremental path (joins/retires through the
    LiveComposition frontier) — execution is exact per request, so
    reordering and admission must not change a single token."""
    cfg, params = _cfg_params(arch)
    policy = SchedulerPolicy(respect_deps=True,
                             composition="incremental")
    fe = ServingFrontend.build(cfg, params, n_replicas=2, max_len=32,
                               policy=policy)
    fe.run(make_workload("poisson", 6, _RATE, seed=9,
                         max_new_tokens=(2, 4)))
    sync = ServingEngine(cfg, params, max_len=32,
                         policy=SchedulerPolicy(
                             respect_deps=True, composition="batch"))
    sync.submit([r for _, r in make_workload(
        "poisson", 6, _RATE, seed=9, max_new_tokens=(2, 4))])
    assert fe.outputs() == sync.run()["outputs"]


def test_replica_routing_determinism():
    """Same seed, fresh pool: identical (rid → replica) assignment
    sequence and identical virtual-time stats, twice over."""
    def one():
        rec = FlightRecorder()
        fe = _frontend(n_replicas=2, device=_tiny_device(),
                       recorder=rec)
        st = fe.run(make_workload("bursty", 8, _RATE, seed=21,
                                  prompt_len=(3, 8)))
        picks = [(e["rid"], e["replica"]) for e in rec.events
                 if e["kind"] == "admit"]
        return picks, st

    picks_a, st_a = one()
    picks_b, st_b = one()
    assert picks_a and picks_a == picks_b
    assert st_a == st_b


def test_cache_stats_conservation_across_replicas():
    """Flat-path lookups are conserved: one lookup per dispatched
    step, whether each replica keeps its own ScheduleCache or the
    pool shares one — and tokens are identical either way."""
    adm = AdmissionPolicy(route="round_robin")
    fe = _frontend(n_replicas=2, admission=adm)
    fe.run(make_workload("poisson", 8, _RATE, seed=13))
    for i, eng in enumerate(fe.engines):
        s = eng.schedule_cache.stats()
        assert s["hits"] + s["misses"] == fe._steps[i]

    fe2 = _frontend(n_replicas=2, shared_cache=True, admission=adm)
    fe2.run(make_workload("poisson", 8, _RATE, seed=13))
    assert fe2.engines[0].schedule_cache is fe2.engines[1].schedule_cache
    shared = fe2.engines[0].schedule_cache.stats()
    assert shared["hits"] + shared["misses"] == sum(fe2._steps)
    assert fe2.outputs() == fe.outputs()


def test_cache_affinity_routes_same_signature_together():
    """Identical prefill signatures land on one replica (warm
    pattern store), pinned via the sticky map."""
    rec = FlightRecorder()
    fe = _frontend(n_replicas=2, recorder=rec,
                   admission=AdmissionPolicy(route="cache_affinity"))
    fe.run(make_workload("poisson", 8, _RATE, seed=2,
                         prompt_len=(5, 5)))   # one signature for all
    picks = {e["replica"] for e in rec.events if e["kind"] == "admit"}
    assert len(picks) == 1


# --------------------------------------------------------------------------
# satellite 4: engine-local audit keying + frontend_step events
# --------------------------------------------------------------------------

def test_audit_sampling_keys_on_engine_local_steps():
    """With two replicas at ``audit_frac=0.5``, each replica audits
    per *its own* step count (the PR 3 integer-crossing rule over the
    engine-local counter) — not per global dispatch tick."""
    policy = SchedulerPolicy(audit_frac=0.5, audit_k=3)
    fe = _frontend(n_replicas=2, policy=policy,
                   admission=AdmissionPolicy(route="round_robin"))
    fe.run(make_workload("poisson", 8, _RATE, seed=7))
    assert all(s > 0 for s in fe._steps), "need both replicas stepping"
    total_ticks = fe._tick
    for i, eng in enumerate(fe.engines):
        seen = eng.composer.auditor._steps_seen
        assert seen == fe._steps[i] < total_ticks
        expected = sum(QualityAuditor.crossed(s, 0.5)
                       for s in range(1, seen + 1))
        audited = int(eng.metrics.counter("audit_steps").value)
        assert audited == expected


def test_frontend_step_events_carry_both_counters():
    """Every dispatch emits one ``frontend_step`` event with the
    global ``tick`` and the replica's engine-local ``engine_step``;
    per replica the latter is the contiguous sequence 1..steps."""
    rec = FlightRecorder()
    fe = _frontend(n_replicas=2, recorder=rec,
                   admission=AdmissionPolicy(route="round_robin"))
    fe.run(make_workload("poisson", 6, _RATE, seed=4))
    steps = [e for e in rec.events if e["kind"] == "frontend_step"]
    assert [e["tick"] for e in steps] == list(range(1, fe._tick + 1))
    assert all(e["dt"] >= 0 and e["t_end"] >= e["t_start"]
               for e in steps)
    for i in range(2):
        local = [e["engine_step"] for e in steps if e["replica"] == i]
        assert local == list(range(1, fe._steps[i] + 1))
