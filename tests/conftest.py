"""Shared pytest wiring: the ``requires_jax_device`` marker.

Tests exercising the *compiled* Pallas path (not interpret mode) carry
``@pytest.mark.requires_jax_device``; on CPU-only runners they are
skipped automatically — the interpret-mode twins in the same files
cover the kernel logic there, so tier-1 stays runnable everywhere.
"""

import pytest


def _has_accelerator() -> bool:
    try:
        import jax
    except Exception:
        return False
    try:
        return jax.default_backend() in ("tpu", "gpu", "cuda", "rocm")
    except Exception:
        return False


def pytest_collection_modifyitems(config, items):
    if _has_accelerator():
        return
    skip = pytest.mark.skip(
        reason="no TPU/GPU jax backend: compiled Pallas path unavailable "
               "(interpret-mode tests cover the kernel logic)")
    for item in items:
        if "requires_jax_device" in item.keywords:
            item.add_marker(skip)
