"""PR 9: the online quality auditor, per-request latency accounting,
drift monitoring, and the flight-recorder wiring through the serving
stack.

The load-bearing claims:

* the served ``kind="refined"`` composition of a traced arch audits at
  or above the paper's 90th-percentile claim against K=50 seeded
  random topological orders on the four-core serving device (the
  Fig.-1 protocol, run by :class:`repro.obs.QualityAuditor` exactly
  the way the engine runs it online);
* auditing, latency tracking and flight recording are pure observers:
  served tokens are bit-identical with all of them on or off;
* the deprecated ``SchedulerPolicy.warm_audit_frac`` keeps feeding the
  historical ``warm_regret_mean`` / ``warm_sampled`` stats keys,
  routed through the auditor.
"""

import random

import numpy as np
import pytest

from repro.core.tpu import make_serving_device
from repro.obs import (DriftMonitor, FlightRecorder, LatencyTracker,
                       MetricsRegistry, QualityAuditor)

_X4 = make_serving_device(n_units=4)

#: model-free stand-in for a populated KV cache (``build_dag_triples``
#: only checks ``r.cache is None``)
_DECODED = object()


def _traced_step(arch="qwen1.5-0.5b", *, max_stages=8,
                 reqs_spec=(("prefill", 64), ("prefill", 32),
                            ("decode", 128), ("decode", 256),
                            ("decode", 512))):
    from repro.configs import get_config
    from repro.graph.kernel_graph import (arch_kv_bytes_per_token,
                                          estimate_n_params)
    from repro.serve import Request, build_dag_triples

    cfg = get_config(arch, "full")
    n_params = estimate_n_params(cfg)
    reqs = []
    for rid, (phase, n) in enumerate(reqs_spec):
        r = Request(rid, np.zeros(n, np.int32))
        if phase == "decode":
            r.cache, r.pos = _DECODED, n
        reqs.append(r)
    triples, traced = build_dag_triples(
        cfg, reqs, n_params=n_params,
        kv_bytes_per_token=arch_kv_bytes_per_token(cfg),
        max_stages=max_stages)
    return n_params, triples, traced


def _refined_composer(n_params, *, metrics=None, recorder=None,
                      **pol_kw):
    from repro.serve import Composer, ScheduleCache, SchedulerPolicy

    pol_kw.setdefault("kind", "refined")
    pol_kw.setdefault("respect_deps", True)
    pol_kw.setdefault("refine_model", "gated")
    pol_kw.setdefault("dag_guard", "gated")
    pol_kw.setdefault("cache", False)
    pol = SchedulerPolicy(audit_frac=1.0, audit_k=50, **pol_kw)
    cache = ScheduleCache(metrics=metrics)
    return Composer(pol, _X4, 2.0 * n_params, cache,
                    recorder=recorder), pol


# --------------------------------------------------------------------------
# deterministic sampling
# --------------------------------------------------------------------------

def test_crossing_rule_density_and_determinism():
    for frac in (0.05, 0.25, 1.0):
        hits = [n for n in range(1, 401)
                if QualityAuditor.crossed(n, frac)]
        assert len(hits) == int(400 * frac)
        assert hits == [n for n in range(1, 401)
                        if QualityAuditor.crossed(n, frac)]
    assert not any(QualityAuditor.crossed(n, 0.0)
                   for n in range(1, 100))


def test_sample_step_counts_and_seeds():
    class Pol:
        audit_frac, audit_seed = 0.5, 7

    aud = QualityAuditor(Pol(), _X4, MetricsRegistry())
    picks = [aud.sample_step() for _ in range(10)]
    assert sum(picks) == 5
    s1 = aud._seed()
    aud.sample_step()
    assert aud._seed() != s1          # distinct baselines per step


# --------------------------------------------------------------------------
# the Fig.-1 acceptance claim, online
# --------------------------------------------------------------------------

def test_refined_traced_step_audits_above_floor():
    """The acceptance criterion at test scale: the served refined
    composition of a traced qwen step on the x4 device lands at or
    above the 90th percentile of 50 seeded random topological orders
    under the gated-event makespan.  (benchmarks/serving.py
    ``audit_bench`` runs the same protocol on all three archs at
    16 coarsened stages.)"""
    rec = FlightRecorder()
    n_params, triples, traced = _traced_step()
    comp, _ = _refined_composer(n_params, recorder=rec)
    rounds = comp.compose_dag(triples, traced)
    verdict = comp.auditor.audit_dag(rounds, traced,
                                     arch="qwen1.5-0.5b@x4",
                                     kind="refined")
    assert verdict is not None
    assert verdict["k"] == 50
    assert verdict["currency"] == "gated"
    assert verdict["percentile"] >= 90.0
    assert not verdict["below_floor"]
    snap = comp.cache.metrics.snapshot()
    assert snap["audit_steps"] == 1.0
    assert snap["audit_baselines"] == 50.0
    assert snap["audit_below_floor"] == 0.0
    key = "audit_quality_percentile{arch=qwen1.5-0.5b@x4,kind=refined}"
    assert snap[key + ".count"] == 1
    assert snap[key + ".max_s"] == verdict["percentile"]
    # the verdict landed in the flight recorder too
    audits = [e for e in rec.events if e["kind"] == "audit"]
    assert len(audits) == 1
    assert audits[0]["percentile"] == verdict["percentile"]


def test_audit_dag_is_seeded_deterministic():
    n_params, triples, traced = _traced_step()
    def one():
        comp, _ = _refined_composer(n_params)
        rounds = comp.compose_dag(triples, traced)
        return comp.auditor.audit_dag(rounds, traced, arch="q",
                                      kind="refined")
    assert one() == one()


def test_audit_dag_skips_unmappable_rounds():
    """Rounds whose items don't map onto the traced graph (a sliced or
    foreign composition) are skipped with a reason counter, never
    scored against the wrong population."""
    n_params, triples, traced = _traced_step()
    comp, _ = _refined_composer(n_params)
    rounds = comp.compose_dag(triples, traced)
    # foreign kernel set: audit against a *different* step's graph
    _, _, other = _traced_step(reqs_spec=(("prefill", 48),
                                          ("decode", 192)))
    assert comp.auditor.audit_dag(rounds, other, arch="q",
                                  kind="refined") is None
    # partial composition: a dropped round leaves the graph uncovered
    assert comp.auditor.audit_dag(rounds[:-1], traced, arch="q",
                                  kind="refined") is None
    snap = comp.cache.metrics.snapshot()
    assert snap["audit_skipped{reason=sliced}"] == 1.0
    assert snap["audit_skipped{reason=partial}"] == 1.0
    assert snap["audit_steps"] == 0.0


def test_audit_flat_round_currency():
    from repro.serve import Composer, ScheduleCache, SchedulerPolicy
    from repro.core.tpu import decode_profile, prefill_profile

    pol = SchedulerPolicy(kind="symbiotic", audit_frac=1.0,
                          audit_k=40, audit_seed=3)
    comp = Composer(pol, _X4, 2 * 7e9, ScheduleCache())
    items = ([prefill_profile(f"p{i}", n_params=7e9, seq_len=512,
                              kv_bytes_per_token=131072.0)
              for i in range(2)]
             + [decode_profile(f"d{i}", n_params=7e9,
                               kv_len=256 * (i + 1),
                               kv_bytes_per_token=131072.0)
                for i in range(6)])
    triples = [(it, None, None) for it in items]
    rounds = comp.compose(triples)
    verdict = comp.auditor.audit_flat(rounds, weights_bytes=2 * 7e9,
                                      arch="flat", kind="symbiotic")
    assert verdict is not None
    assert verdict["currency"] == "round"
    assert verdict["k"] == 40
    assert 0.0 <= verdict["percentile"] <= 100.0
    assert comp.auditor.audit_flat([], weights_bytes=1.0, arch="f",
                                   kind="symbiotic") is None
    assert comp.cache.metrics.snapshot()[
        "audit_skipped{reason=empty}"] == 1.0


# --------------------------------------------------------------------------
# engine wiring: observers never change served tokens
# --------------------------------------------------------------------------

def _engine_run(policy_kw, *, metrics=None, recorder=None):
    jax = pytest.importorskip("jax")

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import Request, SchedulerPolicy, ServingEngine

    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_len=32,
                        policy=SchedulerPolicy(**policy_kw),
                        metrics=metrics, recorder=recorder)
    rng = np.random.default_rng(0)
    eng.submit([Request(i, rng.integers(0, 128, size=4),
                        max_new_tokens=3) for i in range(3)])
    return eng.run(arrivals=[(2, [Request(9,
                                          rng.integers(0, 128, size=4),
                                          max_new_tokens=2)])])


@pytest.mark.parametrize("kind,deps", [("symbiotic", True),
                                       ("symbiotic", False)])
def test_engine_tokens_bit_identical_with_audit_on(kind, deps):
    base = {"kind": kind, "respect_deps": deps}
    s_off = _engine_run(base)
    m, rec = MetricsRegistry(), FlightRecorder()
    s_on = _engine_run({**base, "audit_frac": 1.0, "audit_k": 8},
                       metrics=m, recorder=rec)
    assert s_on["outputs"] == s_off["outputs"]
    assert s_on["modelled_time_s"] == s_off["modelled_time_s"]
    snap = m.snapshot()
    assert snap["audit_steps"] >= 1.0
    assert snap["audit_baselines"] >= 8.0
    assert s_on["phases"]["audit"]["calls"] >= 1
    # the audit phase is excluded from the compose series
    assert s_on["phases"]["compose"]["calls"] >= \
        s_on["phases"]["audit"]["calls"]
    kinds = {e["kind"] for e in rec.events}
    assert "audit" in kinds and "schedule" in kinds


def test_engine_latency_block_and_drift_keys():
    stats = _engine_run({"kind": "symbiotic", "respect_deps": True})
    lat = stats["latency"]
    assert lat["completed"] == 4 and lat["in_flight"] == 0
    assert lat["p50_s"] > 0.0
    assert lat["p99_s"] >= lat["p95_s"] >= lat["p50_s"] > 0.0
    assert lat["max_s"] >= lat["p99_s"]
    assert lat["goodput_rps"] > 0.0
    assert lat["goodput_tokens_per_s"] > 0.0
    assert set(lat["phase_mean_s"]) == {"compose", "guard", "refine",
                                        "execute"}
    assert lat["phase_mean_s"]["compose"] > 0.0
    # drift EWMA rides on the cache stats per namespace
    drift = stats["schedule_cache"]["drift_ewma"]
    assert set(drift) == {"flat", "dag", "live"}
    snap = stats["metrics"]
    assert "request_latency_s.p50_s" in snap
    assert snap["requests_completed"] == 4.0


def test_warm_audit_frac_alias_still_feeds_legacy_keys():
    """The deprecated knob, now routed through the auditor: every warm
    hit is audited at frac=1.0 and the historical stats keys keep
    reporting."""
    stats = _engine_run({"kind": "symbiotic",
                         "warm_audit_frac": 1.0})
    cache = stats["schedule_cache"]
    assert cache["warm_hits"] >= 1
    assert cache["warm_sampled"] == cache["warm_hits"]
    assert isinstance(cache["warm_regret_mean"], float)


# --------------------------------------------------------------------------
# LatencyTracker / DriftMonitor units (injected clock: exact numbers)
# --------------------------------------------------------------------------

def test_latency_tracker_attribution_math():
    t = {"now": 0.0}
    lt = LatencyTracker(MetricsRegistry(), clock=lambda: t["now"])
    lt.arrive(1, t=0.0)
    lt.arrive(2, t=1.0)
    lt.attribute([1], {"compose": 0.5, "execute": 0.5}, t=2.0)
    lt.attribute([1, 2], {"compose": 1.0}, t=3.0)
    lt.complete(1, tokens=4, t=4.0)
    lt.complete(2, tokens=2, t=5.0)
    lt.complete(99, t=9.0)            # unknown rid: ignored
    st = lt.stats(wall_s=10.0)
    assert st["completed"] == 2 and st["in_flight"] == 0
    assert st["mean_s"] == pytest.approx((4.0 + 4.0) / 2)
    assert st["max_s"] == 4.0
    # queue spans: rid 1 first scheduled at 2.0, rid 2 at 3.0
    assert st["queue_p99_s"] == pytest.approx(2.0)
    # phase shares: rid 1 got 0.5 + 1.0/2 compose, rid 2 got 0.5
    assert st["phase_mean_s"]["compose"] == pytest.approx(0.75)
    assert st["phase_mean_s"]["execute"] == pytest.approx(0.25)
    assert st["goodput_rps"] == pytest.approx(0.2)
    assert st["goodput_tokens_per_s"] == pytest.approx(0.6)


def test_drift_monitor_ewma():
    m = MetricsRegistry()
    dm = DriftMonitor(m, alpha=0.5)
    assert dm.ewma("flat") == 0.0
    dm.observe("flat", -0.1)          # sign is dropped
    assert dm.ewma("flat") == pytest.approx(0.1)
    dm.observe("flat", 0.3)
    assert dm.ewma("flat") == pytest.approx(0.2)
    dm.observe("dag", 0.05)
    assert dm.ewma("dag") == pytest.approx(0.05)
    snap = m.snapshot()
    assert snap["replay_drift_ewma{namespace=flat}"] == \
        pytest.approx(0.2)
    assert snap["replay_drift{namespace=flat}.count"] == 2


# --------------------------------------------------------------------------
# rebuild reasons (live composition)
# --------------------------------------------------------------------------

def test_live_rebuild_reasons_are_counted():
    from repro.serve import (Composer, LiveComposition, ScheduleCache,
                             SchedulerPolicy)

    rec = FlightRecorder()
    n_params, triples, traced = _traced_step()
    pol = SchedulerPolicy(kind="symbiotic", respect_deps=True,
                          cache=False, composition="incremental")
    cache = ScheduleCache()
    comp = Composer(pol, _X4, 2.0 * n_params, cache, recorder=rec)
    live = LiveComposition(comp)
    live.compose_dag(triples, traced)
    # the first build is the seed: named in the flight recorder, but
    # deliberately NOT counted (frontier_rebuilds keeps its pre-PR 9
    # meaning of backstop-forced rebuilds only)
    rebuilds = [e for e in rec.events if e["kind"] == "rebuild"]
    assert rebuilds and rebuilds[0]["reason"] == "seed"
    assert rebuilds[0]["counted"] is False
    assert cache.frontier_rebuilds == 0
    # a backstop-forced rebuild is counted under its reason
    live._rebuild(triples, traced,
                  live._chain_view(triples, traced),
                  count=True, reason="capacity")
    snap = cache.metrics.snapshot()
    assert snap["frontier_rebuild_reason{reason=capacity}"] == 1.0
    assert rec.events[-1]["kind"] == "rebuild"
    assert rec.events[-1]["reason"] == "capacity"
    total = sum(v for k, v in snap.items()
                if k.startswith("frontier_rebuild_reason{"))
    assert total == snap["cache_frontier_rebuilds"] == 1.0
