"""Substrate tests: data determinism, checkpoint atomicity/resume,
optimizer behaviour, gradient compression, train-loop fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import BucketedBatcher, DataConfig, SyntheticLM
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress_int8,
                         decompress_int8, global_norm)
from repro.train import (LoopConfig, TrainLoop, latest_step,
                         restore_checkpoint, save_checkpoint)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_restorable():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=4)
    a = SyntheticLM(cfg)
    b1 = [a.next_batch() for _ in range(3)]
    state = a.state_dict()
    b2 = a.next_batch()
    # restore mid-stream on a "replacement host"
    c = SyntheticLM(cfg)
    c.load_state_dict(state)
    b2r = c.next_batch()
    np.testing.assert_array_equal(b2["inputs"], b2r["inputs"])
    # full determinism from scratch
    d = SyntheticLM(cfg)
    np.testing.assert_array_equal(b1[0]["inputs"],
                                  d.next_batch()["inputs"])


def test_data_host_sharding_disjoint_streams():
    k = dict(vocab=128, seq_len=16, global_batch=8, n_hosts=2)
    h0 = SyntheticLM(DataConfig(host_id=0, **k))
    h1 = SyntheticLM(DataConfig(host_id=1, **k))
    b0, b1 = h0.next_batch(), h1.next_batch()
    assert b0["inputs"].shape == (4, 16)
    assert not np.array_equal(b0["inputs"], b1["inputs"])


def test_bucketed_batcher():
    b = BucketedBatcher(buckets=(8, 16, 32))
    lengths = np.array([3, 9, 30, 33, 15])
    out = b.assign(lengths)
    assert list(out[8]) == [0]
    assert sorted(out[16]) == [1, 4]
    assert sorted(out[32]) == [2, 3]


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}   # d/dw of w^2
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_int8_compression_roundtrip():
    x = {"g": jnp.linspace(-3, 3, 100)}
    dec = decompress_int8(compress_int8(x))
    err = jnp.max(jnp.abs(dec["g"] - x["g"]))
    assert float(err) <= 3.0 / 127 + 1e-6


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    t = _tree()
    save_checkpoint(d, 7, t, extra={"step": 7})
    assert latest_step(d) == 7
    restored, extra = restore_checkpoint(d, t)
    assert extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_publish(tmp_path):
    """A torn tmp dir must not be visible as a checkpoint."""
    d = str(tmp_path)
    t = _tree()
    save_checkpoint(d, 1, t)
    os.makedirs(os.path.join(d, "step_00000002.tmp"))  # simulated crash
    assert latest_step(d) == 1
    restored, _ = restore_checkpoint(d, t)
    assert restored is not None


def test_checkpoint_gc_keeps_last(tmp_path):
    from repro.train import AsyncCheckpointer
    d = str(tmp_path)
    ck = AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(), extra={"step": s})
        ck.wait()
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


# --------------------------------------------------------------------------
# fault-tolerant loop
# --------------------------------------------------------------------------

def test_loop_retries_transient_failures(tmp_path):
    calls = {"n": 0}

    def flaky_step(params, opt_state, batch):
        calls["n"] += 1
        if calls["n"] == 2:           # one transient failure
            raise RuntimeError("simulated preemption")
        return params, opt_state, {"loss": jnp.float32(1.0)}

    data = SyntheticLM(DataConfig(vocab=16, seq_len=4, global_batch=2))
    loop = TrainLoop(step_fn=flaky_step, data=data,
                     cfg=LoopConfig(total_steps=3, ckpt_every=0,
                                    ckpt_dir=str(tmp_path),
                                    retry_backoff_s=0.0))
    p, o, hist = loop.run({}, {})
    assert len(hist) == 3
    assert calls["n"] == 4  # 3 successes + 1 retry


def test_loop_skips_nan_updates(tmp_path):
    step_count = {"n": 0}

    def nan_step(params, opt_state, batch):
        step_count["n"] += 1
        loss = jnp.float32(np.nan if step_count["n"] == 1 else 0.5)
        return {"w": params.get("w", 0) + 1}, opt_state, {"loss": loss}

    data = SyntheticLM(DataConfig(vocab=16, seq_len=4, global_batch=2))
    loop = TrainLoop(step_fn=nan_step, data=data,
                     cfg=LoopConfig(total_steps=2, ckpt_every=0,
                                    ckpt_dir=str(tmp_path)))
    p, o, hist = loop.run({"w": 0}, {})
    assert loop.nan_skips == 1
    assert len(hist) == 1  # the NaN update was discarded


# --------------------------------------------------------------------------
# compute/comm overlap scheduling
# --------------------------------------------------------------------------

def test_overlap_schedule_interleaves_and_reduces_exposed_comm():
    from repro.train.overlap import (CommTask, ComputeTask,
                                     exposed_comm_time, overlap_schedule)
    # realistic magnitudes: one layer's backward ~4e12 FLOPs vs a
    # ~1 GB gradient bucket — combined intensity sits near R_B
    tasks = [ComputeTask(f"c{i}", 4e12) for i in range(4)] + \
            [CommTask(f"g{i}", 1e9) for i in range(4)]
    naive = [t.name for t in tasks]           # all compute then all comm
    sched = overlap_schedule(tasks)
    assert sorted(sched) == sorted(naive)
    t_naive = exposed_comm_time(naive, tasks)
    t_sched = exposed_comm_time(sched, tasks)
    assert t_sched < t_naive * 0.8            # overlap hides >=20%
