"""Property tests for the batched candidate-evaluation path
(:mod:`repro.core.batched`) and the admission/completion scan kernel
(:mod:`repro.kernels.event_scan`).

The contracts pinned here are the ones the refiner relies on:

* the batched round engine is **bit-exact** against ``_FastRoundSim``
  (fresh starts and checkpoint-stitched resumes alike);
* the batched event/gated engines agree with the sequential delta
  evaluators within ``EVENT_TIME_RTOL`` (pure summation-order noise);
* the f32 scan kernel (``jit(vmap)`` and Pallas interpret dispatch)
  agrees with ``_FastEventSim`` within ``F32_EVENT_RTOL``, including
  the degenerate oversized-block drain path;
* ``batch_size=`` routing through :func:`repro.core.refine.refine_order`
  / :func:`repro.graph.refine_order_dag` returns legal permutations
  never modelled-worse than their input, and the greedy + refine
  pipeline packs its :class:`ProfileTable` exactly once.

Written with plain ``random`` (no hypothesis dependency in the pinned
toolchain) over seeded draws, so failures reproduce exactly.
"""

import random

import numpy as np
import pytest

from repro.core import GTX580, KernelProfile
from repro.core.batched import (EVENT_TIME_RTOL, HAS_JAX, BatchedEventSim,
                                BatchedRoundSim, PackedKernels,
                                audit_pair_scores, pair_score_matrix_batched,
                                refine_order_batched)
from repro.core.fastscore import ProfileTable, greedy_order_fast
from repro.core.refine import (DeltaEvaluator, _apply, _FastEventSim,
                               _FastRoundSim, _moves, refine_order,
                               refined_schedule)
from repro.core.resources import bs_kernel, ep_kernel, es_kernel, sw_kernel
from repro.graph.constrained import refine_order_dag
from repro.graph.delta import GatedDeltaEvaluator
from repro.kernels import event_scan

_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]


def _gpu_kernels(rng: random.Random, n: int) -> list[KernelProfile]:
    return [rng.choice(_FAMS)(f"k{i}",
                              grid=rng.choice([8, 16, 32, 48, 64, 96]),
                              shm=rng.choice([0, 4096, 8192, 16384, 24576]),
                              inst=rng.uniform(1e6, 5e8))
            for i in range(n)]


def _oversized(rng: random.Random, n: int) -> list[KernelProfile]:
    """Profiles whose blocks exceed device capacity in some dimension,
    forcing the simulator's degenerate solo-drain path — the branch the
    scan kernel implements as ``passes * t1``."""
    ks = []
    for i in range(n):
        if rng.random() < 0.5:
            dem = {"shm": rng.choice([49152.0, 96000.0]),
                   "reg": rng.uniform(100, 3000.0), "warp": 4.0}
        else:
            dem = {"shm": rng.choice([0.0, 8192.0]),
                   "reg": rng.uniform(512, 8192.0),
                   "warp": float(rng.choice([1, 4, 8, 16]))}
        ks.append(KernelProfile(
            f"a{i}", n_blocks=rng.choice([1, 3, 7, 17, 33]),
            demands=dem, inst_per_block=rng.uniform(1e2, 1e9),
            r=rng.choice([1e-6, 0.5, 4.0, 1e6])))
    return ks


def _chain_edges(rng: random.Random, n: int,
                 width: int) -> set[tuple[int, int]]:
    """Layered DAG over indices 0..n-1 (index order is topological):
    each node depends on 1-2 nodes from the previous layer."""
    edges: set[tuple[int, int]] = set()
    for v in range(width, n):
        layer_lo = max(0, v - 2 * width)
        for _ in range(rng.choice([1, 2])):
            u = rng.randrange(layer_lo, v)
            edges.add((u, v))
    return edges


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(b), 1e-30)


# ---------------------------------------------------------------------------
# batched engines vs sequential references — fresh starts
# ---------------------------------------------------------------------------

def test_batched_round_fresh_is_bit_exact():
    for trial in range(8):
        rng = random.Random(100 + trial)
        ks = _gpu_kernels(rng, rng.choice([8, 16, 24, 40]))
        pk = PackedKernels.for_table(ProfileTable.build(ks, GTX580))
        orders = []
        for b in range(5):
            o = list(ks)
            random.Random(trial * 10 + b).shuffle(o)
            orders.append(o)
        rows = np.stack([pk.rows(o) for o in orders])
        tb = BatchedRoundSim(pk).times_from_checkpoints(
            rows, [None] * len(orders))
        sim = _FastRoundSim(GTX580)
        for b, o in enumerate(orders):
            assert tb[b] == sim.simulate(o)[0]


def test_batched_event_fresh_within_rtol():
    for trial in range(8):
        rng = random.Random(200 + trial)
        ks = _gpu_kernels(rng, rng.choice([8, 16, 24, 40]))
        pk = PackedKernels.for_table(ProfileTable.build(ks, GTX580))
        orders = []
        for b in range(5):
            o = list(ks)
            random.Random(trial * 10 + b).shuffle(o)
            orders.append(o)
        rows = np.stack([pk.rows(o) for o in orders])
        tb = BatchedEventSim(pk).times(rows, [None] * len(orders))
        sim = _FastEventSim(GTX580)
        for b, o in enumerate(orders):
            assert _rel(tb[b], sim.simulate(o)[0]) <= EVENT_TIME_RTOL


def test_batched_event_oversized_blocks_fresh():
    for trial in range(4):
        rng = random.Random(300 + trial)
        ks = _oversized(rng, 16)
        pk = PackedKernels.for_table(ProfileTable.build(ks, GTX580))
        orders = []
        for b in range(4):
            o = list(ks)
            random.Random(trial * 10 + b).shuffle(o)
            orders.append(o)
        rows = np.stack([pk.rows(o) for o in orders])
        tb = BatchedEventSim(pk).times(rows, [None] * len(orders))
        sim = _FastEventSim(GTX580)
        for b, o in enumerate(orders):
            assert _rel(tb[b], sim.simulate(o)[0]) <= EVENT_TIME_RTOL


# ---------------------------------------------------------------------------
# batched engines vs the union of sequential delta evaluations —
# checkpoint-stitched resumes (the refiner's actual workload)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["round", "event", "gated"])
def test_batched_resume_equals_sequential_delta(model):
    for trial in range(5):
        rng = random.Random(400 + trial)
        n = rng.choice([16, 24, 32])
        ks = _gpu_kernels(rng, n)
        edge_ids = None
        if model == "gated":
            edges = _chain_edges(rng, n, width=max(4, n // 8))
            edge_ids = {(id(ks[u]), id(ks[v])) for u, v in edges}
            delta = GatedDeltaEvaluator(GTX580, edge_ids)
            base = list(ks)  # index order is topological
        else:
            delta = DeltaEvaluator(GTX580, model=model)
            base = list(ks)
            random.Random(trial).shuffle(base)
        delta.rebase(base)
        pk = PackedKernels.for_table(ProfileTable.build(ks, GTX580))
        if model == "round":
            bsim = BatchedRoundSim(pk)
        else:
            bsim = BatchedEventSim(pk, edge_ids)
        cands, firsts = [], []
        for first, kind, i, j in _moves(n, "adjacent")[:20]:
            cand = _apply(base, kind, i, j)
            if model == "gated" and not delta.legal(cand):
                continue
            cands.append(cand)
            firsts.append(first)
        assert cands, "neighborhood produced no (legal) candidates"
        rows = np.stack([pk.rows(c) for c in cands])
        cps = []
        for first in firsts:
            if model == "round":
                cp = None
                for c in delta._ckpts:
                    if c.pos < first and (cp is None or c.pos > cp.pos):
                        cp = c
                cps.append(cp)
            else:
                cps.append(delta._ckpts[first])
        if model == "round":
            tb = bsim.times_from_checkpoints(rows, cps)
        else:
            tb = bsim.times(rows, cps)
        for b, cand in enumerate(cands):
            tr, _ = delta.evaluate_costed(cand, firsts[b])
            if model == "round":
                assert tb[b] == tr
            else:
                assert _rel(tb[b], tr) <= EVENT_TIME_RTOL


# ---------------------------------------------------------------------------
# f32 pair-score matrix
# ---------------------------------------------------------------------------

def test_audit_pair_scores_numpy_backend():
    rng = random.Random(11)
    table = ProfileTable.build(_gpu_kernels(rng, 48), GTX580)
    audit = audit_pair_scores(table, backend="numpy")
    assert audit["within_tol"], audit


@pytest.mark.skipif(not HAS_JAX, reason="jax unavailable")
def test_audit_pair_scores_jax_backend():
    rng = random.Random(12)
    table = ProfileTable.build(_gpu_kernels(rng, 48), GTX580)
    audit = audit_pair_scores(table, backend="jax")
    assert audit["within_tol"], audit
    # both f32 backends run the same arithmetic — they agree far more
    # tightly with each other than either does with the f64 reference
    a = pair_score_matrix_batched(table, backend="numpy")
    b = pair_score_matrix_batched(table, backend="jax")
    scale = max(float(np.max(np.abs(a))), 1.0)
    assert float(np.max(np.abs(a - b))) <= 1e-6 * scale


# ---------------------------------------------------------------------------
# the admission/completion scan kernel (repro.kernels.event_scan)
# ---------------------------------------------------------------------------

def _scan_rows(rng: random.Random, table, B: int) -> np.ndarray:
    n = len(table.kernels)
    rows = []
    for _ in range(B):
        perm = list(range(n))
        rng.shuffle(perm)
        rows.append(perm)
    return np.asarray(rows, dtype=np.int32)


@pytest.mark.skipif(not event_scan.HAS_JAX, reason="jax unavailable")
@pytest.mark.parametrize("dispatch", ["jax", "pallas"])
def test_event_scan_matches_fast_event_sim(dispatch):
    for trial in range(4):
        rng = random.Random(500 + trial)
        table = ProfileTable.build(
            _gpu_kernels(rng, rng.choice([8, 16, 24])), GTX580)
        rows = _scan_rows(rng, table, B=6)
        if dispatch == "jax":
            got = event_scan.event_times_jax(rows, table)
        else:
            got = event_scan.event_times_pallas(rows, table,
                                                interpret=True)
        ref = event_scan.event_times_reference(rows, table)
        for b in range(rows.shape[0]):
            assert _rel(float(got[b]), float(ref[b])) \
                <= event_scan.F32_EVENT_RTOL


@pytest.mark.skipif(not event_scan.HAS_JAX, reason="jax unavailable")
@pytest.mark.parametrize("dispatch", ["jax", "pallas"])
def test_event_scan_oversized_blocks(dispatch):
    """Adversarial profiles: per-block demands above device caps drive
    the scan through its ``passes * t1`` solo-drain branch."""
    for trial in range(3):
        rng = random.Random(600 + trial)
        table = ProfileTable.build(_oversized(rng, 12), GTX580)
        rows = _scan_rows(rng, table, B=4)
        if dispatch == "jax":
            got = event_scan.event_times_jax(rows, table)
        else:
            got = event_scan.event_times_pallas(rows, table,
                                                interpret=True)
        ref = event_scan.event_times_reference(rows, table)
        for b in range(rows.shape[0]):
            assert _rel(float(got[b]), float(ref[b])) \
                <= event_scan.F32_EVENT_RTOL


@pytest.mark.requires_jax_device
def test_event_scan_compiled_pallas():
    """The compiled (non-interpret) Pallas dispatch — only meaningful
    on a real accelerator backend; CPU runners skip via conftest."""
    rng = random.Random(7)
    table = ProfileTable.build(_gpu_kernels(rng, 16), GTX580)
    rows = _scan_rows(rng, table, B=4)
    got = event_scan.event_times_pallas(rows, table, interpret=False)
    ref = event_scan.event_times_reference(rows, table)
    for b in range(rows.shape[0]):
        assert _rel(float(got[b]), float(ref[b])) \
            <= event_scan.F32_EVENT_RTOL


# ---------------------------------------------------------------------------
# batch_size routing through the public refiners
# ---------------------------------------------------------------------------

def test_refine_order_batched_never_worse_and_permutation():
    for model in ("round", "event"):
        rng = random.Random(21)
        ks = _gpu_kernels(rng, 32)
        base = greedy_order_fast(ks, GTX580).order
        t0 = DeltaEvaluator(GTX580, model=model).rebase(base)
        out, t, evals = refine_order(base, GTX580, model=model,
                                     budget=40, neighborhood="adjacent",
                                     batch_size=16)
        assert t <= t0 + 1e-12
        assert sorted(id(k) for k in out) == sorted(id(k) for k in base)
        assert evals >= 1


def test_refine_order_batched_matches_currency():
    """The returned time is the *sequential* simulator's own currency
    for the returned order (acceptances are exactly re-verified)."""
    rng = random.Random(22)
    ks = _gpu_kernels(rng, 24)
    base = greedy_order_fast(ks, GTX580).order
    out, t, _ = refine_order(base, GTX580, model="event", budget=40,
                             neighborhood="adjacent", batch_size=16)
    assert _FastEventSim(GTX580).simulate(out)[0] == pytest.approx(
        t, rel=1e-12)


def test_refine_order_dag_batched_gated_legal_and_no_worse():
    rng = random.Random(23)
    n = 24
    ks = _gpu_kernels(rng, n)
    edges = _chain_edges(rng, n, width=max(4, n // 8))
    edge_ids = {(id(ks[u]), id(ks[v])) for u, v in edges}
    base = list(ks)  # topological by construction
    t0 = GatedDeltaEvaluator(GTX580, edge_ids).rebase(base)
    out, t, _ = refine_order_dag(base, GTX580, edge_ids=edge_ids,
                                 model="gated", budget=30,
                                 neighborhood="adjacent", batch_size=16)
    assert t <= t0 + 1e-12
    pos = {id(k): i for i, k in enumerate(out)}
    for u, v in edge_ids:
        assert pos[u] < pos[v]


def test_batched_gated_parity_with_sequential_refiner():
    """The ISSUE-6 quality pin: under the default gated contract
    (``rescore`` on), the batched walk re-scores the chunk remainder
    after every acceptance and therefore retraces the sequential
    first-improving sweep wherever the engine classifies
    improving/non-improving correctly — refined makespans match the
    *sequential refiner's*, not just the input order's."""
    from repro.core.tpu import (decode_profile, make_serving_device,
                                prefill_profile)
    from repro.graph.constrained import greedy_order_dag

    dev = make_serving_device(n_units=4)
    exact = 0
    for seed in range(6):
        rng = random.Random(seed)
        n = 40
        ks = []
        for i in range(n):
            if rng.random() < 0.3:
                it = prefill_profile(
                    f"p{i}", n_params=7e9,
                    seq_len=rng.choice([128, 256, 512, 1024]),
                    kv_bytes_per_token=131072)
            else:
                it = decode_profile(
                    f"d{i}", n_params=7e9,
                    kv_len=rng.randint(64, 8192),
                    kv_bytes_per_token=131072)
            ks.append(it.profile())
        edges: set[tuple[int, int]] = set()
        chains: list[list[int]] = [[] for _ in range(6)]
        for i in range(n):
            c = chains[rng.randrange(6)]
            if c:
                edges.add((c[-1], i))
            c.append(i)
        eids = {(id(ks[u]), id(ks[v])) for u, v in edges}
        order = greedy_order_dag(ks, dev, edges=edges).order
        _, t_seq, _ = refine_order_dag(
            order, dev, edge_ids=eids, model="gated", budget=10,
            neighborhood="adjacent")
        _, t_bat, _ = refine_order_dag(
            order, dev, edge_ids=eids, model="gated", budget=10,
            neighborhood="adjacent", batch_size=32)
        assert t_bat <= t_seq * (1 + 1e-9)
        exact += t_bat == t_seq
        # the fast contract (rescore off) only pins to the input:
        t0 = GatedDeltaEvaluator(dev, eids).rebase(list(order))
        _, t_fast, _ = refine_order_dag(
            order, dev, edge_ids=eids, model="gated", budget=10,
            neighborhood="adjacent", batch_size=32, rescore=False)
        assert t_fast <= t0 + 1e-12
    # most trajectories retrace the sequential one bit-for-bit
    assert exact >= 3


def test_refined_schedule_packs_profile_table_once(monkeypatch):
    rng = random.Random(24)
    ks = _gpu_kernels(rng, 24)
    builds = []
    real_build = ProfileTable.build.__func__

    def counting_build(cls, kernels, device):
        builds.append(len(kernels))
        return real_build(cls, kernels, device)

    monkeypatch.setattr(ProfileTable, "build",
                        classmethod(counting_build))
    refined_schedule(ks, GTX580, budget=20, neighborhood="adjacent",
                     batch_size=16)
    assert builds == [len(ks)]


def test_refine_order_batch_size_rejected_with_custom_time_fn():
    rng = random.Random(25)
    ks = _gpu_kernels(rng, 8)
    # custom time_fn has no batched counterpart: routing must not
    # engage (documented contract — falls back to sequential).
    out, t, _ = refine_order(ks, GTX580,
                             time_fn=lambda o: float(len(o)),
                             budget=5, batch_size=8)
    assert t == float(len(ks))


# ---------------------------------------------------------------------------
# slow sweep
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_batched_event_n1024_sweep():
    rng = random.Random(31)
    ks = _gpu_kernels(rng, 1024)
    pk = PackedKernels.for_table(ProfileTable.build(ks, GTX580))
    orders = []
    for b in range(3):
        o = list(ks)
        random.Random(b).shuffle(o)
        orders.append(o)
    rows = np.stack([pk.rows(o) for o in orders])
    tb = BatchedEventSim(pk).times(rows, [None] * len(orders))
    sim = _FastEventSim(GTX580)
    for b, o in enumerate(orders):
        assert _rel(tb[b], sim.simulate(o)[0]) <= EVENT_TIME_RTOL
