"""Property tests for the DAG scheduling subsystem (repro.graph):

* degenerate DAG: ``greedy_order_dag`` with an empty edge set is
  round-for-round identical to ``greedy_order_fast`` (the ISSUE-3
  acceptance pin), and ``DagEventSimulator`` with no edges is
  float-for-float equal to the reference ``EventSimulator``;
* every order emitted by the constrained greedy, the precedence-
  respecting refiner and the random-topological sampler is a valid
  topological order under randomized DAGs;
* ``trace_arch`` structure: per-request chains, cross-request
  independence, parameter-share normalisation;
* stream assignment partitions the schedule and pins chains;
* the gated simulator orders dependent work strictly after its
  predecessors (monotone vs the ungated bound) and rejects
  non-topological launch orders.

Plain ``random`` over seeded draws (no hypothesis in the pinned
toolchain), as in ``tests/test_fastscore.py``.
"""

import random

import pytest

from repro.configs import get_config
from repro.core import GTX580, EventSimulator, greedy_order_fast
from repro.core.resources import bs_kernel, ep_kernel, es_kernel, sw_kernel
from repro.core.tpu import (decode_profile, make_serving_device,
                            prefill_profile)
from repro.graph import (DagEventSimulator, KernelGraph, assign_streams,
                         fifo_rounds_dag, greedy_order_dag,
                         refine_order_dag, trace_arch)

_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]
_TPU = make_serving_device()


def _gpu_kernels(rng: random.Random, n: int):
    return [rng.choice(_FAMS)(f"k{i}",
                              grid=rng.choice([8, 16, 32, 48, 64, 96]),
                              shm=rng.choice([0, 4096, 8192, 16384, 24576]),
                              inst=rng.uniform(1e6, 5e8))
            for i in range(n)]


def _tpu_profiles(rng: random.Random, n: int):
    items = []
    for i in range(n):
        if rng.random() < 0.4:
            items.append(prefill_profile(
                f"p{i}", n_params=7e9,
                seq_len=rng.choice([128, 256, 512, 1024]),
                kv_bytes_per_token=131072))
        else:
            items.append(decode_profile(
                f"d{i}", n_params=7e9, kv_len=rng.randint(1, 8192),
                kv_bytes_per_token=131072))
    return [it.profile() for it in items]


def _random_dag_edges(rng: random.Random, n: int,
                      density: float = 1.0) -> set:
    """Random forward edges (u < v): acyclic by construction."""
    edges = set()
    for _ in range(int(density * n)):
        u, v = rng.randrange(n), rng.randrange(n)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return edges


def _round_names(sched):
    return [rd.names for rd in sched.rounds]


# --------------------------------------------------------------------------
# degenerate DAG == unconstrained fast path
# --------------------------------------------------------------------------

def test_zero_edge_dag_reproduces_fast_greedy():
    """ISSUE-3 acceptance pin: >= 40 randomized kernel sets across
    both device families, empty edge set, identical rounds AND
    intra-round order."""
    rng = random.Random(42)
    checked = 0
    for trial in range(50):
        if trial % 2 == 0:
            ks, dev = _gpu_kernels(rng, rng.randint(1, 24)), GTX580
        else:
            ks, dev = _tpu_profiles(rng, rng.randint(1, 32)), _TPU
        ref = _round_names(greedy_order_fast(ks, dev))
        dag = _round_names(greedy_order_dag(ks, dev))
        assert ref == dag, f"trial {trial}: {ref} != {dag}"
        checked += 1
    assert checked >= 40


def test_zero_edge_gated_simulator_is_exact():
    """DagEventSimulator with no edges replays EventSimulator's float
    accumulation exactly."""
    rng = random.Random(7)
    for _ in range(20):
        ks = _gpu_kernels(rng, rng.randint(2, 16))
        t_ref = EventSimulator(GTX580).simulate(ks)
        t_dag = DagEventSimulator(GTX580, set()).simulate(ks)
        assert t_dag == t_ref


def test_empty_and_singleton_graphs():
    assert greedy_order_dag([], GTX580).rounds == []
    k = ep_kernel("only")
    assert _round_names(greedy_order_dag([k], GTX580)) == [["only"]]
    g = KernelGraph([k])
    g.validate()
    assert g.is_topological([k])


# --------------------------------------------------------------------------
# topological validity under random DAGs
# --------------------------------------------------------------------------

def test_dag_greedy_emits_topological_orders():
    rng = random.Random(3)
    for trial in range(40):
        n = rng.randint(2, 28)
        ks = _gpu_kernels(rng, n)
        edges = _random_dag_edges(rng, n, density=rng.uniform(0.0, 2.0))
        g = KernelGraph(ks, edges)
        g.validate()
        sched = greedy_order_dag(ks, GTX580, edges=edges)
        assert g.is_topological(sched.order), trial
        # no round may contain both ends of an edge (members run
        # concurrently; a dependent kernel waits for the next round)
        eids = g.edges_by_id()
        for rd in sched.rounds:
            ids = [id(k) for k in rd.kernels]
            assert not any((a, b) in eids for a in ids for b in ids)


def test_random_topological_orders_are_topological():
    rng = random.Random(11)
    for _ in range(10):
        n = rng.randint(3, 20)
        g = KernelGraph(_gpu_kernels(rng, n),
                        _random_dag_edges(rng, n, 1.5))
        for o in g.random_topological_orders(10, seed=rng.randrange(99)):
            assert g.is_topological(o)


def test_cycle_detection():
    ks = _gpu_kernels(random.Random(0), 3)
    g = KernelGraph(ks, {(0, 1), (1, 2)})
    g.validate()
    g.add_edge(2, 0)
    with pytest.raises(ValueError):
        g.validate()
    with pytest.raises(ValueError):
        greedy_order_dag(ks, GTX580, edges={(0, 1), (1, 2), (2, 0)})
    with pytest.raises(ValueError):
        g.random_topological_order(random.Random(0))


def test_refine_order_dag_stays_topological_and_no_worse():
    rng = random.Random(9)
    for _ in range(10):
        n = rng.randint(4, 16)
        ks = _gpu_kernels(rng, n)
        edges = _random_dag_edges(rng, n, 1.0)
        g = KernelGraph(ks, edges)
        sched = greedy_order_dag(ks, GTX580, edges=edges)
        from repro.core import simulate
        t0 = simulate(sched.order, GTX580, model="event")
        order, t, _ = refine_order_dag(sched.order, GTX580,
                                       edge_ids=g.edges_by_id(),
                                       budget=60, model="event",
                                       neighborhood="adjacent")
        assert g.is_topological(order)
        assert t <= t0 + 1e-15
        assert t == simulate(order, GTX580, model="event")


def test_refine_order_dag_rejects_illegal_input():
    ks = _gpu_kernels(random.Random(1), 4)
    with pytest.raises(ValueError):
        refine_order_dag([ks[1], ks[0], ks[2], ks[3]], GTX580,
                         edges={(0, 1)},
                         edge_ids={(id(ks[0]), id(ks[1]))})


# --------------------------------------------------------------------------
# gated simulator semantics
# --------------------------------------------------------------------------

def test_gated_simulator_serializes_a_full_chain():
    """A single dependency chain admits one kernel at a time, so the
    gated makespan is the sum of the kernels' solo event times (up to
    float re-association of the running clock).  Note the gate is NOT
    monotone versus the ungated dispatcher in general — delaying an
    admission changes co-residency and occupancy, which can help or
    hurt (the paper's order-sensitivity), so only full serialization
    has a closed form to pin."""
    rng = random.Random(13)
    sim = EventSimulator(GTX580)
    for _ in range(10):
        n = rng.randint(2, 10)
        ks = _gpu_kernels(rng, n)
        edges = {(i, i + 1) for i in range(n - 1)}
        g = KernelGraph(ks, edges)
        t_gated = DagEventSimulator(GTX580, g.edges_by_id()).simulate(ks)
        t_solo = sum(sim.simulate([k]) for k in ks)
        assert t_gated == pytest.approx(t_solo, rel=1e-9)


def test_gated_simulator_rejects_non_topological_order():
    ks = _gpu_kernels(random.Random(2), 2)
    sim = DagEventSimulator(GTX580, {(id(ks[0]), id(ks[1]))})
    with pytest.raises(ValueError):
        sim.simulate([ks[1], ks[0]])


def test_fifo_rounds_dag_respects_edges():
    rng = random.Random(17)
    for _ in range(10):
        n = rng.randint(3, 20)
        ks = _gpu_kernels(rng, n)
        edges = _random_dag_edges(rng, n, 1.0)
        g = KernelGraph(ks, edges)
        order = g.random_topological_order(rng)
        rounds = fifo_rounds_dag(order, GTX580, g.edges_by_id(),
                                 demands_of=lambda k: k.demands)
        assert [k for rd in rounds for k in rd] == order
        done: set[int] = set()
        for rd in rounds:
            ids = {id(k) for k in rd}
            for u, v in g.edges_by_id():
                if v in ids:
                    assert u in done, "pred must retire in an earlier round"
                    assert u not in ids
            done |= ids


# --------------------------------------------------------------------------
# trace_arch structure
# --------------------------------------------------------------------------

def test_trace_arch_chains_and_independence():
    cfg = get_config("qwen1.5-0.5b", "smoke")
    reqs = [("prefill", 128), ("decode", 512), ("decode", 1024)]
    tw = trace_arch(cfg, reqs)
    tw.graph.validate()
    # every request owns one chain: len(chain)-1 edges, no cross edges
    per_req: dict[int, list[int]] = {}
    for i, o in enumerate(tw.owners):
        per_req.setdefault(o, []).append(i)
    n_edges = sum(len(v) - 1 for v in per_req.values())
    assert len(tw.graph.edges) == n_edges
    for u, v in tw.graph.edges:
        assert tw.owners[u] == tw.owners[v]
        assert u < v
    # tail items close their chains
    for rid, idxs in per_req.items():
        assert tw.tail_of[rid] == max(idxs)
    # attention stages carry the KV traffic, ffn stages don't
    for it in tw.items:
        if ":attn" in it.name:
            assert it.hbm_bytes > 0.0 or ":p:" not in it.name


def test_trace_arch_param_share_normalisation():
    cfg = get_config("mixtral-8x7b", "smoke")
    n_params = 1e9
    tw = trace_arch(cfg, [("prefill", 64)], n_params=n_params)
    # prefill touches the full expert banks: shares sum to the model
    # minus the (untraced) embedding tables
    flops_total = sum(it.flops for it in tw.items)
    assert flops_total < 2.0 * n_params * 64
    assert flops_total > 0.5 * 2.0 * n_params * 64
    # decode streams only routed-active experts: strictly fewer flops
    twd = trace_arch(cfg, [("decode", 64)], n_params=n_params)
    moe_p = [it for it in tw.items if ":moe" in it.name]
    moe_d = [it for it in twd.items if ":moe" in it.name]
    assert moe_p and moe_d
    assert (sum(it.flops for it in moe_d) <
            sum(it.flops for it in moe_p) / 64 * 1.01)


def test_trace_arch_max_stages_coarsening():
    cfg = get_config("qwen1.5-0.5b", "full")   # 24 layers -> 48 stages
    fine = trace_arch(cfg, [("decode", 256)])
    coarse = trace_arch(cfg, [("decode", 256)], max_stages=6)
    assert len(coarse.items) <= 6 < len(fine.items)
    # grouping preserves total work and traffic
    assert sum(i.flops for i in coarse.items) == pytest.approx(
        sum(i.flops for i in fine.items), rel=1e-9)
    assert sum(i.hbm_bytes for i in coarse.items) == pytest.approx(
        sum(i.hbm_bytes for i in fine.items), rel=1e-9)
    coarse.graph.validate()


# --------------------------------------------------------------------------
# stream assignment
# --------------------------------------------------------------------------

def test_assign_streams_partitions_and_pins_chains():
    cfg = get_config("qwen1.5-0.5b", "smoke")
    tw = trace_arch(cfg)
    g = tw.graph
    sched = greedy_order_dag(g.kernels, _TPU, edges=g.edges)
    sa = assign_streams(sched, g.edges_by_id(), k=3)
    # partition: every kernel on exactly one queue
    all_ids = sorted(id(k) for s in sa.streams for k in s)
    assert all_ids == sorted(id(k) for k in g.kernels)
    # chains pin: both ends of every edge share a queue
    for u, v in g.edges:
        assert (sa.stream_of[id(g.kernels[u])]
                == sa.stream_of[id(g.kernels[v])])
    # independent roots spread: >1 queue used when k > 1
    assert len({sa.stream_of[id(k)] for k in g.kernels}) > 1
    with pytest.raises(ValueError):
        assign_streams(sched, g.edges_by_id(), k=0)


# --------------------------------------------------------------------------
# slow sweep (ISSUE-3 CI satellite)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_dag_greedy_n512_sweep():
    """n=512 chain-structured DAG: construction completes, emits a
    valid topological order, and the 0-edge variant still matches the
    flat fast path at this scale."""
    rng = random.Random(29)
    ks = _gpu_kernels(rng, 512)
    edges = set()
    chains: list[list[int]] = [[] for _ in range(64)]
    for i in range(512):
        c = chains[rng.randrange(64)]
        if c:
            edges.add((c[-1], i))
        c.append(i)
    g = KernelGraph(ks, edges)
    sched = greedy_order_dag(ks, GTX580, edges=edges)
    assert g.is_topological(sched.order)
    assert _round_names(greedy_order_dag(ks, GTX580)) == \
        _round_names(greedy_order_fast(ks, GTX580))
