"""Property tests for the kernel slicing subsystem (repro.slice):

* slice-factor-1 identity: with no policy (or one that never
  triggers) the sliced pipeline reproduces the unsliced DAG pipeline
  bit-for-bit — same rounds, same order, identical gated makespan;
* slice-profile conservation: slices sum back to the parent (work,
  traffic, demand mass, tokens) within float tolerance, while the
  stage weight stream is copied, not split;
* topological validity of slice/join expansion under random DAGs:
  slices inherit in-edges, successors hang off the join, sibling
  slices stay mutually independent, and every emitted order is
  topological;
* sliced makespan <= unsliced makespan on saturating (oversized-slot)
  profiles in the gated simulator;
* zero-work join markers retire instantly in ``DagEventSimulator``;
* serving: generated tokens are bit-identical with ``slice_policy``
  on or off, and the DAG-path ScheduleCache warms up.

Plain ``random`` over seeded draws (no hypothesis in the pinned
toolchain), as in ``tests/test_fastscore.py`` / ``tests/test_graph.py``.
"""

import random
from dataclasses import replace

import pytest

from repro.core import GTX580
from repro.core.resources import bs_kernel, ep_kernel, es_kernel, sw_kernel
from repro.core.tpu import (decode_profile, make_serving_device,
                            prefill_profile)
from repro.graph import DagEventSimulator, KernelGraph, greedy_order_dag
from repro.slice import (KernelSlicer, SlicePolicy, coalesce_rounds,
                         expand_nodes, greedy_order_slices, is_join,
                         is_slice, join_item, join_profile,
                         merge_slice_profiles, parent_name,
                         refine_order_slices, slice_indices)

_TPU = make_serving_device()
_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]


def _tpu_items(rng: random.Random, n: int, *, oversized_frac=0.25):
    items = []
    for i in range(n):
        if rng.random() < oversized_frac:
            items.append(prefill_profile(
                f"r{i}:p:L0:attn", n_params=7e9,
                seq_len=rng.choice([6144, 8192, 12288]),
                kv_bytes_per_token=131072))
        else:
            items.append(decode_profile(
                f"r{i}:d:L0:attn", n_params=7e9,
                kv_len=rng.randint(256, 8192),
                kv_bytes_per_token=131072))
    return items


def _gpu_kernels(rng: random.Random, n: int):
    return [rng.choice(_FAMS)(f"k{i}",
                              grid=rng.choice([8, 16, 32, 48, 64, 96]),
                              shm=rng.choice([0, 4096, 8192, 16384]),
                              inst=rng.uniform(1e6, 5e8))
            for i in range(n)]


def _random_dag_edges(rng: random.Random, n: int, density=1.0) -> set:
    edges = set()
    for _ in range(int(density * n)):
        u, v = rng.randrange(n), rng.randrange(n)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return edges


def _round_names(sched):
    return [rd.names for rd in sched.rounds]


# --------------------------------------------------------------------------
# naming / policy
# --------------------------------------------------------------------------

def test_name_helpers():
    assert parent_name("r0:p:L3:moe#s1of4") == "r0:p:L3:moe"
    assert parent_name("r0:p:L3:moe#join") == "r0:p:L3:moe"
    assert parent_name("r0:p:L3:moe") == "r0:p:L3:moe"
    assert is_slice("a#s0of2") and not is_slice("a#join")
    assert is_join("a#join") and not is_join("a#s0of2")


def test_policy_validation():
    with pytest.raises(ValueError):
        SlicePolicy(mode="nope")
    with pytest.raises(ValueError):
        SlicePolicy(target_fill=0.0)
    with pytest.raises(ValueError):
        SlicePolicy(fixed_k=0)


def test_slice_count_modes():
    sl_occ = KernelSlicer(SlicePolicy(), _TPU)
    sl_fill = KernelSlicer(SlicePolicy(mode="round_fill",
                                       target_fill=0.5), _TPU)
    sl_fix = KernelSlicer(SlicePolicy(mode="fixed", fixed_k=5), _TPU)
    big = prefill_profile("r0:p:L0", n_params=7e9, seq_len=8192,
                          kv_bytes_per_token=131072).profile()
    small = decode_profile("r1:d:L0", n_params=7e9, kv_len=512,
                           kv_bytes_per_token=131072).profile()
    # footprint 2x the slot budget: oversized for every mode
    assert sl_occ.footprint_frac(big) == pytest.approx(2.0)
    assert sl_occ.slice_count(big) == 3      # ceil(2.0 / 0.75)
    assert sl_fill.slice_count(big) == 4     # ceil(2.0 / 0.5)
    assert sl_fix.slice_count(big) == 5
    # fits comfortably: occupancy / fixed leave it alone
    assert sl_occ.slice_count(small) == 1
    assert sl_fix.slice_count(small) == 1
    # slices and joins are terminal
    cut = sl_occ.slice_profile(big, 3)[0]
    assert sl_occ.slice_count(cut) == 1
    assert sl_occ.slice_count(join_profile(big)) == 1


def test_slice_count_clamps_to_granularity():
    sl = KernelSlicer(SlicePolicy(mode="fixed", trigger_frac=0.0,
                                  fixed_k=16), _TPU)
    one_tok = decode_profile("r0:d:L0", n_params=7e9, kv_len=4096,
                             kv_bytes_per_token=131072)
    assert len(sl.slice_item(one_tok, 16)) == 1   # 1 token: uncuttable
    four = prefill_profile("r1:p:L0", n_params=1e9, seq_len=4,
                           kv_bytes_per_token=131072)
    assert len(sl.slice_item(four, 16)) == 4


# --------------------------------------------------------------------------
# conservation
# --------------------------------------------------------------------------

def test_item_slices_conserve_parent():
    rng = random.Random(3)
    sl = KernelSlicer(SlicePolicy(), _TPU)
    for _ in range(30):
        it = prefill_profile(f"r0:p:L{rng.randrange(9)}",
                             n_params=rng.uniform(1e9, 3e11),
                             seq_len=rng.choice([4097, 6144, 8192, 16384]),
                             kv_bytes_per_token=rng.uniform(1e3, 2e5))
        it = replace(it, weight_bytes=2e9)
        k = rng.randint(2, 8)
        parts = sl.slice_item(it, k)
        assert len(parts) == k
        assert sum(p.flops for p in parts) == pytest.approx(it.flops)
        assert sum(p.hbm_bytes for p in parts) == pytest.approx(it.hbm_bytes)
        assert sum(p.vmem_bytes for p in parts) == pytest.approx(
            it.vmem_bytes)
        assert sum(p.tokens for p in parts) == it.tokens
        for p in parts:
            # the stage weight stream is shared, never split
            assert p.weight_bytes == it.weight_bytes
            assert p.intensity == pytest.approx(it.intensity)
            assert parent_name(p.name) == it.name


def test_profile_slices_conserve_parent():
    rng = random.Random(7)
    sl_gpu = KernelSlicer(SlicePolicy(), GTX580)
    for _ in range(30):
        prof = rng.choice(_FAMS)(f"k{rng.randrange(99)}",
                                 grid=rng.choice([16, 48, 96, 256]),
                                 shm=rng.choice([0, 8192, 16384]),
                                 inst=rng.uniform(1e6, 1e9))
        k = rng.randint(2, 6)
        parts = sl_gpu.slice_profile(prof, k)
        k_eff = min(k, prof.n_blocks)
        assert len(parts) == k_eff
        # grid partition: block counts sum, per-block profile unchanged
        assert sum(p.n_blocks for p in parts) == prof.n_blocks
        for p in parts:
            assert p.inst_per_block == prof.inst_per_block
            assert p.demands == prof.demands
            assert p.r == prof.r
        # total work / traffic / demand mass conserved
        assert sum(p.inst_per_block * p.n_blocks for p in parts) == \
            pytest.approx(prof.inst_per_block * prof.n_blocks)
        assert sum(p.mem_per_block() * p.n_blocks for p in parts) == \
            pytest.approx(prof.mem_per_block() * prof.n_blocks)


def test_single_block_profile_slices_scale_mass():
    sl = KernelSlicer(SlicePolicy(), _TPU)
    prof = prefill_profile("r0:p:L0", n_params=7e9, seq_len=8193,
                           kv_bytes_per_token=131072).profile()
    parts = sl.slice_profile(prof, 3)
    assert len(parts) == 3
    for dim in prof.demands:
        assert sum(p.demands[dim] for p in parts) == \
            pytest.approx(prof.demands[dim])
    assert sum(p.inst_per_block for p in parts) == \
        pytest.approx(prof.inst_per_block)
    assert all(p.r == prof.r for p in parts)


# --------------------------------------------------------------------------
# expansion topology
# --------------------------------------------------------------------------

def test_expand_nodes_rewires_the_diamond():
    rng = random.Random(11)
    sl = KernelSlicer(SlicePolicy(), GTX580)
    for _ in range(20):
        n = rng.randint(4, 20)
        ks = _gpu_kernels(rng, n)
        edges = _random_dag_edges(rng, n, 1.5)
        t = rng.randrange(n)
        parts = sl.slice_profile(ks[t], rng.randint(2, 4))
        if len(parts) < 2:
            continue
        exp = expand_nodes(ks, edges, {t: (parts, join_profile(ks[t]))})
        g = KernelGraph(exp.kernels, exp.edges)
        g.validate()                      # still acyclic
        slice_idx = set(exp.new_of[t])
        join_idx = exp.join_of[t]
        for u, v in edges:
            if v == t:                    # in-edges inherited by slices
                for s in slice_idx:
                    assert (exp.new_of[u][0], s) in exp.edges
            if u == t:                    # out-edges hang off the join
                assert (join_idx, exp.new_of[v][0]) in exp.edges
        for s in slice_idx:               # diamond closes through join
            assert (s, join_idx) in exp.edges
            # sibling slices are mutually independent
            for s2 in slice_idx:
                assert (s, s2) not in exp.edges
        assert all(exp.parent_of[s] == t for s in slice_idx)
        assert exp.parent_of[join_idx] == t


def test_expansion_preserves_topological_input_order():
    """Input with forward edges (u < v) stays forward after in-place
    expansion — the invariant the serving fifo baseline relies on."""
    rng = random.Random(13)
    sl = KernelSlicer(SlicePolicy(), GTX580)
    for _ in range(10):
        n = rng.randint(5, 16)
        ks = _gpu_kernels(rng, n)
        edges = _random_dag_edges(rng, n, 1.0)
        exps = {}
        for t in rng.sample(range(n), rng.randint(1, 3)):
            parts = sl.slice_profile(ks[t], 3)
            if len(parts) >= 2:
                exps[t] = (parts, join_profile(ks[t]))
        if not exps:
            continue
        exp = expand_nodes(ks, edges, exps)
        assert all(u < v for u, v in exp.edges)


def test_greedy_order_slices_emits_topological_orders():
    rng = random.Random(17)
    pol = SlicePolicy(mode="round_fill", target_fill=0.5)
    for _ in range(15):
        n = rng.randint(4, 20)
        items = _tpu_items(rng, n, oversized_frac=0.4)
        profs = [it.profile() for it in items]
        edges = _random_dag_edges(rng, n, rng.uniform(0.0, 1.5))
        res = greedy_order_slices(profs, _TPU, edges=edges, policy=pol)
        g = res.graph()
        g.validate()
        assert g.is_topological(res.order)
        # no round contains both ends of an edge
        eids = res.edges_by_id()
        for rd in res.rounds:
            ids = [id(k) for k in rd.kernels]
            assert not any((a, b) in eids for a in ids for b in ids)
        # parent_of maps every expanded node to an original index
        assert len(res.parent_of) == len(res.kernels)
        assert all(0 <= p < n for p in res.parent_of)


def test_refine_order_slices_respects_slice_edges():
    rng = random.Random(19)
    items = _tpu_items(rng, 10, oversized_frac=0.5)
    profs = [it.profile() for it in items]
    edges = {(i, i + 1) for i in range(0, 8, 2)}
    res = greedy_order_slices(profs, _TPU, edges=edges,
                              policy=SlicePolicy())
    assert res.sliced            # something was cut
    order, _, _ = refine_order_slices(res, _TPU, budget=30,
                                      model="event")
    assert res.graph().is_topological(order)


# --------------------------------------------------------------------------
# slice-factor-1 identity
# --------------------------------------------------------------------------

def test_factor1_identity_no_policy():
    """policy=None: identical rounds, order and gated makespan to the
    unsliced DAG pipeline, across randomized DAG workloads."""
    rng = random.Random(23)
    for _ in range(20):
        n = rng.randint(2, 24)
        items = _tpu_items(rng, n, oversized_frac=0.3)
        profs = [it.profile() for it in items]
        edges = _random_dag_edges(rng, n, 1.0)
        ref = greedy_order_dag(profs, _TPU, edges=edges)
        res = greedy_order_slices(profs, _TPU, edges=edges, policy=None)
        assert _round_names(res.schedule) == _round_names(ref)
        assert res.sliced == {} and res.passes == 0
        eids = KernelGraph(profs, edges).edges_by_id()
        t_ref = DagEventSimulator(_TPU, eids).simulate(ref.order)
        t_res = DagEventSimulator(_TPU, res.edges_by_id()).simulate(
            res.order)
        assert t_res == t_ref    # bit-identical float accumulation


def test_factor1_identity_untriggered_policy():
    """A policy whose trigger nothing crosses leaves the schedule
    bit-identical too (the lazy path never expands)."""
    rng = random.Random(29)
    for _ in range(10):
        n = rng.randint(2, 16)
        profs = [it.profile()
                 for it in _tpu_items(rng, n, oversized_frac=0.0)]
        edges = _random_dag_edges(rng, n, 0.8)
        ref = greedy_order_dag(profs, _TPU, edges=edges)
        res = greedy_order_slices(profs, _TPU, edges=edges,
                                  policy=SlicePolicy())
        assert _round_names(res.schedule) == _round_names(ref)
        assert res.passes == 0


# --------------------------------------------------------------------------
# gated simulator: joins + saturating profiles
# --------------------------------------------------------------------------

def test_join_markers_add_no_gated_time():
    """A slice diamond over one kernel simulates to the same gated
    time as the unsliced kernel when nothing else co-executes, and
    the zero-work join never inflates the makespan."""
    it = prefill_profile("r0:p:L0", n_params=7e9, seq_len=8192,
                         kv_bytes_per_token=131072)
    prof = it.profile()
    sl = KernelSlicer(SlicePolicy(mode="fixed", fixed_k=2), _TPU)
    parts = sl.slice_profile(prof, 2)
    jn = join_profile(prof)
    exp = expand_nodes([prof], set(), {0: (parts, jn)})
    g = KernelGraph(exp.kernels, exp.edges)
    t_unsliced = DagEventSimulator(_TPU, set()).simulate([prof])
    t_sliced = DagEventSimulator(_TPU, g.edges_by_id()).simulate(
        exp.kernels)
    # two half-size oversized passes == one full pass (same roofline)
    assert t_sliced == pytest.approx(t_unsliced, rel=1e-9)


def test_sliced_makespan_no_worse_on_saturating_profiles():
    """ISSUE-4 pin: on profiles that saturate the slot budget, the
    sliced greedy's gated makespan is never worse than the unsliced
    greedy's, and strictly better when there is memory-bound work to
    co-execute."""
    rng = random.Random(31)
    strict_wins = 0
    for trial in range(12):
        n = rng.randint(6, 18)
        items = _tpu_items(rng, n, oversized_frac=0.35)
        if not any(it.tokens > 4096 for it in items):
            continue
        profs = [it.profile() for it in items]
        un = greedy_order_dag(profs, _TPU)
        t_un = DagEventSimulator(_TPU, set()).simulate(un.order)
        res = greedy_order_slices(profs, _TPU, policy=SlicePolicy())
        t_sl = DagEventSimulator(_TPU, res.edges_by_id()).simulate(
            res.order)
        assert t_sl <= t_un * (1 + 1e-9), trial
        if t_sl < t_un * (1 - 1e-6):
            strict_wins += 1
    assert strict_wins >= 3


def test_zero_work_join_requires_drained_predecessors():
    """The join is still gated: it must not retire before its slices,
    so successors of the join start strictly after every slice."""
    it = prefill_profile("r0:p:L0", n_params=7e9, seq_len=8192,
                         kv_bytes_per_token=131072)
    tail = decode_profile("r0:d:L1", n_params=7e9, kv_len=4096,
                          kv_bytes_per_token=131072).profile()
    prof = it.profile()
    sl = KernelSlicer(SlicePolicy(mode="fixed", fixed_k=2), _TPU)
    parts = sl.slice_profile(prof, 2)
    exp = expand_nodes([prof, tail], {(0, 1)},
                       {0: (parts, join_profile(prof))})
    g = KernelGraph(exp.kernels, exp.edges)
    sim = DagEventSimulator(_TPU, g.edges_by_id())
    t_chain = sim.simulate(exp.kernels)
    solo = DagEventSimulator(_TPU, set())
    t_parts = solo.simulate(parts) + solo.simulate([tail])
    # fully serialized chain: slices then tail, join adding nothing
    assert t_chain == pytest.approx(t_parts, rel=1e-9)
    # a non-topological order (join before its slices) is rejected
    bad = [exp.kernels[i] for i in (exp.join_of[0], *exp.new_of[0])] + \
        [tail]
    with pytest.raises(ValueError):
        sim.simulate(bad)


# --------------------------------------------------------------------------
# serving integration
# --------------------------------------------------------------------------

def _smoke_engine(policy, device):
    import jax
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import ServingEngine
    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = _smoke_engine._params
    if params is None or _smoke_engine._cfg is not cfg:
        params = T.init(jax.random.PRNGKey(0), cfg)
        _smoke_engine._params, _smoke_engine._cfg = params, cfg
    return ServingEngine(cfg, params, max_len=64, policy=policy,
                         device=device)


_smoke_engine._params = None
_smoke_engine._cfg = None


def _smoke_requests(n=3, size=8):
    import numpy as np
    from repro.serve import Request
    rng = np.random.default_rng(0)
    return [Request(i, rng.integers(0, 512, size=size), max_new_tokens=4)
            for i in range(n)]


def test_serving_tokens_bit_identical_with_slice_policy():
    """slice_policy only reshapes modelled rounds: generated tokens
    are bit-identical with it on or off — against a shrunken slot
    budget that makes the 8-token prefill stages genuinely oversized,
    so slicing actually triggers."""
    from repro.serve import SchedulerPolicy
    dev = make_serving_device(token_budget=6)
    base = _smoke_engine(SchedulerPolicy(kind="symbiotic",
                                         respect_deps=True), dev)
    base.submit(_smoke_requests())
    s_base = base.run()
    sliced = _smoke_engine(
        SchedulerPolicy(kind="symbiotic", respect_deps=True,
                        slice_policy=SlicePolicy()), dev)
    sliced.submit(_smoke_requests())
    s_sliced = sliced.run()
    assert s_sliced["outputs"] == s_base["outputs"]
    assert all(len(v) >= 4 for v in s_sliced["outputs"].values())


def test_serving_gated_guard_token_identity():
    """The gated-event guard (``dag_guard="gated"``) only changes
    which *composition* wins the fifo comparison — generated tokens
    stay bit-identical to the round-guard engine, with slicing on
    (shrunken slot budget so cutting genuinely triggers) and off."""
    from repro.serve import SchedulerPolicy
    dev = make_serving_device(token_budget=6)
    base = _smoke_engine(SchedulerPolicy(kind="symbiotic",
                                         respect_deps=True,
                                         slice_policy=SlicePolicy()), dev)
    base.submit(_smoke_requests())
    s_base = base.run()
    gated = _smoke_engine(
        SchedulerPolicy(kind="symbiotic", respect_deps=True,
                        slice_policy=SlicePolicy(), dag_guard="gated"),
        dev)
    gated.submit(_smoke_requests())
    s_gated = gated.run()
    assert s_gated["outputs"] == s_base["outputs"]
    # unsliced path too
    plain = _smoke_engine(SchedulerPolicy(kind="symbiotic",
                                          respect_deps=True,
                                          dag_guard="gated"), dev)
    plain.submit(_smoke_requests())
    assert plain.run()["outputs"] == s_base["outputs"]


def test_serving_gated_guard_scores_sliced_composition():
    """``_dag_gated_time`` rebuilds the expanded slice/join dependency
    structure from item names: finite on a composition whose first
    stage was cut into slices + join, and ``inf`` (guard rejection)
    when the flat order breaks the slice diamond (join launched before
    its slices)."""
    from repro.serve import SchedulerPolicy
    from repro.slice import KernelSlicer, join_item
    dev = make_serving_device(token_budget=6)
    eng = _smoke_engine(
        SchedulerPolicy(kind="symbiotic", respect_deps=True,
                        slice_policy=SlicePolicy(), dag_guard="gated",
                        cache=False), dev)
    eng.submit(_smoke_requests())
    triples, traced = eng._work_items_dag()
    # hand-cut the first request's head stage into a slice diamond,
    # exactly as _compose_dag's make_slices closure would
    it0, r0, kind0 = triples[0]
    parts = KernelSlicer(SlicePolicy(mode="fixed", trigger_frac=0.0,
                                     fixed_k=2), dev).slice_item(it0, 2)
    assert len(parts) == 2
    ji = join_item(it0)
    rounds = ([[(parts[0], r0, "frag"), (parts[1], r0, "frag")],
               [(ji, r0, kind0)]] +
              [[trip] for trip in triples[1:]])
    t = eng._dag_gated_time(rounds, traced)
    assert 0.0 < t < float("inf")
    # join before its slices: non-topological flat order scores inf
    bad = ([[(ji, r0, kind0)],
            [(parts[0], r0, "frag"), (parts[1], r0, "frag")]] +
           [[trip] for trip in triples[1:]])
    assert eng._dag_gated_time(bad, traced) == float("inf")


def test_gated_guard_unlocks_slicing_win_round_guard_hides():
    """The ROADMAP slicing follow-up, resolved: on a prefill+decode
    mix whose prefill stages are oversized, the round-model guard
    structurally rejects the sliced composition (every slice round
    pays the stage weight stream) and serves dep-aware fifo, while
    the gated guard accepts it — and the accepted composition's gated
    makespan is strictly better than the round guard's choice."""
    import numpy as np
    from repro.serve import Request, SchedulerPolicy
    dev = make_serving_device(token_budget=6)

    def submit(eng):
        rng = np.random.default_rng(0)
        eng.submit([Request(i, rng.integers(0, 512, size=12),
                            max_new_tokens=4) for i in range(2)] +
                   [Request(10 + i, rng.integers(0, 512, size=2),
                            max_new_tokens=6) for i in range(6)])

    results = {}
    for guard in ("rounds", "gated"):
        eng = _smoke_engine(
            SchedulerPolicy(kind="symbiotic", respect_deps=True,
                            slice_policy=SlicePolicy(), dag_guard=guard,
                            cache=False), dev)
        submit(eng)
        triples, traced = eng._work_items_dag()
        rounds = eng._compose_dag(triples, traced)
        names = [t[0].name for rd in rounds for t in rd]
        results[guard] = (sum(1 for nm in names if "#s" in nm),
                          eng._dag_gated_time(rounds, traced))
    assert results["rounds"][0] == 0, "round guard serves unsliced fifo"
    assert results["gated"][0] > 0, "gated guard accepts the slices"
    assert results["gated"][1] < results["rounds"][1]


def test_serving_refine_model_gated_runs():
    """kind="refined" with refine_model="gated" threads the gated
    delta evaluator through _compose_dag; tokens match the symbiotic
    engine (refinement only reorders modelled rounds)."""
    from repro.serve import SchedulerPolicy
    dev = make_serving_device()
    base = _smoke_engine(SchedulerPolicy(kind="symbiotic",
                                         respect_deps=True), dev)
    base.submit(_smoke_requests())
    s_base = base.run()
    ref = _smoke_engine(
        SchedulerPolicy(kind="refined", respect_deps=True,
                        refine_model="gated", refine_budget=20,
                        dag_guard="gated"), dev)
    ref.submit(_smoke_requests())
    s_ref = ref.run()
    assert s_ref["outputs"] == s_base["outputs"]


def test_serving_dag_cache_warms_up():
    """PR 3 bypassed the cache on the respect_deps path; the
    coarsened per-request chain keying must now produce hits in
    decode-heavy steady state, surfaced as ``dag_hits``."""
    from repro.serve import SchedulerPolicy
    eng = _smoke_engine(SchedulerPolicy(kind="symbiotic",
                                        respect_deps=True),
                        make_serving_device())
    eng.submit(_smoke_requests())
    stats = eng.run()["schedule_cache"]
    assert stats["dag_hits"] >= 1
    assert stats["hits"] == stats["dag_hits"]


def test_dag_replay_reproduces_cold_composition():
    """Replaying a cached DAG pattern on the identical queue state
    must reproduce the cold composition round-for-round."""
    from repro.serve import SchedulerPolicy
    eng = _smoke_engine(SchedulerPolicy(kind="symbiotic",
                                        respect_deps=True),
                        make_serving_device())
    eng.submit(_smoke_requests())
    cold = eng._compose_dag(*(eng._work_items_dag()[:2]))
    warm = eng._compose_dag(*(eng._work_items_dag()[:2]))
    assert eng.schedule_cache.dag_hits == 1
    assert [[t[0].name for t in rd] for rd in warm] == \
        [[t[0].name for t in rd] for rd in cold]


def test_replay_drift_triggers_revalidation():
    """A cached pattern whose stored modelled time drifts beyond
    ``replay_drift_tol`` from the replayed composition is rejected
    (counted as a revalidation) and the step recomposes cold; with
    the tolerance disabled the same replay is accepted optimistically."""
    from repro.serve import SchedulerPolicy
    eng = _smoke_engine(SchedulerPolicy(kind="symbiotic",
                                        respect_deps=True,
                                        replay_drift_tol=0.05),
                        make_serving_device())
    eng.submit(_smoke_requests())
    triples, traced = eng._work_items_dag()
    eng._compose_dag(triples, traced)          # cold store
    key, _ = eng._dag_key_and_labels(triples, traced)
    t0 = eng.schedule_cache.time_of(key)
    assert t0 is not None and t0 > 0
    # poison the stored time: the honest replay now "drifts" >5%
    eng.schedule_cache._times[key] = t0 * 2.0
    eng._compose_dag(*(eng._work_items_dag()[:2]))
    assert eng.schedule_cache.replay_revalidations == 1
    # the cold recompose re-stored the honest time
    assert eng.schedule_cache.time_of(key) == pytest.approx(t0)
    # tol <= 0 replays the same poisoned entry optimistically
    eng.schedule_cache._times[key] = t0 * 2.0
    eng.policy.replay_drift_tol = 0.0
    eng._compose_dag(*(eng._work_items_dag()[:2]))
    assert eng.schedule_cache.replay_revalidations == 1


# --------------------------------------------------------------------------
# slow sweep (ISSUE-4 CI satellite)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_sliced_dag_n512_sweep():
    """n=512 chain-structured DAG with oversized stages: sliced
    construction completes, stays topological, conserves node mass,
    and the gated makespan is no worse than unsliced."""
    rng = random.Random(37)
    items = _tpu_items(rng, 512, oversized_frac=0.1)
    profs = [it.profile() for it in items]
    edges = set()
    chains: list[list[int]] = [[] for _ in range(64)]
    for i in range(512):
        c = chains[rng.randrange(64)]
        if c:
            edges.add((c[-1], i))
        c.append(i)
    res = greedy_order_slices(profs, _TPU, edges=edges,
                              policy=SlicePolicy())
    g = res.graph()
    g.validate()
    assert g.is_topological(res.order)
    assert res.sliced
    un = greedy_order_dag(profs, _TPU, edges=edges)
    eids = KernelGraph(profs, edges).edges_by_id()
    t_un = DagEventSimulator(_TPU, eids).simulate(un.order)
    t_sl = DagEventSimulator(_TPU, res.edges_by_id()).simulate(res.order)
    assert t_sl <= t_un * (1 + 1e-9)


# --------------------------------------------------------------------------
# coalescing: same-round siblings merge back (inverse conservation law)
# --------------------------------------------------------------------------

def _work_mass(ks):
    """Total instructions and per-dimension demand mass over a node
    set (zero-work joins contribute nothing by construction)."""
    inst = sum(k.inst_per_block * k.n_blocks for k in ks)
    dims = {d for k in ks for d in k.demands}
    dem = {d: sum(k.demands.get(d, 0.0) * k.n_blocks for k in ks)
           for d in dims}
    return inst, dem


def test_merge_slice_profiles_full_merge_restores_parent():
    rng = random.Random(61)
    for prof in _gpu_kernels(rng, 6):
        sl = KernelSlicer(SlicePolicy(mode="fixed", fixed_k=3), GTX580)
        parts = sl.slice_profile(prof, 3)
        merged = merge_slice_profiles(parts)
        assert merged.name == prof.name
        assert not is_slice(merged.name)
        i0, d0 = _work_mass([prof])
        i1, d1 = _work_mass([merged])
        assert i1 == pytest.approx(i0, rel=1e-12)
        for d in d0:
            assert d1[d] == pytest.approx(d0[d], rel=1e-12)


def test_merge_slice_profiles_partial_naming_roundtrip():
    rng = random.Random(62)
    prof = _gpu_kernels(rng, 1)[0]
    sl = KernelSlicer(SlicePolicy(mode="fixed", fixed_k=4), GTX580)
    parts = sl.slice_profile(prof, 4)
    merged = merge_slice_profiles([parts[1], parts[3]])
    assert is_slice(merged.name)
    assert parent_name(merged.name) == prof.name
    ix, k = slice_indices(merged.name)
    assert (ix, k) == ([1, 3], 4)
    # a later pass can finish the merge: partial + remaining == parent
    done = merge_slice_profiles([merged, parts[0], parts[2]])
    assert done.name == prof.name
    assert done.n_blocks == prof.n_blocks


def test_merge_slice_profiles_mass_slices_conserve_totals():
    it = prefill_profile("r0:p:L0", n_params=7e9, seq_len=8192,
                         kv_bytes_per_token=131072)
    prof = it.profile()
    sl = KernelSlicer(SlicePolicy(mode="fixed", fixed_k=2), _TPU)
    parts = sl.slice_profile(prof, 2)
    merged = merge_slice_profiles(parts)
    i0, d0 = _work_mass([prof])
    i1, d1 = _work_mass([merged])
    assert i1 == pytest.approx(i0, rel=1e-12)
    for d in d0:
        assert d1[d] == pytest.approx(d0[d], rel=1e-12)


def test_merge_slice_profiles_rejects_bad_groups():
    rng = random.Random(63)
    a, b = _gpu_kernels(rng, 2)
    sl = KernelSlicer(SlicePolicy(mode="fixed", fixed_k=2), GTX580)
    pa, pb = sl.slice_profile(a, 2), sl.slice_profile(b, 2)
    with pytest.raises(ValueError):
        merge_slice_profiles([pa[0], pb[1]])       # different parents
    with pytest.raises(ValueError):
        merge_slice_profiles([pa[0], pa[0]])       # duplicate index
    with pytest.raises(ValueError):
        merge_slice_profiles([])


def test_coalesce_rounds_conserves_and_keeps_makespan():
    """On a workload the round_fill policy over-slices, coalescing
    merges same-round siblings back: fewer nodes, identical work and
    demand mass, a still-topological order, and a bit-identical gated
    makespan (merged siblings ran side by side already)."""
    rng = random.Random(64)
    merged_any = False
    for trial in range(6):
        items = _tpu_items(rng, rng.randint(8, 16), oversized_frac=0.9)
        profs = [it.profile() for it in items]
        res = greedy_order_slices(
            profs, _TPU,
            policy=SlicePolicy(mode="round_fill", target_fill=0.2))
        out = coalesce_rounds(res)
        i0, d0 = _work_mass(res.kernels)
        i1, d1 = _work_mass(out.kernels)
        assert i1 == pytest.approx(i0, rel=1e-12)
        for d in d0:
            assert d1[d] == pytest.approx(d0.get(d, 0.0), rel=1e-12)
        g = out.graph()
        g.validate()
        assert g.is_topological(out.order)
        t0 = DagEventSimulator(_TPU, res.edges_by_id()).simulate(
            res.order)
        t1 = DagEventSimulator(_TPU, out.edges_by_id()).simulate(
            out.order)
        assert t1 == pytest.approx(t0, rel=1e-9)
        if len(out.kernels) < len(res.kernels):
            merged_any = True
            # every merge shrinks the graph; fully collapsed stages
            # must leave no orphan joins behind
            names = {k.name for k in out.kernels}
            for k in out.kernels:
                if is_join(k.name):
                    p = parent_name(k.name)
                    assert any(is_slice(nm) and not is_join(nm) and
                               parent_name(nm) == p for nm in names)
    assert merged_any


def test_coalesce_rounds_noop_when_siblings_spread():
    """When the composed schedule keeps siblings in distinct rounds
    (the common, useful case) coalescing is the identity."""
    rng = random.Random(65)
    items = _tpu_items(rng, 10, oversized_frac=0.35)
    profs = [it.profile() for it in items]
    res = greedy_order_slices(profs, _TPU, policy=SlicePolicy())
    out = coalesce_rounds(res)
    sibs_shared = any(
        len({parent_name(k.name) for k in rd.kernels
             if is_slice(k.name) and not is_join(k.name)}) <
        sum(1 for k in rd.kernels
            if is_slice(k.name) and not is_join(k.name))
        for rd in res.rounds)
    if not sibs_shared:
        assert out is res
