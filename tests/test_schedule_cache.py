"""Unit tests for the serving ScheduleCache (no model, no jax device
work): signatures, key multisets, pattern replay, LRU bound."""

from repro.serve import ScheduleCache


def test_signature_buckets_decode_kv_lens():
    c = ScheduleCache(kv_bucket=256)
    assert c.signature("decode", 0) == c.signature("decode", 255)
    assert c.signature("decode", 255) != c.signature("decode", 256)
    # prefill is keyed by exact token count (compiled geometry)
    assert c.signature("prefill", 128) != c.signature("prefill", 129)


def test_key_is_order_invariant_multiset():
    a = [("d", 1), ("p", 128), ("d", 1)]
    b = [("d", 1), ("d", 1), ("p", 128)]
    assert ScheduleCache.key_of(a) == ScheduleCache.key_of(b)
    assert ScheduleCache.key_of(a) != ScheduleCache.key_of(a[:2])


def test_lookup_store_and_hit_accounting():
    c = ScheduleCache()
    key = ("symbiotic", ScheduleCache.key_of([("d", 0), ("p", 8)]))
    assert c.lookup(key) is None
    pattern = ((("p", 8), ("d", 0)),)
    c.store(key, pattern)
    assert c.lookup(key) == pattern
    assert c.hits == 1 and c.misses == 1
    assert c.hit_rate == 0.5
    assert c.stats()["entries"] == 1


def test_lru_eviction_bound():
    c = ScheduleCache(max_entries=4)
    for i in range(10):
        c.store(("k", i), ())
    assert len(c._store) == 4
    assert ("k", 9) in c._store and ("k", 5) not in c._store
