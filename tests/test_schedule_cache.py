"""Unit tests for the serving ScheduleCache (no model, no jax device
work): signatures, key multisets, namespaced keys, pattern replay,
LRU bound and refresh accounting, near-miss warm starts."""

from repro.serve import ScheduleCache


def test_signature_buckets_decode_kv_lens():
    c = ScheduleCache(kv_bucket=256)
    assert c.signature("decode", 0) == c.signature("decode", 255)
    assert c.signature("decode", 255) != c.signature("decode", 256)
    # prefill is keyed by exact token count (compiled geometry)
    assert c.signature("prefill", 128) != c.signature("prefill", 129)


def test_key_is_order_invariant_multiset():
    a = [("d", 1), ("p", 128), ("d", 1)]
    b = [("d", 1), ("d", 1), ("p", 128)]
    assert ScheduleCache.key_of(a) == ScheduleCache.key_of(b)
    assert ScheduleCache.key_of(a) != ScheduleCache.key_of(a[:2])


def test_lookup_store_and_hit_accounting():
    c = ScheduleCache()
    key = ("flat", "symbiotic",
           ScheduleCache.key_of([("d", 0), ("p", 8)]))
    assert c.lookup(key) is None
    pattern = ((("p", 8), ("d", 0)),)
    c.store(key, pattern)
    assert c.lookup(key) == pattern
    assert c.hits == 1 and c.misses == 1
    assert c.hit_rate == 0.5
    assert c.stats()["entries"] == 1


def test_lru_eviction_bound():
    c = ScheduleCache(max_entries=4)
    for i in range(10):
        c.store(("flat", "k", i), ())
    assert len(c._store) == 4
    assert (("flat", "k", 9) in c._store and
            ("flat", "k", 5) not in c._store)


def test_restore_refreshes_lru_position():
    """Re-storing an existing key must move it to the fresh end:
    without move_to_end a refreshed entry kept its stale position and
    was evicted as if it were never touched."""
    c = ScheduleCache(max_entries=3)
    c.store(("flat", "k", 1), ())
    c.store(("flat", "k", 2), ())
    c.store(("flat", "k", 3), ())
    c.store(("flat", "k", 1), ((("d", 0),),))  # refresh oldest entry
    c.store(("flat", "k", 4), ())   # evicts the true LRU: ("k", 2)
    assert ("flat", "k", 1) in c._store
    assert ("flat", "k", 2) not in c._store
    assert c._store[("flat", "k", 1)] == ((("d", 0),),)


def _key(kind, sigs):
    return ("flat", kind, ScheduleCache.key_of(list(sigs)))


def test_near_miss_one_joined():
    c = ScheduleCache()
    pat = ((("p", 8), ("d", 0)), (("d", 0),))
    c.store(_key("symbiotic", [("p", 8), ("d", 0), ("d", 0)]), pat)
    # one decode joined the mix
    got = c.near_miss(_key("symbiotic",
                           [("p", 8), ("d", 0), ("d", 0), ("d", 1)]))
    assert got is not None
    pattern, added, removed = got
    assert pattern == pat and added == [("d", 1)] and removed == []


def test_near_miss_one_left():
    c = ScheduleCache()
    pat = ((("p", 8), ("d", 0)), (("d", 0),))
    c.store(_key("symbiotic", [("p", 8), ("d", 0), ("d", 0)]), pat)
    got = c.near_miss(_key("symbiotic", [("p", 8), ("d", 0)]))
    assert got is not None
    pattern, added, removed = got
    assert pattern == pat and added == [] and removed == [("d", 0)]


def test_near_miss_rejects_far_keys_and_other_kinds():
    c = ScheduleCache()
    c.store(_key("symbiotic", [("d", 0), ("d", 0)]), ())
    # two signatures differ (a substitution): not a near miss
    assert c.near_miss(_key("symbiotic", [("d", 1), ("d", 2)])) is None
    # same multiset distance but different policy kind
    assert c.near_miss(_key("refined", [("d", 0)])) is None
    # identical key is a lookup hit, not a near miss
    assert c.near_miss(_key("symbiotic", [("d", 0), ("d", 0)])) is None


def test_warm_hits_surface_in_stats():
    c = ScheduleCache()
    assert c.stats()["warm_hits"] == 0
    c.warm_hits += 1
    assert c.stats()["warm_hits"] == 1


def test_warm_regret_accounting():
    """Warm-start quality audit (ROADMAP item): sampled warm hits
    record their modelled regret; the mean surfaces in stats() and is
    0.0 with no samples (not NaN)."""
    c = ScheduleCache()
    s = c.stats()
    assert s["warm_sampled"] == 0 and s["warm_regret_mean"] == 0.0
    c.record_warm_regret(0.10)
    c.record_warm_regret(-0.02)
    s = c.stats()
    assert s["warm_sampled"] == 2
    assert abs(s["warm_regret_mean"] - 0.04) < 1e-12


def test_store_records_model_time_for_drift_checks():
    """Stale-replay re-validation (ROADMAP item) compares the replayed
    composition's modelled time against the one recorded at store
    time; patterns stored without a time opt out (None)."""
    c = ScheduleCache()
    c.store(("flat", "k", 1), (), 0.125)
    c.store(("flat", "k", 2), ())
    assert c.time_of(("flat", "k", 1)) == 0.125
    assert c.time_of(("flat", "k", 2)) is None
    assert c.time_of(("flat", "k", 3)) is None  # never stored
    # eviction drops the recorded time alongside the pattern
    small = ScheduleCache(max_entries=2)
    small.store(("flat", "k", 1), (), 1.0)
    small.store(("flat", "k", 2), (), 2.0)
    small.store(("flat", "k", 3), (), 3.0)
    assert small.time_of(("flat", "k", 1)) is None
    assert small.time_of(("flat", "k", 3)) == 3.0


def test_new_counters_surface_in_stats():
    c = ScheduleCache()
    s = c.stats()
    assert s["dag_hits"] == 0 and s["replay_revalidations"] == 0
    c.dag_hits += 2
    c.replay_revalidations += 1
    s = c.stats()
    assert s["dag_hits"] == 2 and s["replay_revalidations"] == 1


def test_warm_audit_sampling_is_deterministic():
    """The engine samples warm hits when the counter crosses integer
    multiples of 1/frac — verify the crossing rule the engine uses."""
    def sampled(seen, frac):
        return int(seen * frac) > int((seen - 1) * frac)
    assert [s for s in range(1, 9) if sampled(s, 0.25)] == [4, 8]
    assert [s for s in range(1, 5) if sampled(s, 1.0)] == [1, 2, 3, 4]
    assert [s for s in range(1, 9) if sampled(s, 0.0)] == []


def test_keys_are_namespaced():
    """PR 7: every key names its path — flat or dag — so a traced step
    can never consult a flat-signature pattern (the PR 3 cache-bypass
    wart, now structurally impossible)."""
    import pytest

    c = ScheduleCache()
    with pytest.raises(AssertionError):
        c.store(("symbiotic", (("d", 0),)), ())     # legacy 2-tuple
    with pytest.raises(AssertionError):
        c.lookup(("symbiotic", (("d", 0),)))
    key = ("flat", "symbiotic", (("d", 0),))
    c.store(key, ())
    assert c.lookup(key, namespace="flat") == ()
    with pytest.raises(AssertionError):
        c.lookup(key, namespace="dag")              # wrong path
    dkey = ("dag", "symbiotic", ((("d", 0), 3),))
    c.store(dkey, ())
    assert c.lookup(dkey, namespace="dag") == ()
    with pytest.raises(AssertionError):
        c.near_miss(dkey)       # warm adaptation is flat-only


def test_near_miss_never_crosses_namespaces():
    c = ScheduleCache()
    # a dag entry whose (kind, len±1) shape would match the flat scan
    c.store(("dag", "symbiotic", (("d", 0),)), ())
    assert c.near_miss(("flat", "symbiotic",
                        (("d", 0), ("d", 0)))) is None


def test_incremental_counters_surface_in_stats():
    c = ScheduleCache()
    s = c.stats()
    assert s["incremental_joins"] == 0
    assert s["incremental_leaves"] == 0
    assert s["frontier_rebuilds"] == 0
    assert s["gated_sims_saved"] == 0.0
    c.incremental_joins += 3
    c.incremental_leaves += 2
    c.frontier_rebuilds += 1
    c.gated_sims_saved += 0.75
    s = c.stats()
    assert s["incremental_joins"] == 3
    assert s["incremental_leaves"] == 2
    assert s["frontier_rebuilds"] == 1
    assert abs(s["gated_sims_saved"] - 0.75) < 1e-12
