"""End-to-end system tests: paper-claim validation, training
integration, serving engine, distributed-vs-local MoE equivalence."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GTX580, EXPERIMENTS, greedy_order, percentile_rank,
                        simulate)
from repro.core.refine import refined_schedule


# --------------------------------------------------------------------------
# paper-claim validation (the reproduction's headline numbers)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(EXPERIMENTS))
def test_algorithm_near_optimal_per_experiment(name):
    """Deviation from optimal stays within the paper's reported band
    (paper: 0.02%..5.51%; we allow <=10%) on every experiment."""
    ks = EXPERIMENTS[name]()
    n = len(ks)
    sched = greedy_order(ks, GTX580)
    t_alg = simulate(sched.order, GTX580)
    if n <= 6:
        times = [simulate([ks[i] for i in p], GTX580)
                 for p in itertools.permutations(range(n))]
    else:
        import random
        rng = random.Random(0)
        times = [simulate([ks[i] for i in rng.sample(range(n), n)], GTX580)
                 for _ in range(1500)] + [t_alg]
    t_opt = min(times)
    assert t_alg / t_opt - 1 < 0.10, f"{name}: {t_alg / t_opt - 1:.2%}"


def test_refined_above_90th_percentile_everywhere():
    """Beyond-paper scheduler: >=90th percentile on every experiment."""
    import random
    for name, make in EXPERIMENTS.items():
        ks = make()
        n = len(ks)
        _, t_ref = refined_schedule(ks, GTX580, budget=600)
        if n <= 6:
            times = [simulate([ks[i] for i in p], GTX580)
                     for p in itertools.permutations(range(n))]
        else:
            rng = random.Random(0)
            times = [simulate([ks[i] for i in rng.sample(range(n), n)],
                              GTX580) for _ in range(1500)]
        assert percentile_rank(t_ref, times) >= 90.0, name


def test_ordering_matters_when_resources_stressed():
    """The design space must show a real spread for the stressed
    experiments (the paper's premise)."""
    ks = EXPERIMENTS["EpBsEsSw-8"]()
    import random
    rng = random.Random(1)
    times = [simulate([ks[i] for i in rng.sample(range(len(ks)),
                                                 len(ks))], GTX580)
             for _ in range(400)]
    assert max(times) / min(times) > 1.3


# --------------------------------------------------------------------------
# training integration (loss goes down through the full substrate)
# --------------------------------------------------------------------------

def test_train_loss_decreases(tmp_path):
    from repro.launch.train import train
    out = train("qwen1.5-0.5b", variant="smoke", steps=40,
                global_batch=4, seq_len=64, ckpt_dir=str(tmp_path),
                ckpt_every=0)
    assert out["last_loss"] < out["first_loss"] - 0.1


def test_train_resume_continues(tmp_path):
    from repro.launch.train import train
    out1 = train("qwen1.5-0.5b", variant="smoke", steps=10,
                 global_batch=2, seq_len=32, ckpt_dir=str(tmp_path),
                 ckpt_every=10)
    out2 = train("qwen1.5-0.5b", variant="smoke", steps=20,
                 global_batch=2, seq_len=32, ckpt_dir=str(tmp_path),
                 ckpt_every=10)
    # resumed run trained only steps 10..20
    assert len(out2["losses"]) == 10


# --------------------------------------------------------------------------
# serving engine
# --------------------------------------------------------------------------

def test_serving_engine_generates_and_orders():
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import Request, SchedulerPolicy, ServingEngine
    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    eng = ServingEngine(cfg, params, max_len=32,
                        policy=SchedulerPolicy(kind="symbiotic"))
    eng.submit([Request(i, rng.integers(0, 512, size=4), max_new_tokens=4)
                for i in range(3)])
    stats = eng.run()
    assert stats["total_new_tokens"] >= 12
    assert all(len(v) >= 4 for v in stats["outputs"].values())
    assert stats["modelled_time_s"] > 0


def test_serving_warm_start_on_arrival():
    """A request joining a steady mix is a cache near-miss: the engine
    must adapt the cached composition (warm start) instead of
    recomputing, and generation must stay correct."""
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import Request, SchedulerPolicy, ServingEngine
    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    eng = ServingEngine(cfg, params, max_len=32,
                        policy=SchedulerPolicy(kind="symbiotic"))
    eng.submit([Request(i, rng.integers(0, 512, size=4), max_new_tokens=6)
                for i in range(3)])
    late = [Request(10, rng.integers(0, 512, size=4), max_new_tokens=4)]
    stats = eng.run(arrivals=[(2, late)])
    cache = stats["schedule_cache"]
    assert cache["warm_hits"] >= 1, cache
    assert all(len(v) >= 4 for v in stats["outputs"].values())


def test_serving_respect_deps_matches_flat_tokens():
    """The respect_deps path schedules per-layer chains (interior
    stages execute nothing) — generated tokens must be identical to
    the flat per-request path, and the composition must beat the
    dependency-aware fifo baseline's modelled time, or tie via the
    guard."""
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import Request, SchedulerPolicy, ServingEngine
    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)

    def reqs():
        rng = np.random.default_rng(0)
        return [Request(i, rng.integers(0, 512, size=4), max_new_tokens=4)
                for i in range(3)]

    flat = ServingEngine(cfg, params, max_len=32,
                         policy=SchedulerPolicy(kind="symbiotic"))
    flat.submit(reqs())
    s_flat = flat.run()
    stats = {}
    for kind in ("fifo", "symbiotic"):
        eng = ServingEngine(cfg, params, max_len=32,
                            policy=SchedulerPolicy(kind=kind,
                                                   respect_deps=True))
        eng.submit(reqs())
        stats[kind] = eng.run()
        assert stats[kind]["outputs"] == s_flat["outputs"], kind
        # per-layer granularity: a 4-layer smoke config cannot finish
        # a request in fewer than 8 chained stages -> >= 8 rounds/step
        assert stats[kind]["rounds"] > s_flat["rounds"]
    # the symbiotic DAG composition never models worse than the
    # dep-aware fifo baseline (the _compose_dag guard guarantees it)
    assert (stats["symbiotic"]["modelled_time_s"]
            <= stats["fifo"]["modelled_time_s"] + 1e-12)


def test_serving_greedy_decode_deterministic():
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import Request, ServingEngine
    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    outs = []
    for _ in range(2):
        eng = ServingEngine(cfg, params, max_len=16)
        eng.submit([Request(0, np.arange(4), max_new_tokens=4)])
        outs.append(eng.run()["outputs"][0])
    assert outs[0] == outs[1]


# --------------------------------------------------------------------------
# distributed MoE == local MoE (shard_map correctness on a 1x1 mesh)
# --------------------------------------------------------------------------

def test_moe_distributed_matches_local():
    from repro.dist.context import act_ctx, set_activation_axes
    from repro.models.common import ModelConfig
    from repro.models.moe import MoE
    cfg = ModelConfig(name="m", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=4, head_dim=8, d_ff=64, vocab=64,
                      n_experts=4, top_k=2, n_shared_experts=1,
                      moe_d_ff=48, dtype="float32")
    p = MoE.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y_local, aux_local = MoE._fwd_local(p, cfg, x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.dist.context import set_activation_axes
    with jax.set_mesh(mesh):
        set_activation_axes(dp="data", tp="model", mesh=mesh)
        try:
            y_ep, aux_ep = jax.jit(
                lambda pp, xx: MoE._fwd_ep(pp, cfg, xx))(p, x)
        finally:
            set_activation_axes(dp=None, tp=None)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_local["moe_lb_loss"]),
                               float(aux_ep["moe_lb_loss"]), rtol=1e-3)


# --------------------------------------------------------------------------
# TPU round model sanity
# --------------------------------------------------------------------------

def test_symbiotic_round_beats_split_rounds():
    """One mixed prefill+decode round is faster than prefill-only +
    decode-only rounds (the weight stream is paid once)."""
    from repro.core.tpu import (decode_profile, make_serving_device,
                                prefill_profile, round_time)
    dev = make_serving_device()
    w = 14e9
    p = prefill_profile("p", n_params=7e9, seq_len=2048,
                        kv_bytes_per_token=131072)
    ds = [decode_profile(f"d{i}", n_params=7e9, kv_len=4096,
                         kv_bytes_per_token=131072) for i in range(8)]
    mixed = round_time([p] + ds, dev, w)
    split = round_time([p], dev, w) + round_time(ds, dev, w)
    assert mixed < split


# --------------------------------------------------------------------------
# elastic restart: checkpoint saved on one mesh restores onto another
# --------------------------------------------------------------------------

def test_elastic_checkpoint_reshard(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import restore_checkpoint, save_checkpoint
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    save_checkpoint(str(tmp_path), 3, tree)
    # "new cluster": a (1,1) mesh with explicit shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shard = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _ = restore_checkpoint(str(tmp_path), tree, shardings=shard)
    assert restored["w"].sharding.is_equivalent_to(shard["w"], 2)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


# --------------------------------------------------------------------------
# robustness: refined scheduler on random workloads (the paper's ">90th
# percentile" claim, generalised beyond its six hand-picked experiments)
# --------------------------------------------------------------------------

def test_refined_robust_on_random_workloads():
    import random
    from repro.core import GTX580
    from repro.core.resources import bs_kernel, ep_kernel, es_kernel, \
        sw_kernel
    rng = random.Random(42)
    pcts = []
    for trial in range(8):
        ks = []
        for i in range(5):
            fam = rng.choice([ep_kernel, bs_kernel, es_kernel, sw_kernel])
            ks.append(fam(f"k{i}", grid=rng.choice([16, 32, 48]),
                          shm=rng.choice([0, 8192, 16384])))
        _, t_ref = refined_schedule(ks, GTX580, budget=400)
        times = [simulate([ks[i] for i in p], GTX580)
                 for p in itertools.permutations(range(5))]
        pcts.append(percentile_rank(t_ref, times))
    assert sum(pcts) / len(pcts) >= 90.0, pcts
