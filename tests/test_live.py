"""Live incremental composition (PR 7): GreedyFrontier mechanics and
the ``SchedulerPolicy.composition="incremental"`` serving path.

* the frontier sink on ``greedy_order_dag`` records exactly the
  rounds the batch greedy returns;
* ``insert_chain`` places a chain's stages in strictly increasing
  rounds (the precedence invariant), ``remove`` retires them —
  including a leave-of-just-joined — and ``refresh`` swaps to drifted
  profile objects in place;
* engine level: ``composition="incremental"`` generates bit-identical
  tokens to ``"batch"`` across all three traced archs under join/leave
  churn, with slicing, with a forced drift-backstop rebuild, and in
  the untriggered (no-churn) case; the new counters surface in
  ``ScheduleCache.stats()``.
"""

import random

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.tpu import (decode_profile, make_serving_device,
                            prefill_profile)
from repro.graph.constrained import GreedyFrontier, greedy_order_dag
from repro.models import transformer as T
from repro.serve import Request, SchedulerPolicy, ServingEngine
from repro.slice import SlicePolicy

_TPU = make_serving_device()
ARCHS = ("qwen1.5-0.5b", "mixtral-8x7b", "deepseek-v2-236b")


# --------------------------------------------------------------------------
# frontier mechanics (no model, no engine)
# --------------------------------------------------------------------------

def _chain_profiles(rng: random.Random, tag: str, n: int):
    """One request-like chain: a prefill head and decode-ish stages."""
    out = []
    for i in range(n):
        if i == 0 and rng.random() < 0.5:
            it = prefill_profile(f"{tag}:p{i}", n_params=7e9,
                                 seq_len=rng.choice([128, 256, 512]),
                                 kv_bytes_per_token=131072)
        else:
            it = decode_profile(f"{tag}:d{i}", n_params=7e9,
                                kv_len=rng.randint(1, 4096),
                                kv_bytes_per_token=131072)
        out.append(it.profile())
    return out


def _chain_workload(rng: random.Random, n_chains: int):
    """Chain-structured DAG (the traced-serving shape: edges only
    within one chain)."""
    profs, edges = [], set()
    for c in range(n_chains):
        chain = _chain_profiles(rng, f"r{c}", rng.randint(1, 4))
        base = len(profs)
        profs.extend(chain)
        edges |= {(base + i, base + i + 1)
                  for i in range(len(chain) - 1)}
    return profs, edges


def _round_index_of(frontier: GreedyFrontier) -> dict:
    return {name: i for i, rd in enumerate(frontier.round_names())
            for name in rd}


def _assert_chain_order(frontier, chains):
    at = _round_index_of(frontier)
    for chain in chains:
        idxs = [at[p.name] for p in chain]
        assert idxs == sorted(idxs) and len(set(idxs)) == len(idxs), \
            (chain[0].name, idxs)


def test_frontier_sink_matches_greedy_rounds():
    for seed in range(8):
        rng = random.Random(seed)
        profs, edges = _chain_workload(rng, rng.randint(2, 6))
        f = GreedyFrontier(_TPU)
        sched = greedy_order_dag(profs, _TPU, edges=edges, frontier=f)
        assert f.round_names() == [rd.names for rd in sched.rounds]
        assert [p.name for p in f.order()] == [p.name
                                               for p in sched.order]


def test_frontier_insert_chain_keeps_precedence():
    for seed in range(6):
        rng = random.Random(100 + seed)
        profs, edges = _chain_workload(rng, 3)
        f = GreedyFrontier(_TPU)
        greedy_order_dag(profs, _TPU, edges=edges, frontier=f)
        new = _chain_profiles(rng, "rx", 3)
        f.insert_chain(new)
        names = {p.name for p in f.order()}
        assert names == {p.name for p in profs} | {p.name for p in new}
        _assert_chain_order(f, [new])


def test_frontier_remove_and_leave_of_just_joined():
    rng = random.Random(7)
    profs, edges = _chain_workload(rng, 3)
    f = GreedyFrontier(_TPU)
    greedy_order_dag(profs, _TPU, edges=edges, frontier=f)
    before = f.round_names()
    new = _chain_profiles(rng, "rx", 3)
    f.insert_chain(new)
    # leave-of-just-joined: retiring the chain restores the previous
    # membership; rounds the insert had extended re-fold their combs
    f.remove({p.name for p in new})
    assert {p.name for p in f.order()} == {p.name for p in profs}
    assert [rd for rd in f.round_names() if rd] == \
        [rd for rd in before if rd]
    # and the frontier is still extendable afterwards
    f.insert_chain(_chain_profiles(rng, "ry", 2))
    _assert_chain_order(f, [])


def test_frontier_refresh_swaps_drifted_profiles():
    rng = random.Random(11)
    profs, edges = _chain_workload(rng, 3)
    f = GreedyFrontier(_TPU)
    greedy_order_dag(profs, _TPU, edges=edges, frontier=f)
    drifted = {}
    for p in profs:
        if p.name.split(":")[1].startswith("d"):
            # the serving drift: decode kv one step longer
            it = decode_profile(p.name, n_params=7e9, kv_len=4097,
                                kv_bytes_per_token=131072)
            drifted[p.name] = it.profile()
    f.refresh(drifted)
    by_name = {p.name: p for p in f.order()}
    for name, p in drifted.items():
        assert by_name[name] is p
    f.insert_chain(_chain_profiles(rng, "rz", 2))  # still scoreable
    assert len(f.order()) == len(profs) + 2


# --------------------------------------------------------------------------
# serving: incremental == batch, bit for bit
# --------------------------------------------------------------------------

_PARAMS_CACHE: dict = {}


def _engine(arch, policy, device=None, max_len=32):
    cfg = get_config(arch, "smoke")
    if arch not in _PARAMS_CACHE:
        _PARAMS_CACHE[arch] = T.init(jax.random.PRNGKey(0), cfg)
    return ServingEngine(cfg, _PARAMS_CACHE[arch], max_len=max_len,
                         policy=policy, device=device)


def _churn_run(arch, composition, device=None, slice_policy=None,
               drift_tol=0.05):
    """Churny serving run: staggered arrivals with different lifetimes
    so requests join and leave the mix at different steps."""
    policy = SchedulerPolicy(kind="symbiotic", respect_deps=True,
                             composition=composition,
                             slice_policy=slice_policy,
                             replay_drift_tol=drift_tol)
    eng = _engine(arch, policy, device=device)
    rng = np.random.default_rng(0)
    eng.submit([Request(i, rng.integers(0, 128, size=4),
                        max_new_tokens=3 + i) for i in range(2)])
    late = [(2, [Request(10, rng.integers(0, 128, size=4),
                         max_new_tokens=2)]),
            (4, [Request(11, rng.integers(0, 128, size=4),
                         max_new_tokens=3)])]
    return eng.run(arrivals=late)


@pytest.mark.parametrize("arch", ARCHS)
def test_incremental_tokens_bit_identical_under_churn(arch):
    s_batch = _churn_run(arch, "batch")
    s_inc = _churn_run(arch, "incremental")
    assert s_inc["outputs"] == s_batch["outputs"]
    stats = s_inc["schedule_cache"]
    # churn exercised the frontier: phase changes and arrivals join,
    # finished requests leave
    assert stats["incremental_joins"] >= 1
    assert stats["incremental_leaves"] >= 1


def test_incremental_leave_of_just_joined_request():
    """A request that joins and finishes almost immediately (one
    decode step after its prefill) must retire cleanly from the live
    frontier."""
    def run(composition):
        policy = SchedulerPolicy(kind="symbiotic", respect_deps=True,
                                 composition=composition)
        eng = _engine("qwen1.5-0.5b", policy)
        rng = np.random.default_rng(1)
        eng.submit([Request(i, rng.integers(0, 128, size=4),
                            max_new_tokens=6) for i in range(2)])
        blip = [(2, [Request(9, rng.integers(0, 128, size=4),
                             max_new_tokens=1)])]
        return eng.run(arrivals=blip)

    s_batch = run("batch")
    s_inc = run("incremental")
    assert s_inc["outputs"] == s_batch["outputs"]
    assert len(s_inc["outputs"][9]) >= 1


def test_untriggered_incremental_matches_batch():
    """No churn at all (one cohort, equal lifetimes): the incremental
    path must be bit-identical to batch — the property pin that the
    frontier machinery is invisible when nothing exercises it."""
    def run(composition):
        policy = SchedulerPolicy(kind="symbiotic", respect_deps=True,
                                 composition=composition)
        eng = _engine("qwen1.5-0.5b", policy)
        rng = np.random.default_rng(2)
        eng.submit([Request(i, rng.integers(0, 128, size=4),
                            max_new_tokens=4) for i in range(3)])
        return eng.run()

    s_batch = run("batch")
    s_inc = run("incremental")
    assert s_inc["outputs"] == s_batch["outputs"]
    assert s_inc["total_new_tokens"] == s_batch["total_new_tokens"]


def test_incremental_drift_backstop_rebuilds():
    """With a hair-trigger drift tolerance the kv growth between
    steps forces cold rebuilds — counted, and still bit-identical."""
    s_batch = _churn_run("qwen1.5-0.5b", "batch")
    s_inc = _churn_run("qwen1.5-0.5b", "incremental", drift_tol=1e-9)
    assert s_inc["outputs"] == s_batch["outputs"]
    assert s_inc["schedule_cache"]["frontier_rebuilds"] >= 1


def test_incremental_with_slicing_tokens_identical():
    """Slice-aware live joins (``frontier_solo_expander``): a shrunken
    slot budget makes prefill stages oversized so cutting genuinely
    triggers on both paths; tokens stay bit-identical."""
    dev = make_serving_device(token_budget=6)
    s_batch = _churn_run("qwen1.5-0.5b", "batch", device=dev,
                         slice_policy=SlicePolicy())
    s_inc = _churn_run("qwen1.5-0.5b", "incremental", device=dev,
                       slice_policy=SlicePolicy())
    assert s_inc["outputs"] == s_batch["outputs"]


def test_incremental_fifo_kind_passes_through():
    """kind="fifo" has no composition to keep live: the incremental
    engine serves dep-aware arrival order exactly like batch."""
    def run(composition):
        policy = SchedulerPolicy(kind="fifo", respect_deps=True,
                                 composition=composition)
        eng = _engine("qwen1.5-0.5b", policy)
        rng = np.random.default_rng(3)
        eng.submit([Request(i, rng.integers(0, 128, size=4),
                            max_new_tokens=3) for i in range(2)])
        return eng.run()

    s_batch = run("batch")
    s_inc = run("incremental")
    assert s_inc["outputs"] == s_batch["outputs"]
    assert s_inc["modelled_time_s"] == pytest.approx(
        s_batch["modelled_time_s"])


def test_gated_guard_reuses_checkpoints_across_candidates():
    """PR 7 satellite: with ``dag_guard="gated"`` the per-step guard
    delta-evaluates same-kernel-set candidates against the first full
    simulation's checkpoints instead of re-simulating from scratch;
    the saved full-sim equivalents surface in stats, and tokens are
    unaffected."""
    def run(guard):
        policy = SchedulerPolicy(kind="symbiotic", respect_deps=True,
                                 dag_guard=guard, cache=False)
        eng = _engine("qwen1.5-0.5b", policy)
        rng = np.random.default_rng(4)
        eng.submit([Request(i, rng.integers(0, 128, size=4),
                            max_new_tokens=3) for i in range(3)])
        return eng.run()

    s_rounds = run("rounds")
    s_gated = run("gated")
    assert s_gated["outputs"] == s_rounds["outputs"]
    assert s_gated["schedule_cache"]["gated_sims_saved"] > 0.0
    assert s_rounds["schedule_cache"]["gated_sims_saved"] == 0.0
