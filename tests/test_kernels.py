"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret
mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref

_RTOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dt):
    return _RTOL[jnp.bfloat16 if dt == jnp.bfloat16 else jnp.float32]


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 2, 2, 64),
    (2, 256, 4, 2, 128),
    (1, 512, 4, 1, 80),     # non-128 head_dim -> padded path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96),
                                           (False, None)])
def test_flash_attention(B, S, H, Hkv, D, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    g = H // Hkv
    kf = jnp.repeat(k, g, 2).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = jnp.repeat(v, g, 2).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    expect = ref.flash_attention_ref(
        qf, kf, vf, scale=1.0 / np.sqrt(D), causal=causal, window=window)
    expect = expect.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype))


@pytest.mark.parametrize("B,H,Hkv,T,D", [
    (2, 4, 2, 512, 64),
    (1, 2, 2, 1024, 128),
    (3, 4, 1, 256, 80),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, Hkv, T, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    out = ops.decode_attention(q, k, v, lengths, interpret=True)
    g = H // Hkv
    kf = jnp.repeat(k, g, 2).transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vf = jnp.repeat(v, g, 2).transpose(0, 2, 1, 3).reshape(B * H, T, D)
    qf = q.reshape(B * H, 1, D)
    lens = jnp.repeat(lengths[:, None], H, 1).reshape(B * H, 1)
    expect = ref.decode_attention_ref(qf, kf, vf, lens,
                                      scale=1.0 / np.sqrt(D))
    expect = expect.reshape(B, H, D)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype))


@pytest.mark.parametrize("R,D", [(64, 256), (256, 1024), (100, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(R, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    x = jax.random.normal(ks[0], (R, D), dtype)
    scale = jax.random.normal(ks[1], (D,), jnp.float32) * 0.1 + 1.0
    out = ops.rmsnorm(x, scale, interpret=True)
    expect = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype))


def test_flash_matches_model_attention():
    """Kernel agrees with the model's XLA blockwise path."""
    from repro.models.attention import blockwise_sdpa
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, Hkv, D = 2, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    out_k = ops.flash_attention(q, k, v, causal=True, interpret=True)
    out_x = blockwise_sdpa(q, k, v, scale=1.0 / np.sqrt(D), causal=True,
                           window=None, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_x),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,T,Dc,S", [(1, 64, 32, 8), (2, 128, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan(B, T, Dc, S, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    x = jax.random.normal(ks[0], (B, T, Dc), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, Dc), dtype)) * 0.1
    bm = jax.random.normal(ks[2], (B, T, S), dtype)
    cm = jax.random.normal(ks[3], (B, T, S), dtype)
    a = -jnp.exp(jax.random.normal(ks[4], (Dc, S), jnp.float32) * 0.3)
    d = jax.random.normal(ks[5], (Dc,), jnp.float32)
    out = ops.mamba_scan(x, dt, bm, cm, a, d, interpret=True)
    expect = ref.mamba_scan_ref(x, dt, bm, cm, a, d)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=_tol(dtype) * 2, atol=_tol(dtype) * 2)


def test_mamba_kernel_matches_model_layer():
    """The Pallas scan agrees with the model's chunked lax.scan path."""
    from repro.models.common import ModelConfig
    from repro.models.ssm import Mamba
    cfg = ModelConfig(name="m", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=4, head_dim=8, d_ff=64, vocab=64,
                      block_pattern=("mamba",), mamba_d_state=8,
                      dtype="float32")
    p = Mamba.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    y_model = Mamba.fwd(p, cfg, x)
    # rebuild the scan inputs exactly as Mamba.fwd does
    import jax.numpy as jnp
    from repro.models.common import dense
    xz = dense(p["w_in"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    dc = cfg.mamba_d_conv
    Sq = x.shape[1]
    pad = jnp.pad(xi, ((0, 0), (dc - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + Sq, :] * p["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(conv + p["conv_b"])
    dt, Bm, Cm = Mamba._dbc(p, cfg, xc)
    A = -jnp.exp(p["a_log"])
    y = ops.mamba_scan(xc, dt, Bm, Cm, A, p["d_skip"], interpret=True)
    y = y * jax.nn.silu(z)
    y = dense(p["w_out"], y)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_model),
                               rtol=2e-4, atol=2e-4)
