"""Seeded property-test harness shim (PR 10).

The toolchain pins no ``hypothesis`` build (carried-over ROADMAP
item), so property-style tests here use plain :mod:`random` under a
seeded ``@cases`` decorator.  Bodies are written hypothesis-shaped —
they take a single ``rng`` argument and draw everything from it — so
they port directly when the pin lands.

Porting map (``proptest`` → ``hypothesis``)::

    @cases(n=50, seed=7)              @settings(max_examples=50,
    def test_x(rng):              →              derandomize=True)
        k = rng.randint(1, 9)         @given(rng=st.randoms(
        ...                               use_true_random=False))
                                      def test_x(rng):
                                          k = rng.randint(1, 9)
                                          ...

i.e. ``cases(n=N)`` ≙ ``settings(max_examples=N)``; the injected
seeded ``random.Random`` ≙ ``st.randoms()``; per-case seeds are
derived deterministically from ``seed`` so failures reproduce by
case index (the decorator reports the failing case's seed, the
counterpart of hypothesis' falsifying-example output).  Draws inside
bodies already use only the ``random.Random`` API surface
(``randint`` / ``randrange`` / ``random`` / ``choice`` / ``shuffle``)
that ``st.randoms()`` provides.

Not collected by pytest (no ``test_`` prefix); import it from test
modules: ``from proptest import cases``.
"""

from __future__ import annotations

import functools
import inspect
import random

__all__ = ["cases", "case_seed"]

#: multiplier separating per-suite seed streams; any odd constant
#: works, a large prime keeps neighbouring suites' streams disjoint.
_SEED_STRIDE = 1_000_003


def case_seed(seed: int, i: int) -> int:
    """The derived seed of case ``i`` under base ``seed`` — exposed so
    a failing case can be re-run standalone."""
    return seed * _SEED_STRIDE + i


def cases(n: int = 25, seed: int = 0):
    """Run the decorated test body ``n`` times, each with a fresh
    deterministically-seeded ``random.Random`` passed as ``rng``.

    On failure, re-raises with the case index and derived seed
    prepended so the case reproduces standalone via
    ``random.Random(case_seed(seed, i))``.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            for i in range(n):
                s = case_seed(seed, i)
                try:
                    fn(*args, rng=random.Random(s), **kw)
                except AssertionError as e:
                    raise AssertionError(
                        f"case {i}/{n} (seed {s}): {e}") from e
        # hide ``rng`` from pytest's fixture resolution (hypothesis'
        # @given does the same for its injected arguments)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name != "rng"])
        del wrapper.__wrapped__
        return wrapper
    return deco
