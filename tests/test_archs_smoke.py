"""Per-architecture smoke tests: reduced config of the same family,
one forward + one train step on CPU, asserting shapes and no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import arch_names, get_config
from repro.models import transformer as T


def _batch_for(cfg, key, B=2, S=16):
    if cfg.input_mode == "tokens":
        return jax.random.randint(key, (B, S), 0, cfg.vocab)
    return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch", arch_names())
def test_smoke_forward(arch):
    cfg = get_config(arch, "smoke")
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    batch = _batch_for(cfg, key)
    logits, aux = jax.jit(lambda p, b: T.forward(p, cfg, b))(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite logits"
    for k, v in aux.items():
        assert jnp.isfinite(v), f"{arch}: non-finite aux {k}"


@pytest.mark.parametrize("arch", arch_names())
def test_smoke_train_step(arch):
    cfg = get_config(arch, "smoke")
    key = jax.random.PRNGKey(1)
    params = T.init(key, cfg)
    batch = _batch_for(cfg, key)
    if cfg.input_mode == "tokens":
        labels = jnp.roll(batch, -1, axis=1)
    else:
        labels = jax.random.randint(key, batch.shape[:2], 0, cfg.vocab)

    def loss_fn(p):
        logits, aux = T.forward(p, cfg, batch)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)
        loss = -jnp.mean(ll)
        return loss + 0.01 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    flat = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in flat), f"{arch}: NaN grads"
    # One SGD step changes the loss (sanity that grads are non-trivial).
    new_params = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2 = jax.jit(loss_fn)(new_params)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", [a for a in arch_names()
                                  if get_config(a, "smoke").causal])
def test_smoke_decode(arch):
    cfg = get_config(arch, "smoke")
    key = jax.random.PRNGKey(2)
    params = T.init(key, cfg)
    B = 2
    cache = T.init_cache(cfg, B, 8)
    if cfg.input_mode == "tokens":
        tok = jnp.zeros((B,), jnp.int32)
    else:
        tok = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
    step = jax.jit(lambda p, t, c, s: T.decode_step(p, cfg, t, c, s))
    logits, cache = step(params, tok, cache, 0)
    assert logits.shape == (B, cfg.vocab)
    assert jnp.isfinite(logits).all()
    logits, cache = step(params, tok, cache, 1)
    assert jnp.isfinite(logits).all()
