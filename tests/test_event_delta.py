"""Property tests for the checkpointable EventSimulator and the
event-model delta evaluator: exact float equality between suffix
re-simulation and full re-simulation, checkpoint interchangeability
between the reference and fast implementations, the cohort same-instant
invariant, and the oversized-block consistency pin against the round
model.

Written with plain ``random`` (no hypothesis dependency in the pinned
toolchain) over seeded draws, so failures reproduce exactly.
"""

import random

import pytest

from repro.core import (GTX580, DeviceModel, EventSimulator, KernelProfile,
                        RoundSimulator, simulate)
from repro.core.refine import DeltaEvaluator, _FastEventSim, refine_order
from repro.core.resources import bs_kernel, ep_kernel, es_kernel, sw_kernel
from repro.core.tpu import (decode_profile, make_serving_device,
                            prefill_profile)

_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]
_TPU = make_serving_device()


def _gpu_kernels(rng: random.Random, n: int) -> list[KernelProfile]:
    return [rng.choice(_FAMS)(f"k{i}",
                              grid=rng.choice([8, 16, 32, 48, 64, 96]),
                              shm=rng.choice([0, 4096, 8192, 16384, 24576]),
                              inst=rng.uniform(1e6, 5e8))
            for i in range(n)]


def _tpu_profiles(rng: random.Random, n: int) -> list[KernelProfile]:
    items = []
    for i in range(n):
        if rng.random() < 0.4:
            items.append(prefill_profile(
                f"p{i}", n_params=7e9,
                seq_len=rng.choice([128, 256, 512, 1024]),
                kv_bytes_per_token=131072))
        else:
            items.append(decode_profile(
                f"d{i}", n_params=7e9, kv_len=rng.randint(1, 8192),
                kv_bytes_per_token=131072))
    return [it.profile() for it in items]


def _adversarial(rng: random.Random, n: int) -> list[KernelProfile]:
    """Profiles engineered to hit the simulator's edge paths: oversized
    blocks (degenerate solo execution), near-capacity fits, extreme
    intensities spanning 12 orders of magnitude, and single-block
    grids."""
    ks = []
    for i in range(n):
        roll = rng.random()
        if roll < 0.2:
            # oversized in one dimension: forces the degenerate path
            dem = {"shm": rng.choice([49152.0, 96000.0]),
                   "reg": rng.uniform(100, 3000.0), "warp": 4.0}
        elif roll < 0.4:
            # exactly at capacity: fits alone, nothing else joins
            dem = {"shm": 48 * 1024.0, "reg": 1024.0, "warp": 48.0}
        else:
            dem = {"shm": rng.choice([0.0, 8192.0]),
                   "reg": rng.uniform(512, 8192.0),
                   "warp": float(rng.choice([1, 4, 8, 16]))}
        ks.append(KernelProfile(
            f"a{i}", n_blocks=rng.choice([1, 3, 7, 17, 33]),
            demands=dem, inst_per_block=rng.uniform(1e2, 1e9),
            r=rng.choice([1e-6, 0.5, 4.0, 1e6])))
    return ks


def _moves(rng: random.Random, ks: list, n_moves: int):
    n = len(ks)
    for _ in range(n_moves):
        i, j = rng.randrange(n), rng.randrange(n)
        if i == j:
            continue
        cand = list(ks)
        cand[i], cand[j] = cand[j], cand[i]
        yield cand, min(i, j)
        cand = list(ks)
        cand.insert(j, cand.pop(i))
        yield cand, min(i, j)


# --------------------------------------------------------------------------
# fast event sim == reference event sim (full runs)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU, _tpu_profiles),
                                          (GTX580, _adversarial)])
def test_fast_event_sim_matches_reference(device, maker):
    rng = random.Random(13)
    fast = _FastEventSim(device)
    ref = EventSimulator(device)
    for _ in range(15):
        ks = maker(rng, rng.randint(1, 20))
        assert fast.simulate(ks)[0] == ref.simulate(ks)


# --------------------------------------------------------------------------
# checkpoint resume == full simulation, both implementations, both ways
# --------------------------------------------------------------------------

@pytest.mark.parametrize("maker", [_gpu_kernels, _adversarial])
def test_checkpoint_resume_equals_full(maker):
    rng = random.Random(7)
    ref = EventSimulator(GTX580)
    fast = _FastEventSim(GTX580)
    for _ in range(8):
        ks = maker(rng, rng.randint(2, 14))
        n = len(ks)
        t_full = ref.simulate(ks)
        _, ref_ck = ref.simulate(ks, record=True)
        t_fast, fast_ck = fast.simulate(ks, record=True)
        assert t_fast == t_full
        assert [c.pos for c in ref_ck] == list(range(n))
        assert [c.pos for c in fast_ck] == list(range(n))
        for p in {0, n // 2, n - 1}:
            # resume from own checkpoints
            assert ref.simulate(ks, start_state=ref_ck[p]) == t_full
            assert fast.simulate(ks, start_state=fast_ck[p])[0] == t_full
            # checkpoints are interchangeable between implementations
            assert ref.simulate(ks, start_state=fast_ck[p]) == t_full
            assert fast.simulate(ks, start_state=ref_ck[p])[0] == t_full


# --------------------------------------------------------------------------
# delta evaluation == full re-simulation (exact), randomized + adversarial
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device,maker", [(GTX580, _gpu_kernels),
                                          (_TPU, _tpu_profiles),
                                          (GTX580, _adversarial)])
def test_event_delta_equals_full_resimulation(device, maker):
    rng = random.Random(5)
    ref = EventSimulator(device)
    for _ in range(10):
        ks = maker(rng, rng.randint(2, 18))
        ev = DeltaEvaluator(device, model="event")
        ev.rebase(ks)
        for cand, first in _moves(rng, ks, 12):
            assert ev.evaluate(cand, first) == ref.simulate(cand)


def test_event_delta_costs_suffix_fraction():
    rng = random.Random(2)
    ks = _gpu_kernels(rng, 16)
    ev = DeltaEvaluator(GTX580, model="event")
    ev.rebase(ks)
    cand = list(ks)
    cand[14], cand[15] = cand[15], cand[14]
    t, frac = ev.evaluate_costed(cand, 14)
    assert t == EventSimulator(GTX580).simulate(cand)
    assert frac == pytest.approx(2 / 16)
    # event model: every position is an admission boundary
    assert ev.boundaries() is None


@pytest.mark.slow
def test_event_delta_equals_full_resimulation_n512():
    """Large-n sweep (serving-scale order): suffix re-simulation stays
    bit-exact at n = 512."""
    rng = random.Random(11)
    ks = _gpu_kernels(rng, 512)
    ev = DeltaEvaluator(GTX580, model="event")
    ev.rebase(ks)
    ref = EventSimulator(GTX580)
    for p in (511, 400, 256):
        cand = list(ks)
        cand[p - 1], cand[p] = cand[p], cand[p - 1]
        assert ev.evaluate(cand, p - 1) == ref.simulate(cand)


# --------------------------------------------------------------------------
# refine_order(model="event") delta path
# --------------------------------------------------------------------------

def test_refine_event_delta_matches_reference_trajectory():
    """With the full move set the event delta path retraces the
    full-evaluation trajectory exactly (same moves, equal times)."""
    rng = random.Random(9)
    for _ in range(5):
        ks = _gpu_kernels(rng, rng.randint(3, 9))
        sim = EventSimulator(GTX580)
        o_ref, t_ref, _ = refine_order(
            ks, GTX580, time_fn=sim.simulate, budget=2000,
            neighborhood="full")
        o_fast, t_fast, _ = refine_order(
            ks, GTX580, model="event", budget=2000, neighborhood="full")
        assert t_fast == t_ref
        assert [k.name for k in o_fast] == [k.name for k in o_ref]


def test_refine_event_never_worse_and_exact():
    rng = random.Random(3)
    for neighborhood in ("full", "adjacent", "auto"):
        ks = _gpu_kernels(rng, 12)
        t0 = EventSimulator(GTX580).simulate(ks)
        order, t, _ = refine_order(ks, GTX580, model="event", budget=60,
                                   neighborhood=neighborhood)
        assert t <= t0 + 1e-15
        # the returned time is the true event-model time, exactly
        assert t == EventSimulator(GTX580).simulate(order)


# --------------------------------------------------------------------------
# satellite regressions
# --------------------------------------------------------------------------

def test_cohort_merge_same_instant_only():
    """A block admitted at a later instant must not merge into an old
    cohort whose progress underflowed to zero (frac_left still exactly
    1.0): cohorts are tagged with their admission instant.

    Scenario: a glacially slow kernel B holds unit 0 at frac_left ==
    1.0 while a fast kernel F completes on unit 1; the queue head X
    only fits after F frees unit 1, which unblocks a second B block
    onto unit 0 at t > 0.  The checkpoint captured when the trailing
    sentinel is first examined must show two separate B cohorts with
    distinct admission instants.
    """
    dev = DeviceModel(name="tiny", n_units=2, caps={"s": 4.0},
                      max_resident=8, compute_rate=1e9, mem_bw=1e9,
                      r_balanced=1.0)
    B = KernelProfile("B", n_blocks=1, demands={"s": 2.0},
                      inst_per_block=1e30, r=1e9)
    F = KernelProfile("F", n_blocks=1, demands={"s": 4.0},
                      inst_per_block=1e6, r=1e9)
    X = KernelProfile("X", n_blocks=1, demands={"s": 4.0},
                      inst_per_block=1e6, r=1e9)
    S = KernelProfile("S", n_blocks=1, demands={"s": 4.0},
                      inst_per_block=1e6, r=1e9)  # trailing sentinel
    order = [B, F, X, B, S]
    for sim_cls in (EventSimulator, _FastEventSim):
        sim = sim_cls(dev)
        out = sim.simulate(order, record=True)
        ckpts = out[1]
        cp = ckpts[4]  # sentinel S: examined right after B#2 placed
        unit0 = cp.units[0]
        b_cohorts = [c for c in unit0[2] if c[0] is B]
        assert len(b_cohorts) == 2, (
            "cross-instant blocks must form separate cohorts")
        (k1, n1, f1, t1), (k2, n2, f2, t2) = b_cohorts
        assert n1 == n2 == 1
        assert f1 == 1.0  # old cohort's progress underflowed
        assert t1 == 0.0 and t2 > 0.0  # distinct admission instants


def test_oversized_block_event_matches_round_exactly():
    """The degenerate oversized-block path charges ceil(n_blocks /
    n_units) occupancy-adjusted solo passes — the same float
    accumulation as RoundSimulator's forced single-block rounds."""
    dev = DeviceModel(name="occ", n_units=2,
                      caps={"s": 4.0, "w": 8.0}, max_resident=4,
                      compute_rate=1e9, mem_bw=1e9, r_balanced=1.0,
                      sat_dim="w", sat_compute=4.0, sat_memory=8.0)
    for nb in (1, 2, 5, 7):
        k = KernelProfile("big", n_blocks=nb,
                          demands={"s": 8.0, "w": 2.0},
                          inst_per_block=3e8, r=2.0)
        t_event = EventSimulator(dev).simulate([k])
        t_round = RoundSimulator(dev).simulate([k])
        assert t_event == t_round
        t_fast = _FastEventSim(dev).simulate([k])[0]
        assert t_fast == t_event
    # occupancy adjustment is applied (w=2 of sat_memory=8 -> mem eff
    # 0.25): a single block must take longer than its raw roofline
    k = KernelProfile("big", n_blocks=1, demands={"s": 8.0, "w": 2.0},
                      inst_per_block=3e8, r=2.0)
    raw = max(k.inst_per_block / dev.compute_rate,
              k.mem_per_block() / dev.mem_bw)
    assert EventSimulator(dev).simulate([k]) > raw


def test_oversized_mixed_with_normal_kernels_consistent():
    """Orders mixing oversized and normal kernels stay exactly equal
    between the reference and fast event sims, and delta-evaluate
    exactly."""
    rng = random.Random(21)
    big = KernelProfile("big", n_blocks=5,
                        demands={"shm": 96000.0, "reg": 512.0, "warp": 4.0},
                        inst_per_block=1e8, r=4.0)
    for _ in range(5):
        ks = _gpu_kernels(rng, 6) + [big]
        rng.shuffle(ks)
        ref = EventSimulator(GTX580)
        assert _FastEventSim(GTX580).simulate(ks)[0] == ref.simulate(ks)
        ev = DeltaEvaluator(GTX580, model="event")
        ev.rebase(ks)
        for cand, first in _moves(rng, ks, 6):
            assert ev.evaluate(cand, first) == ref.simulate(cand)


def test_event_sim_sat_dim_configs_match_reference():
    """Event model under the three sat_dim configurations (in caps,
    empty, set-but-untracked): fast == reference exactly, and the
    untracked config runs at peak efficiency rather than degrading to
    ~0 (the DeviceModel audit fix)."""
    rng = random.Random(31)
    base = dict(n_units=4, caps={"a": 100.0, "b": 50.0}, max_resident=4,
                compute_rate=1e9, mem_bw=1e9, r_balanced=2.0)
    devs = [DeviceModel(name="insat", sat_dim="a", sat_compute=30.0,
                        sat_memory=80.0, **base),
            DeviceModel(name="nosat", **base),
            DeviceModel(name="oddsat", sat_dim="zz", sat_compute=30.0,
                        sat_memory=80.0, **base)]
    ks = [KernelProfile(f"k{i}", n_blocks=rng.randint(1, 8),
                        demands={"a": rng.uniform(1, 40),
                                 "b": rng.uniform(1, 20)},
                        inst_per_block=rng.uniform(1e5, 1e7),
                        r=rng.uniform(0.5, 8.0)) for i in range(10)]
    for dev in devs:
        assert (_FastEventSim(dev).simulate(ks)[0]
                == EventSimulator(dev).simulate(ks))
    # untracked sat_dim == no occupancy model (not ~1e12x slower)
    assert (simulate(ks, devs[2], model="event")
            == simulate(ks, devs[1], model="event"))
