"""Docs-freshness check (ISSUE 5 satellite): every ``repro.*`` dotted
name mentioned in ``docs/*.md`` (and the README) must resolve against
the live package — import the longest importable module prefix, then
getattr-walk the remainder — and every mentioned repo-relative file
path must exist.  Renaming a module, function or benchmark without
updating the docs fails here instead of silently rotting them.
"""

import importlib
import os
import re

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_DOCS = [os.path.join(_ROOT, "README.md")] + sorted(
    os.path.join(_ROOT, "docs", f)
    for f in (os.listdir(os.path.join(_ROOT, "docs"))
              if os.path.isdir(os.path.join(_ROOT, "docs")) else [])
    if f.endswith(".md"))

#: dotted repro names, e.g. ``repro.graph.delta.GatedDeltaEvaluator``
_DOTTED = re.compile(r"\brepro(?:\.[A-Za-z_]\w*)+")
#: repo-relative paths, e.g. ``benchmarks/dag.py``, ``docs/benchmarks.md``
_PATHS = re.compile(
    r"\b(?:benchmarks|tests|examples|docs|src)/[\w./-]+\.(?:py|md)\b")
#: committed benchmark artifacts, e.g. ``BENCH_dag.json``
_BENCH = re.compile(r"\bBENCH_\w+\.json\b")


def _docs():
    assert _DOCS, "docs suite missing"
    for path in _DOCS:
        with open(path, encoding="utf-8") as f:
            yield path, f.read()


def _resolve(dotted: str) -> None:
    """Import the longest importable module prefix of ``dotted``, then
    attribute-walk the rest.  Raises on any failure."""
    parts = dotted.split(".")
    last_err = None
    for k in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:k]))
        except ImportError as e:
            last_err = e
            continue
        for attr in parts[k:]:
            obj = getattr(obj, attr)  # raises AttributeError if stale
        return
    raise last_err or ImportError(dotted)


def test_docs_exist():
    names = {os.path.basename(p) for p in _DOCS}
    assert "README.md" in names
    for required in ("architecture.md", "paper_mapping.md",
                     "benchmarks.md"):
        assert required in names, f"docs/{required} missing"


def test_every_dotted_repro_name_resolves():
    failures = []
    for path, text in _docs():
        for dotted in sorted(set(_DOTTED.findall(text))):
            try:
                _resolve(dotted)
            except (ImportError, AttributeError) as e:
                failures.append(f"{os.path.basename(path)}: {dotted} "
                                f"({type(e).__name__}: {e})")
    assert not failures, "stale names in docs:\n" + "\n".join(failures)


def test_every_mentioned_path_exists():
    failures = []
    for path, text in _docs():
        for rel in sorted(set(_PATHS.findall(text))):
            if not os.path.exists(os.path.join(_ROOT, rel)):
                failures.append(f"{os.path.basename(path)}: {rel}")
        for rel in sorted(set(_BENCH.findall(text))):
            if not os.path.exists(os.path.join(_ROOT, rel)):
                failures.append(f"{os.path.basename(path)}: {rel}")
    assert not failures, "stale paths in docs:\n" + "\n".join(failures)


def test_architecture_names_cover_scheduling_packages():
    """architecture.md must keep naming every scheduling-layer module
    — the map is the doc's reason to exist."""
    text = dict(_docs())[os.path.join(_ROOT, "docs", "architecture.md")]
    for mod in ("repro.core.scheduler", "repro.core.fastscore",
                "repro.core.simulator", "repro.core.refine",
                "repro.core.tpu", "repro.graph.kernel_graph",
                "repro.graph.constrained", "repro.graph.streams",
                "repro.graph.delta", "repro.slice.slicer",
                "repro.slice.graph", "repro.slice.constrained",
                "repro.serve.engine", "repro.serve.composer",
                "repro.serve.cache", "repro.serve.live",
                "repro.serve.frontend", "repro.serve.loadgen",
                "repro.obs.trace", "repro.obs.metrics",
                "repro.obs.profile", "repro.obs.audit",
                "repro.obs.latency", "repro.obs.export"):
        assert mod in text, f"architecture.md no longer names {mod}"
