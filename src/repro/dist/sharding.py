"""Placement policies: parameter, batch and cache sharding specs.

One convention everywhere: the mesh axis named ``"model"`` is tensor
parallelism; every other axis is data parallelism (``"data"``, plus
``"pod"`` on multi-pod meshes).  Dimensions are only sharded when they
divide the axis size evenly, so no spec here ever introduces padding.

* ``param_specs(mode="train")`` — TP over ``model`` on the largest
  divisible dimension, then FSDP over the data axes on the largest
  remaining divisible dimension.
* ``param_specs(mode="serve")`` — TP-only *resident* weights (no
  per-layer all-gathers on the decode path).
* ``cache_specs`` — batch dimension over the data axes, one more
  divisible dimension (kv heads) over ``model``.
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "batch_spec",
    "named",
    "param_specs",
    "cache_specs",
    "serve_weights_resident",
]


def _dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _dp_entry(mesh):
    axes = _dp_axes(mesh)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _model_size(mesh) -> int:
    return int(mesh.shape.get("model", 1))


def _data_size(mesh) -> int:
    return math.prod(int(mesh.shape[a]) for a in _dp_axes(mesh))


def batch_spec(mesh) -> P:
    """PartitionSpec whose leading entry is the batch (data) sharding."""
    return P(_dp_entry(mesh))


def _is_spec(x) -> bool:
    return isinstance(x, P)


def named(mesh, spec_tree):
    """Map a tree of PartitionSpecs to NamedShardings on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=_is_spec)


def _leaf_spec(shape, *, msize: int, dsize: int, dp_entry,
               fsdp: bool) -> P:
    if not shape:
        return P()
    entries: list[Any] = [None] * len(shape)
    order = sorted(range(len(shape)), key=lambda i: shape[i], reverse=True)
    ti = None
    if msize > 1:
        ti = next((i for i in order if shape[i] % msize == 0), None)
        if ti is not None:
            entries[ti] = "model"
    if fsdp and dsize > 1:
        di = next((i for i in order
                   if i != ti and shape[i] % dsize == 0), None)
        if di is not None:
            entries[di] = dp_entry
    return P(*entries)


def param_specs(params, mesh, mode: str = "train"):
    """Tree of PartitionSpecs matching ``params`` (arrays or abstract
    ShapeDtypeStructs)."""
    msize, dsize = _model_size(mesh), _data_size(mesh)
    dp = _dp_entry(mesh)
    fsdp = mode == "train"

    def spec(leaf):
        return _leaf_spec(tuple(getattr(leaf, "shape", ()) or ()),
                          msize=msize, dsize=dsize, dp_entry=dp, fsdp=fsdp)

    return jax.tree.map(spec, params)


def cache_specs(cache, mesh):
    """KV/state cache specs: batch over data axes, kv-heads over model."""
    msize, dsize = _model_size(mesh), _data_size(mesh)
    dp = _dp_entry(mesh)

    def spec(leaf):
        shape = tuple(getattr(leaf, "shape", ()) or ())
        if not shape:
            return P()
        entries: list[Any] = [None] * len(shape)
        if dsize > 1 and shape[0] % dsize == 0:
            entries[0] = dp
        if msize > 1:
            order = sorted(range(1, len(shape)), key=lambda i: shape[i],
                           reverse=True)
            ti = next((i for i in order if shape[i] % msize == 0), None)
            if ti is not None:
                entries[ti] = "model"
        return P(*entries)

    return jax.tree.map(spec, cache)


def serve_weights_resident(params, mesh, *,
                           hbm_bytes_per_chip: float = 16 * 1024**3,
                           resident_frac: float = 0.5) -> bool:
    """True when TP-only (``mode="serve"``) weights fit resident per
    chip, i.e. the decode step may be unrolled without materialising
    per-layer FSDP all-gathers (see :mod:`repro.launch.dryrun`)."""
    msize = _model_size(mesh)

    def leaf_bytes(leaf) -> float:
        shape = tuple(getattr(leaf, "shape", ()) or ())
        dtype = getattr(leaf, "dtype", None)
        item = jax.numpy.dtype(dtype).itemsize if dtype is not None else 4
        n = math.prod(shape) if shape else 1
        if msize > 1 and any(s % msize == 0 for s in shape):
            n //= msize
        return float(n * item)

    total = sum(leaf_bytes(l) for l in jax.tree.leaves(params))
    return total <= resident_frac * hbm_bytes_per_chip
