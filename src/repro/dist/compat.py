"""Aliases for JAX API drift between the pinned 0.4.x and >=0.5.

The codebase is written against the consolidated surface:

* ``jax.set_mesh(mesh)`` used as a context manager, and
* ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``.

On 0.4.x the same functionality exists as the ``Mesh`` context manager
and ``jax.experimental.shard_map.shard_map`` (whose replication check
is spelled ``check_rep``).  ``install()`` adds thin aliases when the
attributes are missing; on a new-enough JAX it is a no-op.
"""

from __future__ import annotations

import jax

__all__ = ["install"]


def install() -> None:
    if not hasattr(jax, "set_mesh"):
        def set_mesh(mesh):
            # ``Mesh`` is itself a context manager on 0.4.x; entering it
            # installs the resource environment the way set_mesh does.
            return mesh

        jax.set_mesh = set_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        _UNSET = object()

        def shard_map(f, *, mesh=None, in_specs=None, out_specs=None,
                      check_vma=_UNSET, **kwargs):
            check_rep = kwargs.pop("check_rep", check_vma)
            if check_rep is _UNSET:
                # Both the 0.4.x check_rep and the modern check_vma
                # default to True — preserve that when unspecified.
                check_rep = True
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs,
                              check_rep=bool(check_rep), **kwargs)

        jax.shard_map = shard_map
