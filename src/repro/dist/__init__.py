"""Distribution substrate: mesh-logical activation axes + placement.

``repro.dist.context`` binds the *logical* activation axes model code
references ("dp", "tp") to concrete mesh axes; ``repro.dist.sharding``
holds the placement policies (parameter, batch and cache specs) the
launchers feed to ``jax.jit``.  Importing the package installs the
small compatibility aliases (:mod:`repro.dist.compat`) that let the
codebase target the modern ``jax.set_mesh`` / ``jax.shard_map`` API on
the pinned 0.4.x toolchain.
"""

from . import compat as _compat

_compat.install()

from . import context, sharding  # noqa: E402

__all__ = ["context", "sharding"]
