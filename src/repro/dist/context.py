"""Logical activation axes bound to concrete mesh axes per process.

Model code never names mesh axes directly: it constrains activations
against the *logical* axes ``"dp"`` (batch/data parallel — possibly a
tuple of mesh axes) and ``"tp"`` (tensor/model parallel), and the
launcher binds those once via :func:`set_activation_axes`.  With no
binding in place every :func:`constrain` is the identity, so the same
model code runs unsharded (CPU tests, the serving engine, eval
scripts) without carrying mesh plumbing.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from . import compat as _compat

_compat.install()

__all__ = [
    "set_activation_axes",
    "activation_axes",
    "mesh",
    "dp_size",
    "tp_size",
    "constrain",
    "act_ctx",
]

_state = threading.local()


def _get() -> dict[str, Any]:
    if not hasattr(_state, "v"):
        _state.v = {"dp": None, "tp": None, "mesh": None}
    return _state.v


def set_activation_axes(*, dp=None, tp=None, mesh=None) -> None:
    """Bind (or clear, with all-None) the logical activation axes.

    ``dp`` may be a single mesh-axis name or a tuple of names (multi-pod
    data parallelism); ``tp`` is a single mesh-axis name.
    """
    s = _get()
    s["dp"], s["tp"], s["mesh"] = dp, tp, mesh


def activation_axes() -> tuple[Any, Any]:
    s = _get()
    return s["dp"], s["tp"]


def mesh():
    return _get()["mesh"]


def _axis_size(m, ax) -> int:
    if m is None or ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= int(m.shape[a])
        return n
    return int(m.shape[ax])


def dp_size() -> int:
    s = _get()
    return _axis_size(s["mesh"], s["dp"])


def tp_size() -> int:
    s = _get()
    return _axis_size(s["mesh"], s["tp"])


def _resolve(entry):
    s = _get()
    if entry == "dp":
        return s["dp"]
    if entry == "tp":
        return s["tp"]
    return entry


def constrain(x, axes: Sequence[Any]):
    """``with_sharding_constraint`` against logical axes; identity when
    no mesh is bound (or every resolved entry is None)."""
    m = _get()["mesh"]
    if m is None:
        return x
    resolved = tuple(_resolve(e) for e in axes)
    if all(e is None for e in resolved):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*resolved)))


@contextmanager
def act_ctx(*, dp=None, tp=None, mesh=None):
    """Scoped :func:`set_activation_axes` (restores the previous binding)."""
    s = _get()
    prev = (s["dp"], s["tp"], s["mesh"])
    set_activation_axes(dp=dp, tp=tp, mesh=mesh)
    try:
        yield
    finally:
        s["dp"], s["tp"], s["mesh"] = prev
