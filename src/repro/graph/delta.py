"""Gated-event delta evaluation: suffix re-simulation for DAG orders.

PR 3/PR 4 gave dependency-carrying schedules their own makespan
currency — the ready-set gated dispatcher
(:class:`repro.graph.streams.DagEventSimulator`) — but the local
search (:func:`repro.graph.constrained.refine_order_dag`,
:func:`repro.slice.constrained.refine_order_slices`) could only
delta-evaluate the *ungated* event model.  Refined orders therefore
had to fall back to the greedy whenever the gated currency disagreed
with the ungated proxy, which on traced-arch workloads was nearly
always (the gate serializes every intra-request chain, a constraint
the proxy never sees).  This module closes that gap — mirroring ACS
(arXiv:2401.12377): scheduling decisions on irregular dependency
graphs must be evaluated in the dependency-aware cost model itself:

* :class:`_FastGatedSim` — an operation-for-operation port of
  ``DagEventSimulator`` over flat tuples (the same technique
  :class:`repro.core.refine._FastEventSim` applies to
  ``EventSimulator``), bit-identical in its float accumulation and
  checkpoint-interchangeable with the reference.  Both produce and
  consume the plain :class:`~repro.core.simulator.EventCheckpoint`:
  the gate's retired-block state is *derived* on resume (a kernel
  before the resume position has retired ``grid - blocks still in
  cohorts``), so no gated-specific checkpoint type is needed.
* :class:`GatedDeltaEvaluator` — the
  :class:`repro.core.refine.DeltaEvaluator` discipline (one
  checkpoint per order position, candidate cost charged as the suffix
  fraction) under the gated model.  Moves that would invert a
  precedence edge are rejected *before* any simulation
  (:meth:`GatedDeltaEvaluator.legal`, the same O(n + E) position-map
  scan ``refine_order_dag`` applies); legal candidates resume from
  the latest checkpoint at suffix cost.

Exactness is property-tested in ``tests/test_gated_delta.py``:
suffix re-simulation equals full gated re-simulation float-for-float
on randomized DAGs, slice/join graphs (zero-work join markers) and
the 0-edge degeneration, where the gated pipeline reproduces the
ungated ``EventSimulator`` identity.

The batched evaluator (:mod:`repro.core.batched`, reached through
``refine_order_dag(..., batch_size=...)``) scores legal gated
candidates in vectorized lockstep from this module's checkpoints and
re-verifies every acceptance through :class:`GatedDeltaEvaluator`, so
the batched trajectory stays in this exact currency.
"""

from __future__ import annotations

import math
from typing import Sequence

from repro.core.refine import DeltaEvaluator
from repro.core.resources import DeviceModel, KernelProfile
from repro.core.simulator import EventCheckpoint

__all__ = ["GatedDeltaEvaluator", "_FastGatedSim"]


class _FastGatedSim:
    """DagEventSimulator with per-kernel profile data precomputed once.

    Bit-identical arithmetic to
    :class:`repro.graph.streams.DagEventSimulator` — the same
    operations on the same floats in the same order — over flat tuples
    instead of demand dicts and dataclasses, exactly as
    :class:`repro.core.refine._FastEventSim` ports ``EventSimulator``.
    Unit state is a list ``[used, n_resident, cohorts, lam]``; a cohort
    is ``[kernel, n_blocks, frac_left, t_admit, inst_per_block,
    mem_per_block, demands, inst * n_blocks, mem * n_blocks]``.  The
    ready-set gate keys per-kernel retired-block counts by object
    identity; zero-work kernels (slice join markers) retire instantly
    without occupying a unit.  Produces and consumes the same
    :class:`EventCheckpoint` format as the reference, so checkpoints
    are interchangeable between the two implementations
    (property-tested in ``tests/test_gated_delta.py``).
    """

    _EPS = 1e-12

    def __init__(self, device: DeviceModel, edge_ids: set = frozenset()):
        self.device = device
        self.edge_ids = set(edge_ids)
        self._preds: dict[int, list[int]] = {}
        for u, v in self.edge_ids:
            self._preds.setdefault(v, []).append(u)
        self._dims = tuple(device.caps)
        self._caps = tuple(device.cap(d) for d in self._dims)
        self._sat_idx = (self._dims.index(device.sat_dim)
                         if device.sat_dim in self._dims else -1)
        self._crate = device.compute_rate
        self._mbw = device.mem_bw
        self._satc = device.sat_compute
        self._satm = device.sat_memory
        self._info: dict[int, tuple] = {}

    def _kinfo(self, k: KernelProfile) -> tuple:
        # Keyed by id(k) — the cached entry holds a strong reference
        # to k so its id can never be recycled by a different profile.
        v = self._info.get(id(k))
        if v is None:
            dem = tuple(k.demands[d] for d in self._dims)
            zero = (k.inst_per_block == 0.0 and
                    all(x == 0.0 for x in dem))
            v = (k, dem, k.n_blocks, k.inst_per_block, k.mem_per_block(),
                 zero)
            self._info[id(k)] = v
        return v

    def _eff(self, occ: float, sat: float) -> float:
        if self._sat_idx < 0:
            return 1.0
        return min(1.0, occ / sat)

    def _rate(self, u: list) -> None:
        cohorts = u[2]
        if not cohorts:
            u[3] = 0.0
            return
        eps = self._EPS
        sum_c = sum([c[7] for c in cohorts])
        sum_m = sum([c[8] for c in cohorts])
        si = self._sat_idx
        if si < 0:
            eff_c = eff_m = 1.0
        else:
            occ = u[0][si]
            eff_c = max(min(1.0, occ / self._satc), eps)
            eff_m = max(min(1.0, occ / self._satm), eps)
        u[3] = min(self._crate * eff_c / max(sum_c, eps),
                   self._mbw * eff_m / max(sum_m, eps))

    def simulate(self, order: Sequence[KernelProfile],
                 start_state: EventCheckpoint | None = None,
                 record: bool = False, trace=None
                 ) -> tuple[float, list[EventCheckpoint]]:
        dev = self.device
        dims_n = len(self._dims)
        caps = self._caps
        eps = self._EPS
        n_units = dev.n_units
        max_res = dev.max_resident
        preds = self._preds
        grid: dict[int, int] = {}
        for k in order:
            grid[id(k)] = self._kinfo(k)[2]
        if start_state is None:
            units = [[[0.0] * dims_n, 0, [], 0.0] for _ in range(n_units)]
            start_pos, rr, t = 0, 0, 0.0
            retired: dict[int, int] = {id(k): 0 for k in order}
        else:
            units = []
            for used, n_res, cohorts in start_state.units:
                cs = []
                for k, nb, fl, ta in cohorts:
                    _, dem, _, inst_b, mem_b, _ = self._kinfo(k)
                    cs.append([k, nb, fl, ta, inst_b, mem_b, dem,
                               inst_b * nb, mem_b * nb])
                u = [list(used), n_res, cs, 0.0]
                self._rate(u)
                units.append(u)
            start_pos, rr, t = (start_state.pos, start_state.rr,
                                start_state.time)
            # Derived gate state, as in DagEventSimulator.simulate:
            # positions < start_pos were fully dispatched, so retired
            # = grid minus blocks still resident in the checkpoint.
            retired = {id(k): 0 for k in order}
            for p in range(start_pos):
                retired[id(order[p])] = grid[id(order[p])]
            for _, _, cohorts in start_state.units:
                for k, nb, _, _ in cohorts:
                    retired[id(k)] -= nb

        def ready(k: KernelProfile) -> bool:
            return all(retired.get(p, 0) >= grid.get(p, 0)
                       for p in preds.get(id(k), []))

        # Strict-FIFO queue of [kernel, blocks left, pos, dem, inst,
        # mem, zero_work].
        pending: list[list] = []
        for p in range(start_pos, len(order)):
            k = order[p]
            _, dem, nb, inst_b, mem_b, zero = self._kinfo(k)
            pending.append([k, nb, p, dem, inst_b, mem_b, zero])
        head = 0
        n_pend = len(pending)
        ckpts: list[EventCheckpoint] = []
        next_ckpt = start_pos
        n_res_total = sum(u[1] for u in units)

        def snapshot(pos: int, blocks_left: int) -> EventCheckpoint:
            return EventCheckpoint(
                pos=pos, blocks_left=blocks_left, time=t, rr=rr,
                units=tuple((tuple(u[0]), u[1],
                             tuple((c[0], c[1], c[2], c[3])
                                   for c in u[2]))
                            for u in units))

        def try_admit(pending=pending, units=units, caps=caps,
                      dims_r=range(dims_n), units_r=range(n_units),
                      n_units=n_units, max_res=max_res, eps=eps,
                      record=record, rate=self._rate) -> None:
            # Same closure-bound hot path as _FastEventSim.try_admit,
            # plus the ready gate and the zero-work fast retirement.
            nonlocal rr, head, next_ckpt, n_res_total
            touched: set[int] = set()
            cur_k = None
            rejected: set[int] = set()
            while head < n_pend:
                e = pending[head]
                k, pos, dem = e[0], e[2], e[3]
                if k is not cur_k:
                    cur_k = k
                    rejected = set()
                if record and pos == next_ckpt:
                    # Captured before the ready gate: its verdict
                    # depends only on earlier positions' retired state.
                    ckpts.append(snapshot(pos, e[1]))
                    next_ckpt = pos + 1
                if not ready(k):
                    break  # admission gate: predecessors still in flight
                if e[6]:
                    # Zero-work synchronisation marker (slice join):
                    # retires the instant its predecessors drain.
                    retired[id(k)] = grid[id(k)]
                    head += 1
                    if trace is not None:
                        trace.instant(k.name, t, unit=None, cat="join")
                    continue
                placed = False
                for off in units_r:
                    ui = rr + off
                    if ui >= n_units:
                        ui -= n_units
                    if ui in rejected:
                        continue
                    u = units[ui]
                    if u[1] + 1 > max_res:
                        rejected.add(ui)
                        continue
                    used = u[0]
                    ok = True
                    for di in dims_r:
                        if not used[di] + dem[di] <= caps[di] + eps:
                            ok = False
                            break
                    if not ok:
                        rejected.add(ui)
                        continue
                    for di in dims_r:
                        used[di] += dem[di]
                    u[1] += 1
                    n_res_total += 1
                    for c in reversed(u[2]):
                        if c[0] is k and c[3] == t:
                            c[1] += 1
                            c[7] = c[4] * c[1]
                            c[8] = c[5] * c[1]
                            break
                    else:
                        u[2].append([k, 1, 1.0, t, e[4], e[5], dem,
                                     e[4], e[5]])
                    touched.add(ui)
                    rr = ui + 1
                    if rr >= n_units:
                        rr -= n_units
                    e[1] -= 1
                    if e[1] == 0:
                        head += 1
                    placed = True
                    break
                if not placed:
                    break  # head blocks the queue (strict FIFO)
            for ui in touched:
                rate(units[ui])

        try_admit()
        guard = 0
        while head < n_pend or n_res_total:
            guard += 1
            if guard > 1_000_000:
                raise RuntimeError("_FastGatedSim failed to converge")
            if not n_res_total:
                e = pending[head]
                k = e[0]
                if not ready(k):
                    # Units drained => every dispatched block retired;
                    # an unready head means a predecessor was launched
                    # after it.
                    raise ValueError(
                        f"launch order violates precedence at {k.name!r}")
                # Oversized head runs alone (see DagEventSimulator).
                head += 1
                nb, dem, inst_b, mem_b = e[1], e[3], e[4], e[5]
                occ = dem[self._sat_idx] if self._sat_idx >= 0 else 0.0
                eff_c = max(self._eff(occ, dev.sat_compute), eps)
                eff_m = max(self._eff(occ, dev.sat_memory), eps)
                t1 = max(inst_b / (dev.compute_rate * eff_c),
                         mem_b / (dev.mem_bw * eff_m))
                for p in range(math.ceil(nb / n_units)):
                    t += t1
                    if trace is not None:
                        for ui in range(min(n_units, nb - p * n_units)):
                            trace.span(ui, k.name, t - t1, t,
                                       blocks=1, cat="solo")
                            trace.add_busy(ui, t1)
                retired[id(k)] = grid[id(k)]
                try_admit()
                continue
            dt = min([c[2] / u[3] for u in units if u[2] for c in u[2]])
            t += dt
            freed = False
            for ui, u in enumerate(units):
                cohorts = u[2]
                if not cohorts:
                    continue
                if trace is not None:
                    trace.add_busy(ui, dt)
                lam = u[3]
                done = []
                for c in cohorts:
                    c[2] -= lam * dt
                    if c[2] <= 1e-9:
                        done.append(c)
                if done:
                    freed = True
                    used = u[0]
                    for c in done:
                        cohorts.remove(c)
                        dem, nb = c[6], c[1]
                        for di in range(dims_n):
                            used[di] -= dem[di] * nb
                        u[1] -= nb
                        n_res_total -= nb
                        retired[id(c[0])] = (
                            retired.get(id(c[0]), 0) + nb)
                        if trace is not None:
                            trace.span(ui, c[0].name, c[3], t,
                                       blocks=nb)
                    self._rate(u)
            if freed:
                try_admit()
        return t, ckpts


class GatedDeltaEvaluator(DeltaEvaluator):
    """Suffix re-simulation of locally modified *topological* orders
    under the gated event model.

    The checkpoint discipline is the event model's — one
    :class:`EventCheckpoint` per order position, captured before any
    block of that position is dispatched — so a candidate differing
    first at position ``p`` resumes from the checkpoint at ``p``
    itself.  The gate state is derived from the checkpoint on resume
    (see :class:`_FastGatedSim`), which is why the evaluator needs no
    gated-specific checkpoint format.

    Candidates must be topological; :meth:`legal` is the pre-simulation
    edge-inversion filter (O(n + E) position-map scan, the same check
    ``refine_order_dag`` applies before charging any simulation cost).
    A non-topological candidate that slipped past the filter deadlocks
    the gate and raises ``ValueError`` rather than returning a bogus
    time.
    """

    def __init__(self, device: DeviceModel, edge_ids: set):
        # Bypasses DeltaEvaluator.__init__ (which only knows the flat
        # round/event simulators) but keeps its entire evaluation
        # discipline: _per_position selects the event-style paths.
        self.sim = _FastGatedSim(device, edge_ids)
        self.model = "gated"
        self._per_position = True
        self.edge_ids = self.sim.edge_ids
        self._base: list[KernelProfile] = []
        self._ckpts: list = []
        self._total = 0.0

    def legal(self, cand: Sequence[KernelProfile]) -> bool:
        """True iff every precedence edge points forward in ``cand``
        — the pre-simulation move filter: an edge-inverting move is
        rejected before it costs any simulation."""
        pos = {id(k): p for p, k in enumerate(cand)}
        return all(pos[u] < pos[v] for u, v in self.edge_ids)
