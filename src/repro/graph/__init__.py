"""Dependency-aware kernel-DAG scheduling (PR 3's new subsystem).

Generalizes the paper's Algorithm 1 — built for mutually independent
kernels — to precedence-constrained workloads: real model graphs where
attention feeds MLP feeds the next layer, traced per request from the
serving configs.  Flat-order callers keep using
``repro.core.fastscore``; when dependencies exist, come here:

* :mod:`repro.graph.kernel_graph` — :class:`KernelGraph` +
  :func:`trace_arch` (config -> per-layer work-item chains),
* :mod:`repro.graph.constrained` — :func:`greedy_order_dag` (ready-set
  incremental greedy) + :func:`refine_order_dag` (legal local search;
  ``model="gated"`` optimizes the gated DAG makespan directly),
* :mod:`repro.graph.streams` — :func:`assign_streams` (k launch
  queues) + :class:`DagEventSimulator` (gated makespan model,
  checkpointable),
* :mod:`repro.graph.delta` — :class:`GatedDeltaEvaluator` +
  ``_FastGatedSim`` (suffix re-simulation under the gated model; the
  delta path that makes ``model="gated"`` refinement affordable).

When a workload carries *oversized* stages — profiles that saturate a
device capacity on their own (long prefill chunks against the slot
budget), which the ready-set greedy can only serialize into solo
rounds — go one layer up to :mod:`repro.slice`:
``greedy_order_slices`` lazily cuts exactly those stages into
co-schedulable slices (Kernelet-style) and degenerates to
``greedy_order_dag`` bit-for-bit when nothing triggers.
"""

from .constrained import greedy_order_dag, refine_order_dag
from .delta import GatedDeltaEvaluator
from .kernel_graph import (KernelGraph, TracedWorkload,
                           arch_kv_bytes_per_token, estimate_n_params,
                           trace_arch)
from .streams import (DagEventSimulator, StreamAssignment, assign_streams,
                      fifo_rounds_dag)

__all__ = [
    "KernelGraph", "TracedWorkload", "trace_arch",
    "arch_kv_bytes_per_token", "estimate_n_params",
    "greedy_order_dag", "refine_order_dag", "GatedDeltaEvaluator",
    "DagEventSimulator", "StreamAssignment", "assign_streams",
    "fifo_rounds_dag",
]
