"""Algorithm 1 under precedence constraints: ready-set greedy + legal
local search.

:func:`greedy_order_dag` is the DAG generalisation of the incremental
greedy (:func:`repro.core.fastscore.greedy_order_fast`): it reuses the
same :class:`~repro.core.fastscore.ProfileTable` packing and the
once-computed ``pair_score_matrix``, but restricts both the seed-pair
scan and the absorption candidates of every round to the current
*ready frontier* — nodes whose predecessors have all retired in
**earlier** rounds.  Successors of a round's members only become ready
when the round closes (co-scheduled kernels run concurrently, so a
dependent kernel can never share a round with its predecessor), which
makes the emitted flat order ``Rd_0 ++ Rd_1 ++ ...`` a valid
topological order by construction.  With an empty edge set the frontier
is always the whole alive set and the function reproduces
``greedy_order_fast`` round-for-round, tie-breaks included
(property-tested in ``tests/test_graph.py``).

:func:`refine_order_dag` is the precedence-respecting counterpart of
:func:`repro.core.refine.refine_order`: the same swap/reinsertion move
sets, but moves that would invert an edge are rejected *before* any
simulation, and legal candidates are delta-evaluated.  Three objective
currencies are supported: ``model="round"``/``"event"`` run the flat
:class:`~repro.core.refine.DeltaEvaluator` (those models ignore
precedence — useful as cheap proxies when the gate barely binds), and
``model="gated"`` runs the
:class:`repro.graph.delta.GatedDeltaEvaluator`, optimizing the DAG
makespan of :class:`repro.graph.streams.DagEventSimulator` *directly*
via gated suffix re-simulation — the currency DAG and slice schedules
are actually scored in (``benchmarks/dag.py``,
``benchmarks/slicing.py``, the serving gated guard).
"""

from __future__ import annotations

from time import perf_counter
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.fastscore import (ProfileTable, _absorb, _comb_ratio_scalar,
                                  _comb_scores, _CombState,
                                  pair_score_matrix)
from repro.core.refine import DeltaEvaluator, _apply, _moves
from repro.core.resources import DeviceModel, KernelProfile
from repro.core.scheduler import Round, Schedule, _sort_key
from repro.core.simulator import simulate

from .delta import GatedDeltaEvaluator

__all__ = ["GreedyFrontier", "greedy_order_dag", "refine_order_dag"]


class _FrontierRound:
    """One live round: member profiles plus the ProfileCombine state
    the incremental greedy maintained for it (the virtual combined
    profile new candidates are scored against)."""

    __slots__ = ("members", "comb")

    def __init__(self, members: list[KernelProfile], comb: _CombState):
        self.members = members
        self.comb = comb


def _single_comb(table: ProfileTable, i: int) -> _CombState:
    return _CombState(demand=table.per_unit[i].copy(),
                      bpu=float(table.bpu[i]),
                      n_blocks=float(table.n_blocks[i]),
                      inst=float(table.inst[i]),
                      r=float(table.r[i]))


def _fold_comb(table: ProfileTable, idxs: Sequence[int],
               device: DeviceModel) -> _CombState:
    """ProfileCombine left fold over ``table[idxs]`` — the same
    single-then-absorb arithmetic the incremental greedy applies, so a
    re-derived round comb scores candidates the way the greedy that
    built the round would have."""
    comb = _single_comb(table, idxs[0])
    for c in idxs[1:]:
        comb = _absorb(comb, table, c, device)
    return comb


class GreedyFrontier:
    """Checkpointable round-frontier state of the ready-set greedy.

    The batch greedy (:func:`greedy_order_dag`) discards its per-round
    ProfileCombine states when it returns; this class keeps them, so a
    *live* composition can be extended (a new request's chain placed
    stage by stage where Algorithm 1's own scoring puts it — the
    :func:`repro.core.fastscore.warm_start_insert` rule, generalized
    to precedence chains) or shrunk (a finished request's stages
    retired, affected combs re-folded) without recomposing from
    scratch.  ``greedy_order_dag(..., frontier=...)`` grows one during
    a cold run; :meth:`seed` re-derives one from any finished round
    composition (e.g. a refined or guard-selected one).

    Precedence discipline: members of one round are mutually
    independent, and a chain's stage ``i+1`` is always placed in a
    strictly later round than stage ``i`` (``min_round`` in
    :meth:`insert_chain`), the same invariant the batch greedy
    enforces by closing rounds before unblocking successors.  Cross-
    chain edges are assumed absent — true for traced serving
    workloads, where edges connect stages of one request only.
    """

    def __init__(self, device: DeviceModel):
        self.device = device
        self.rounds: list[_FrontierRound] = []

    # -- construction ---------------------------------------------------
    def reset(self) -> None:
        self.rounds = []

    def _record(self, members: list[KernelProfile],
                comb: _CombState) -> None:
        """Append a closed round (used by ``greedy_order_dag``)."""
        self.rounds.append(_FrontierRound(list(members), comb))

    def seed(self, rounds: Sequence[Sequence[KernelProfile]]) -> None:
        """Re-derive frontier state from a finished composition."""
        self.reset()
        flat = [k for rd in rounds for k in rd]
        if not flat:
            return
        table = ProfileTable.build(flat, self.device)
        base = 0
        for rd in rounds:
            idxs = list(range(base, base + len(rd)))
            base += len(rd)
            if not idxs:
                continue
            self.rounds.append(_FrontierRound(
                list(rd), _fold_comb(table, idxs, self.device)))

    # -- inspection -----------------------------------------------------
    def round_names(self) -> list[list[str]]:
        return [[k.name for k in rd.members] for rd in self.rounds]

    def order(self) -> list[KernelProfile]:
        return [k for rd in self.rounds for k in rd.members]

    def _index_of(self, rd: _FrontierRound) -> int:
        for i, cand in enumerate(self.rounds):
            if cand is rd:
                return i
        raise ValueError("round no longer in frontier")

    def _insert_sorted(self, rd: _FrontierRound,
                       prof: KernelProfile) -> None:
        """Keep Alg. 1's intra-round dispatch order (decreasing
        shared-memory sort key, line 6/10) when a live placement joins
        an existing round — same rule as ``Round.insert_sorted``."""
        key = _sort_key(prof, self.device)
        for i, existing in enumerate(rd.members):
            if key > _sort_key(existing, self.device):
                rd.members.insert(i, prof)
                return
        rd.members.append(prof)

    # -- live mutation --------------------------------------------------
    def _place_one(self, prof: KernelProfile, min_round: int,
                   on_solo=None, max_round: int | None = None,
                   table: ProfileTable | None = None,
                   col: int = 0) -> _FrontierRound:
        """Place one kernel into the best-scoring fitting round at
        index >= ``min_round`` (the ``warm_start_insert`` rule against
        each round's maintained comb).  ``max_round`` (exclusive)
        bounds the scan so a chain's later stages keep existing rounds
        reachable (:meth:`insert_chain` sets it to reserve one round
        per remaining stage); when the bounded window has no fit the
        scan falls back to the full suffix before going solo.  No fit
        anywhere: ``on_solo``, when given, may expand the kernel into
        co-schedulable slices plus a join (returning ``(slices,
        join)``); otherwise a new solo round opens at ``min_round`` —
        leaving every later existing round reachable for the chain's
        later stages.  ``table``/``col`` let a caller placing many
        kernels (``insert_chain``) pack them once instead of building
        a one-row :class:`ProfileTable` per placement."""
        if table is None:
            table, col = ProfileTable.build([prof], self.device), 0
        idx = np.asarray([col])

        def scan(hi):
            best, best_s = None, -np.inf
            for rd in self.rounds[min_round:hi]:
                scores, fits = _comb_scores(rd.comb, table, idx)
                if bool(fits[0]) and float(scores[0]) > best_s:
                    best, best_s = rd, float(scores[0])
            return best

        best = scan(max_round)
        if (best is None and max_round is not None
                and max_round < len(self.rounds)):
            best = scan(None)
        if best is not None:
            self._insert_sorted(best, prof)
            best.comb = _absorb(best.comb, table, col, self.device)
            return best
        if on_solo is not None:
            exp = on_solo(prof)
            if exp is not None:
                parts, join = exp
                slice_at = [self._place_one(p, min_round) for p in parts]
                join_min = 1 + max(self._index_of(rd) for rd in slice_at)
                return self._place_one(join, join_min)
        rd = _FrontierRound([prof], _single_comb(table, col))
        self.rounds.insert(min_round, rd)
        return rd

    def insert_chain(self, profiles: Sequence[KernelProfile],
                     preds: Sequence[Sequence[int]] | None = None,
                     *, on_solo=None) -> None:
        """Extend the live composition with a new chain.

        ``profiles`` are the chain's kernels in intra-chain
        topological order; ``preds[i]`` lists indices (into
        ``profiles``) that must retire in strictly earlier rounds than
        stage ``i`` — default: the plain chain ``i-1 -> i``.
        ``on_solo`` is the slice-expansion hook
        (:func:`repro.slice.constrained.frontier_solo_expander`):
        called when a stage fits no existing round, it may return
        ``(slices, join)`` to place instead — slices share the stage's
        ``min_round`` floor and the join lands strictly after all of
        them, mirroring the lazy expansion of
        :func:`repro.slice.greedy_order_slices`.
        """
        profiles = list(profiles)
        if preds is None:
            preds = [[i - 1] if i else [] for i in range(len(profiles))]
        table = ProfileTable.build(profiles, self.device) \
            if profiles else None
        placed: list[_FrontierRound] = []
        for i, prof in enumerate(profiles):
            min_round = 0
            for p in preds[i]:
                min_round = max(min_round,
                                self._index_of(placed[p]) + 1)
            # Reserve one existing round per remaining stage: an
            # unbounded best-score scan happily parks stage 0 in the
            # *last* round, spilling the whole rest of the chain into
            # fresh solo rounds — under churn the frontier balloons
            # instead of threading the chain through the composition
            # the way the batch ready-set greedy would.
            remaining = len(profiles) - i - 1
            max_round = (max(min_round, len(self.rounds) - remaining)
                         if remaining else None)
            placed.append(self._place_one(prof, min_round,
                                          on_solo=on_solo,
                                          max_round=max_round,
                                          table=table, col=i))

    def remove(self, names: set[str]) -> None:
        """Retire kernels by name (a finished request's stages, slice
        parts included); affected rounds re-fold their combs over the
        surviving members, empty rounds close."""
        kept: list[_FrontierRound] = []
        dirty: list[_FrontierRound] = []
        for rd in self.rounds:
            before = len(rd.members)
            rd.members = [k for k in rd.members if k.name not in names]
            if not rd.members:
                continue
            if len(rd.members) != before:
                dirty.append(rd)
            kept.append(rd)
        self.rounds = kept
        for rd in dirty:
            table = ProfileTable.build(rd.members, self.device)
            rd.comb = _fold_comb(table, range(len(rd.members)),
                                 self.device)

    def refresh(self, profiles: dict[str, KernelProfile]) -> None:
        """Swap members to current (drifted) profile objects by name
        and re-fold every comb — O(n * D), run before scoring new
        insertions against a step whose demands moved (decode kv
        growth).  Names absent from ``profiles`` keep their old
        profile object."""
        for rd in self.rounds:
            rd.members = [profiles.get(k.name, k) for k in rd.members]
        flat = self.order()
        if not flat:
            return
        table = ProfileTable.build(flat, self.device)
        base = 0
        for rd in self.rounds:
            rd.comb = _fold_comb(
                table, range(base, base + len(rd.members)), self.device)
            base += len(rd.members)


def _edge_arrays(n: int, edges: Iterable[tuple[int, int]]
                 ) -> tuple[list[list[int]], np.ndarray]:
    succs: list[list[int]] = [[] for _ in range(n)]
    indeg = np.zeros(n, dtype=np.int64)
    for u, v in set(edges):
        if not (0 <= u < n and 0 <= v < n) or u == v:
            raise ValueError(f"bad edge ({u}, {v}) for n={n}")
        succs[u].append(v)
        indeg[v] += 1
    return succs, indeg


def greedy_order_dag(kernels: Sequence[KernelProfile],
                     device: DeviceModel,
                     *, edges: Iterable[tuple[int, int]] = (),
                     frontier: "GreedyFrontier | None" = None) -> Schedule:
    """Ready-set Algorithm 1 over a kernel DAG.

    ``edges`` are ``(u, v)`` index pairs into ``kernels``: u must
    complete before v starts.  Raises ``ValueError`` on a cycle.  With
    ``edges=()`` this is exactly ``greedy_order_fast`` — same rounds,
    same intra-round order, same tie-breaking.

    ``frontier`` grows a :class:`GreedyFrontier` during the run: every
    closed round is recorded with the exact ProfileCombine state the
    greedy maintained for it (reset first, so the sink always holds
    this run's composition).  A live caller
    (:class:`repro.serve.live.LiveComposition`) later extends or
    shrinks that state instead of re-running this function cold.

    A stage whose profile saturates a device capacity on its own can
    only ever land in a solo round here; callers with such oversized
    stages should use :func:`repro.slice.greedy_order_slices`, which
    wraps this greedy and lazily cuts exactly those stages into
    co-schedulable slices.
    """
    n = len(kernels)
    if frontier is not None:
        frontier.reset()
    if n == 0:
        return Schedule([])
    succs, indeg = _edge_arrays(n, edges)
    table = ProfileTable.build(kernels, device)
    mat = pair_score_matrix(table)
    # Same masking discipline as greedy_order_fast: lower triangle and
    # diagonal dead so the argmax scans exactly the i < j entries the
    # reference scan evaluates; rows/cols die as kernels retire.
    mat[np.tril_indices(n)] = -1.0
    alive = np.ones(n, dtype=bool)
    rounds: list[Round] = []
    n_alive = n

    def kill(i: int) -> None:
        nonlocal n_alive
        alive[i] = False
        mat[i, :] = -1.0
        mat[:, i] = -1.0
        n_alive -= 1

    while n_alive:
        ready = np.nonzero(alive & (indeg == 0))[0]
        if ready.size == 0:
            raise ValueError("precedence edges contain a cycle")
        rd = Round()
        members: list[int] = []
        comb: _CombState | None = None
        if ready.size == 1:
            solo = int(ready[0])
            kill(solo)
            rd.kernels.append(table.kernels[solo])
            members.append(solo)
        else:
            # Seed pair: first strict maximum over ready i < j entries
            # in row-major order — the submatrix scan preserves the
            # full-matrix scan order, so with no edges the selected
            # pair is identical to greedy_order_fast's.
            sub = mat[np.ix_(ready, ready)]
            flat = int(np.argmax(sub))
            si, sj = divmod(flat, ready.size)
            i, j = int(ready[si]), int(ready[sj])
            best = mat[i, j]
            fits_pair = (
                table.bpu[i] + table.bpu[j] <= device.max_resident and
                bool(np.all(table.per_unit[i] + table.per_unit[j] <=
                            table.caps)))
            if best <= 0.0 and not fits_pair:
                # Nothing pairs: heaviest (sort-key) ready kernel runs
                # alone, as in the unconstrained greedy.
                solo = int(ready[int(np.argmax(table.sort_key[ready]))])
                kill(solo)
                rd.kernels.append(table.kernels[solo])
                members.append(solo)
            else:
                rd.insert_sorted(table.kernels[i], device)
                rd.insert_sorted(table.kernels[j], device)
                comb = _CombState(
                    demand=table.per_unit[i] + table.per_unit[j],
                    bpu=table.bpu[i] + table.bpu[j],
                    n_blocks=table.n_blocks[i] + table.n_blocks[j],
                    inst=table.inst[i] + table.inst[j],
                    r=_comb_ratio_scalar(
                        device, table.n_blocks[i], table.inst[i],
                        table.r[i], table.n_blocks[j], table.inst[j],
                        table.r[j]))
                kill(i)
                kill(j)
                members += [i, j]
                # Absorb from the round-start frontier only: indeg is
                # not decremented until the round closes, so nodes
                # unblocked by this round's members never join it.
                while n_alive:
                    idx = np.nonzero(alive & (indeg == 0))[0]
                    if idx.size == 0:
                        break
                    scores, fits = _comb_scores(comb, table, idx)
                    if not fits.any():
                        break
                    scores = np.where(fits, scores, -np.inf)
                    c = int(idx[int(np.argmax(scores))])
                    rd.insert_sorted(table.kernels[c], device)
                    comb = _absorb(comb, table, c, device)
                    kill(c)
                    members.append(c)
        # Round closes: retire members, unblocking their successors
        # for subsequent rounds.
        for m in members:
            for v in succs[m]:
                indeg[v] -= 1
        if frontier is not None:
            # rd.kernels, not members: the frontier keeps Alg. 1's
            # intra-round dispatch order (decreasing shared memory),
            # not the absorption order.
            frontier._record(
                list(rd.kernels),
                comb if comb is not None
                else _single_comb(table, members[0]))
        rounds.append(rd)
    return Schedule(rounds)


def _legal_mask(order: Sequence[KernelProfile],
                edge_ids: set) -> Callable[[Sequence[KernelProfile]], bool]:
    """Fast topological check for candidate orders over the same
    kernel objects: position-map build + edge scan, O(n + E)."""
    def ok(cand: Sequence[KernelProfile]) -> bool:
        pos = {id(k): p for p, k in enumerate(cand)}
        return all(pos[u] < pos[v] for u, v in edge_ids)
    return ok


def refine_order_dag(
    order: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    edges: Iterable[tuple[int, int]] = (),
    edge_ids: set | None = None,
    time_fn: Callable[[Sequence[KernelProfile]], float] | None = None,
    budget: int = 2000,
    model: str = "event",
    neighborhood: str = "full",
    batch_size: int | None = None,
    table=None,
    rescore: bool | None = None,
    metrics=None,
) -> tuple[list[KernelProfile], float, int]:
    """Precedence-respecting hill-climb of a topological launch order.

    ``batch_size`` routes to the batched evaluator
    (:func:`repro.core.batched.refine_order_batched`): illegal
    candidates are filtered for free as in the sequential path, the
    legal neighborhood is scored in vectorized ``(B, n)`` passes
    (gated candidates on the lockstep gated engine) and improving
    moves are re-verified exactly before acceptance.  ``table``
    threads a pre-built :class:`~repro.core.fastscore.ProfileTable`
    through so the pipeline packs once.  ``rescore`` picks the
    batched quality contract (sequential-parity vs
    max-throughput; see :func:`repro.core.batched.refine_order_batched`
    — the default re-scores under ``model="gated"``).

    ``edges`` are index pairs into the *given* ``order``; callers that
    hold a :class:`~repro.graph.kernel_graph.KernelGraph` over a
    permutation of these kernels pass
    ``edge_ids=graph.edges_by_id()`` instead.  The move sets, budget
    accounting (full-simulation equivalents) and delta evaluation are
    those of :func:`repro.core.refine.refine_order`; the only
    difference is the legality filter: a candidate that would place a
    kernel before one of its predecessors is discarded before it costs
    any simulation.  The returned order is therefore always a valid
    topological order, and never modelled-worse than the input.

    ``model`` selects the objective currency: ``"round"``/``"event"``
    are the flat (precedence-blind) simulators, ``"gated"`` the
    dependency-aware :class:`~repro.graph.streams.DagEventSimulator`
    makespan, delta-evaluated via
    :class:`~repro.graph.delta.GatedDeltaEvaluator` — use it when the
    returned time must be the DAG schedule's own scoring currency
    (best_t then *is* the gated makespan of ``best_order``, so no
    greedy fallback is needed on the gated scoreboard).

    ``metrics`` (a :class:`repro.obs.MetricsRegistry`) records
    ``refine_evals`` / ``refine_cost`` / ``refine_score_s`` exactly as
    :func:`repro.core.refine.refine_order` does (and forwards to the
    batched route) — purely additive, the trajectory is unchanged.
    """
    n = len(order)
    base = list(order)
    if edge_ids is None:
        edge_ids = {(id(base[u]), id(base[v])) for u, v in set(edges)}
    if neighborhood == "auto":
        neighborhood = "full" if n <= 128 else "adjacent"
    legal = _legal_mask(base, edge_ids)
    if not legal(base):
        raise ValueError("input order violates the precedence edges")
    if batch_size is not None and time_fn is None \
            and model in ("round", "event", "gated"):
        from repro.core.batched import refine_order_batched

        return refine_order_batched(
            base, device, model=model, budget=budget,
            neighborhood=neighborhood, batch_size=batch_size,
            table=table, edge_ids=edge_ids,
            delta=(GatedDeltaEvaluator(device, edge_ids)
                   if model == "gated" else None),
            legal=legal, rescore=rescore, metrics=metrics)
    t_wall = perf_counter()
    use_delta = time_fn is None and model in ("round", "event", "gated")
    if not use_delta:
        delta = None
    elif model == "gated":
        delta = GatedDeltaEvaluator(device, edge_ids)
    else:
        delta = DeltaEvaluator(device, model=model)
    if time_fn is None and not use_delta:
        # Only reachable with an unknown model string: simulate() then
        # raises on first evaluation.  Valid models always delta-eval.
        time_fn = lambda o: simulate(o, device, model=model)  # noqa: E731
    best = base
    best_t = delta.rebase(best) if use_delta else time_fn(best)
    cost = 1.0
    evals = 1
    eval_cap = 10 * budget if use_delta else budget
    improved = True
    while improved and cost < budget and evals < eval_cap:
        improved = False
        moves = _moves(n, neighborhood)
        if use_delta and neighborhood == "adjacent":
            bounds = delta.boundaries()
            if bounds is None:
                moves.sort(key=lambda m: -m[0])
            else:
                near = [False] * (n + 1)
                for b in bounds:
                    for p in (b - 1, b, b + 1):
                        if 0 <= p < n:
                            near[p] = True
                moves.sort(key=lambda m: (not (near[m[2]] or near[m[3]]),
                                          -m[0]))
        for first, kind, i, j in moves:
            if cost >= budget or evals >= eval_cap:
                break
            cand = _apply(best, kind, i, j)
            if not legal(cand):
                continue  # rejected before simulation: costs nothing
            if use_delta:
                t, frac = delta.evaluate_costed(cand, first)
                cost += frac
            else:
                t = time_fn(cand)
                cost += 1.0
            evals += 1
            if t < best_t - 1e-15:
                best, best_t, improved = cand, t, True
                if use_delta:
                    delta.rebase_incremental(best, first)
    if metrics is not None:
        metrics.counter("refine_evals").inc(evals)
        metrics.counter("refine_cost").inc(cost)
        metrics.histogram("refine_score_s").observe(
            perf_counter() - t_wall)
    return best, best_t, evals
