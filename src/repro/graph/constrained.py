"""Algorithm 1 under precedence constraints: ready-set greedy + legal
local search.

:func:`greedy_order_dag` is the DAG generalisation of the incremental
greedy (:func:`repro.core.fastscore.greedy_order_fast`): it reuses the
same :class:`~repro.core.fastscore.ProfileTable` packing and the
once-computed ``pair_score_matrix``, but restricts both the seed-pair
scan and the absorption candidates of every round to the current
*ready frontier* — nodes whose predecessors have all retired in
**earlier** rounds.  Successors of a round's members only become ready
when the round closes (co-scheduled kernels run concurrently, so a
dependent kernel can never share a round with its predecessor), which
makes the emitted flat order ``Rd_0 ++ Rd_1 ++ ...`` a valid
topological order by construction.  With an empty edge set the frontier
is always the whole alive set and the function reproduces
``greedy_order_fast`` round-for-round, tie-breaks included
(property-tested in ``tests/test_graph.py``).

:func:`refine_order_dag` is the precedence-respecting counterpart of
:func:`repro.core.refine.refine_order`: the same swap/reinsertion move
sets, but moves that would invert an edge are rejected *before* any
simulation, and legal candidates are delta-evaluated.  Three objective
currencies are supported: ``model="round"``/``"event"`` run the flat
:class:`~repro.core.refine.DeltaEvaluator` (those models ignore
precedence — useful as cheap proxies when the gate barely binds), and
``model="gated"`` runs the
:class:`repro.graph.delta.GatedDeltaEvaluator`, optimizing the DAG
makespan of :class:`repro.graph.streams.DagEventSimulator` *directly*
via gated suffix re-simulation — the currency DAG and slice schedules
are actually scored in (``benchmarks/dag.py``,
``benchmarks/slicing.py``, the serving gated guard).
"""

from __future__ import annotations

from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.fastscore import (ProfileTable, _absorb, _comb_ratio_scalar,
                                  _comb_scores, _CombState,
                                  pair_score_matrix)
from repro.core.refine import DeltaEvaluator, _apply, _moves
from repro.core.resources import DeviceModel, KernelProfile
from repro.core.scheduler import Round, Schedule
from repro.core.simulator import simulate

from .delta import GatedDeltaEvaluator

__all__ = ["greedy_order_dag", "refine_order_dag"]


def _edge_arrays(n: int, edges: Iterable[tuple[int, int]]
                 ) -> tuple[list[list[int]], np.ndarray]:
    succs: list[list[int]] = [[] for _ in range(n)]
    indeg = np.zeros(n, dtype=np.int64)
    for u, v in set(edges):
        if not (0 <= u < n and 0 <= v < n) or u == v:
            raise ValueError(f"bad edge ({u}, {v}) for n={n}")
        succs[u].append(v)
        indeg[v] += 1
    return succs, indeg


def greedy_order_dag(kernels: Sequence[KernelProfile],
                     device: DeviceModel,
                     *, edges: Iterable[tuple[int, int]] = ()) -> Schedule:
    """Ready-set Algorithm 1 over a kernel DAG.

    ``edges`` are ``(u, v)`` index pairs into ``kernels``: u must
    complete before v starts.  Raises ``ValueError`` on a cycle.  With
    ``edges=()`` this is exactly ``greedy_order_fast`` — same rounds,
    same intra-round order, same tie-breaking.

    A stage whose profile saturates a device capacity on its own can
    only ever land in a solo round here; callers with such oversized
    stages should use :func:`repro.slice.greedy_order_slices`, which
    wraps this greedy and lazily cuts exactly those stages into
    co-schedulable slices.
    """
    n = len(kernels)
    if n == 0:
        return Schedule([])
    succs, indeg = _edge_arrays(n, edges)
    table = ProfileTable.build(kernels, device)
    mat = pair_score_matrix(table)
    # Same masking discipline as greedy_order_fast: lower triangle and
    # diagonal dead so the argmax scans exactly the i < j entries the
    # reference scan evaluates; rows/cols die as kernels retire.
    mat[np.tril_indices(n)] = -1.0
    alive = np.ones(n, dtype=bool)
    rounds: list[Round] = []
    n_alive = n

    def kill(i: int) -> None:
        nonlocal n_alive
        alive[i] = False
        mat[i, :] = -1.0
        mat[:, i] = -1.0
        n_alive -= 1

    while n_alive:
        ready = np.nonzero(alive & (indeg == 0))[0]
        if ready.size == 0:
            raise ValueError("precedence edges contain a cycle")
        rd = Round()
        members: list[int] = []
        if ready.size == 1:
            solo = int(ready[0])
            kill(solo)
            rd.kernels.append(table.kernels[solo])
            members.append(solo)
        else:
            # Seed pair: first strict maximum over ready i < j entries
            # in row-major order — the submatrix scan preserves the
            # full-matrix scan order, so with no edges the selected
            # pair is identical to greedy_order_fast's.
            sub = mat[np.ix_(ready, ready)]
            flat = int(np.argmax(sub))
            si, sj = divmod(flat, ready.size)
            i, j = int(ready[si]), int(ready[sj])
            best = mat[i, j]
            fits_pair = (
                table.bpu[i] + table.bpu[j] <= device.max_resident and
                bool(np.all(table.per_unit[i] + table.per_unit[j] <=
                            table.caps)))
            if best <= 0.0 and not fits_pair:
                # Nothing pairs: heaviest (sort-key) ready kernel runs
                # alone, as in the unconstrained greedy.
                solo = int(ready[int(np.argmax(table.sort_key[ready]))])
                kill(solo)
                rd.kernels.append(table.kernels[solo])
                members.append(solo)
            else:
                rd.insert_sorted(table.kernels[i], device)
                rd.insert_sorted(table.kernels[j], device)
                comb = _CombState(
                    demand=table.per_unit[i] + table.per_unit[j],
                    bpu=table.bpu[i] + table.bpu[j],
                    n_blocks=table.n_blocks[i] + table.n_blocks[j],
                    inst=table.inst[i] + table.inst[j],
                    r=_comb_ratio_scalar(
                        device, table.n_blocks[i], table.inst[i],
                        table.r[i], table.n_blocks[j], table.inst[j],
                        table.r[j]))
                kill(i)
                kill(j)
                members += [i, j]
                # Absorb from the round-start frontier only: indeg is
                # not decremented until the round closes, so nodes
                # unblocked by this round's members never join it.
                while n_alive:
                    idx = np.nonzero(alive & (indeg == 0))[0]
                    if idx.size == 0:
                        break
                    scores, fits = _comb_scores(comb, table, idx)
                    if not fits.any():
                        break
                    scores = np.where(fits, scores, -np.inf)
                    c = int(idx[int(np.argmax(scores))])
                    rd.insert_sorted(table.kernels[c], device)
                    comb = _absorb(comb, table, c, device)
                    kill(c)
                    members.append(c)
        # Round closes: retire members, unblocking their successors
        # for subsequent rounds.
        for m in members:
            for v in succs[m]:
                indeg[v] -= 1
        rounds.append(rd)
    return Schedule(rounds)


def _legal_mask(order: Sequence[KernelProfile],
                edge_ids: set) -> Callable[[Sequence[KernelProfile]], bool]:
    """Fast topological check for candidate orders over the same
    kernel objects: position-map build + edge scan, O(n + E)."""
    def ok(cand: Sequence[KernelProfile]) -> bool:
        pos = {id(k): p for p, k in enumerate(cand)}
        return all(pos[u] < pos[v] for u, v in edge_ids)
    return ok


def refine_order_dag(
    order: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    edges: Iterable[tuple[int, int]] = (),
    edge_ids: set | None = None,
    time_fn: Callable[[Sequence[KernelProfile]], float] | None = None,
    budget: int = 2000,
    model: str = "event",
    neighborhood: str = "full",
    batch_size: int | None = None,
    table=None,
    rescore: bool | None = None,
) -> tuple[list[KernelProfile], float, int]:
    """Precedence-respecting hill-climb of a topological launch order.

    ``batch_size`` routes to the batched evaluator
    (:func:`repro.core.batched.refine_order_batched`): illegal
    candidates are filtered for free as in the sequential path, the
    legal neighborhood is scored in vectorized ``(B, n)`` passes
    (gated candidates on the lockstep gated engine) and improving
    moves are re-verified exactly before acceptance.  ``table``
    threads a pre-built :class:`~repro.core.fastscore.ProfileTable`
    through so the pipeline packs once.  ``rescore`` picks the
    batched quality contract (sequential-parity vs
    max-throughput; see :func:`repro.core.batched.refine_order_batched`
    — the default re-scores under ``model="gated"``).

    ``edges`` are index pairs into the *given* ``order``; callers that
    hold a :class:`~repro.graph.kernel_graph.KernelGraph` over a
    permutation of these kernels pass
    ``edge_ids=graph.edges_by_id()`` instead.  The move sets, budget
    accounting (full-simulation equivalents) and delta evaluation are
    those of :func:`repro.core.refine.refine_order`; the only
    difference is the legality filter: a candidate that would place a
    kernel before one of its predecessors is discarded before it costs
    any simulation.  The returned order is therefore always a valid
    topological order, and never modelled-worse than the input.

    ``model`` selects the objective currency: ``"round"``/``"event"``
    are the flat (precedence-blind) simulators, ``"gated"`` the
    dependency-aware :class:`~repro.graph.streams.DagEventSimulator`
    makespan, delta-evaluated via
    :class:`~repro.graph.delta.GatedDeltaEvaluator` — use it when the
    returned time must be the DAG schedule's own scoring currency
    (best_t then *is* the gated makespan of ``best_order``, so no
    greedy fallback is needed on the gated scoreboard).
    """
    n = len(order)
    base = list(order)
    if edge_ids is None:
        edge_ids = {(id(base[u]), id(base[v])) for u, v in set(edges)}
    if neighborhood == "auto":
        neighborhood = "full" if n <= 128 else "adjacent"
    legal = _legal_mask(base, edge_ids)
    if not legal(base):
        raise ValueError("input order violates the precedence edges")
    if batch_size is not None and time_fn is None \
            and model in ("round", "event", "gated"):
        from repro.core.batched import refine_order_batched

        return refine_order_batched(
            base, device, model=model, budget=budget,
            neighborhood=neighborhood, batch_size=batch_size,
            table=table, edge_ids=edge_ids,
            delta=(GatedDeltaEvaluator(device, edge_ids)
                   if model == "gated" else None),
            legal=legal, rescore=rescore)
    use_delta = time_fn is None and model in ("round", "event", "gated")
    if not use_delta:
        delta = None
    elif model == "gated":
        delta = GatedDeltaEvaluator(device, edge_ids)
    else:
        delta = DeltaEvaluator(device, model=model)
    if time_fn is None and not use_delta:
        # Only reachable with an unknown model string: simulate() then
        # raises on first evaluation.  Valid models always delta-eval.
        time_fn = lambda o: simulate(o, device, model=model)  # noqa: E731
    best = base
    best_t = delta.rebase(best) if use_delta else time_fn(best)
    cost = 1.0
    evals = 1
    eval_cap = 10 * budget if use_delta else budget
    improved = True
    while improved and cost < budget and evals < eval_cap:
        improved = False
        moves = _moves(n, neighborhood)
        if use_delta and neighborhood == "adjacent":
            bounds = delta.boundaries()
            if bounds is None:
                moves.sort(key=lambda m: -m[0])
            else:
                near = [False] * (n + 1)
                for b in bounds:
                    for p in (b - 1, b, b + 1):
                        if 0 <= p < n:
                            near[p] = True
                moves.sort(key=lambda m: (not (near[m[2]] or near[m[3]]),
                                          -m[0]))
        for first, kind, i, j in moves:
            if cost >= budget or evals >= eval_cap:
                break
            cand = _apply(best, kind, i, j)
            if not legal(cand):
                continue  # rejected before simulation: costs nothing
            if use_delta:
                t, frac = delta.evaluate_costed(cand, first)
                cost += frac
            else:
                t = time_fn(cand)
                cost += 1.0
            evals += 1
            if t < best_t - 1e-15:
                best, best_t, improved = cand, t, True
                if use_delta:
                    delta.rebase_incremental(best, first)
    return best, best_t, evals
