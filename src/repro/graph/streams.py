"""Launch queues and gated makespan for DAG schedules.

The paper's launch-order semantics assume one in-order launch queue
whose false serialisation the reordering exploits.  With precedence
edges in play a runtime typically exposes ``k`` hardware queues
(CUDA streams, TPU async collectives): kernels on different queues may
be admitted concurrently, kernels on one queue stay ordered.  This
module generalizes the flat round order to that setting:

* :func:`assign_streams` maps a round-structured schedule onto ``k``
  launch queues — members of one round are mutually independent (the
  ready-set greedy guarantees it), so they interleave round-robin
  across the queues, while a kernel with predecessors pins to the
  queue of its latest-launched predecessor, keeping each dependent
  chain on a single queue (intra-queue ordering then enforces the
  chain for free, no cross-queue event needed);
* :class:`DagEventSimulator` extends the reference
  :class:`~repro.core.simulator.EventSimulator` with a **ready-set
  admission gate**: the dispatcher holds a kernel at the head of the
  queue until every one of its predecessors has fully drained from the
  units.  With an empty edge set the gate never fires and the
  simulation is float-for-float identical to ``EventSimulator``
  (property-tested in ``tests/test_graph.py``), so DAG schedules get
  the same modelled-makespan currency as flat ones.  Like the
  reference, it is *checkpointable*: ``record=True`` captures one
  :class:`~repro.core.simulator.EventCheckpoint` per order position
  and ``start_state=`` resumes from one, replaying the identical
  float accumulation.  The gate's own state (per-kernel retired-block
  counts) is **derived** from the checkpoint rather than stored in
  it: at the instant position ``p`` is first examined, every earlier
  position has been fully dispatched, so a kernel's retired count is
  its grid size minus the blocks still resident in the checkpoint's
  cohorts — which is what lets gated suffix re-simulation
  (:class:`repro.graph.delta.GatedDeltaEvaluator`) share the flat
  checkpoint format;
* :func:`fifo_rounds_dag` is the dependency-aware arrival-order
  baseline: capacity packing that also closes a round whenever the
  next item depends on a member of the open round (the round model's
  notion of "predecessor has not completed yet").
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Sequence

from repro.core.resources import DeviceModel, KernelProfile
from repro.core.scheduler import Schedule
from repro.core.simulator import _EPS, EventCheckpoint, _Cohort, _Unit

__all__ = ["StreamAssignment", "assign_streams", "DagEventSimulator",
           "fifo_rounds_dag"]


@dataclass
class StreamAssignment:
    """``k`` in-order launch queues plus the kernel -> queue map
    (keyed by object identity, aligned with ``flat_order``)."""

    streams: list[list[KernelProfile]]
    stream_of: dict[int, int]
    flat_order: list[KernelProfile]

    @property
    def k(self) -> int:
        return len(self.streams)

    def occupancy(self) -> list[int]:
        return [len(s) for s in self.streams]


def assign_streams(schedule: Schedule | Sequence[Sequence[KernelProfile]],
                   edge_ids: set, k: int) -> StreamAssignment:
    """Map a round-structured schedule onto ``k`` launch queues.

    ``edge_ids`` is the identity-keyed edge set
    (:meth:`repro.graph.kernel_graph.KernelGraph.edges_by_id`).
    Kernels without predecessors round-robin across queues so
    independent work interleaves; a kernel with predecessors joins the
    queue of its latest-launched predecessor, so every dependent chain
    is pinned to one queue and needs no cross-queue synchronisation.
    Relative launch order within a queue follows the flat round order.
    """
    if k < 1:
        raise ValueError(f"need k >= 1 queues, got {k}")
    rounds = (schedule.rounds if isinstance(schedule, Schedule)
              else list(schedule))
    preds: dict[int, list[int]] = {}
    for u, v in edge_ids:
        preds.setdefault(v, []).append(u)
    streams: list[list[KernelProfile]] = [[] for _ in range(k)]
    stream_of: dict[int, int] = {}
    flat: list[KernelProfile] = []
    launch_pos: dict[int, int] = {}
    rr = 0
    for rd in rounds:
        kernels = rd.kernels if hasattr(rd, "kernels") else rd
        for kern in kernels:
            kid = id(kern)
            ps = [p for p in preds.get(kid, []) if p in stream_of]
            if ps:
                latest = max(ps, key=launch_pos.__getitem__)
                s = stream_of[latest]
            else:
                s = rr
                rr = (rr + 1) % k
            stream_of[kid] = s
            launch_pos[kid] = len(flat)
            streams[s].append(kern)
            flat.append(kern)
    return StreamAssignment(streams=streams, stream_of=stream_of,
                            flat_order=flat)


def fifo_rounds_dag(items: Sequence, device: DeviceModel,
                    edge_ids: set,
                    demands_of=lambda it: it.profile().demands
                    ) -> list[list]:
    """Arrival-order round packing that respects precedence: a round
    also closes when the next item depends on a member of the open
    round (its predecessor has not completed).  ``items`` must arrive
    in a topological order; generic over item type via ``demands_of``
    (pass ``lambda k: k.demands`` for raw profiles)."""
    rounds: list[list] = []
    cur: list = []
    cur_ids: set[int] = set()
    done_ids: set[int] = set()
    known = {id(it) for it in items}
    used = {d: 0.0 for d in device.caps}
    preds: dict[int, list[int]] = {}
    for u, v in edge_ids:
        if u in known:
            preds.setdefault(v, []).append(u)

    def close():
        nonlocal cur, cur_ids, used
        rounds.append(cur)
        done_ids.update(cur_ids)
        cur, cur_ids = [], set()
        used = {d: 0.0 for d in device.caps}

    for it in items:
        dem = demands_of(it)
        ps = preds.get(id(it), [])
        blocked = any(p in cur_ids or p not in done_ids for p in ps)
        fits = all(used[k] + dem[k] <= device.cap(k) for k in used)
        if (blocked or not fits) and cur:
            close()
        if any(p not in done_ids for p in ps):
            raise ValueError("items are not in topological order")
        cur.append(it)
        cur_ids.add(id(it))
        for k in used:
            used[k] += dem[k]
    if cur:
        rounds.append(cur)
    return rounds


@dataclass
class DagEventSimulator:
    """Event-driven dispatcher model with a ready-set admission gate.

    Identical dispatch arithmetic to
    :class:`~repro.core.simulator.EventSimulator` — same unit state,
    same cohort bookkeeping, same float accumulation — plus one rule:
    the head kernel is held at the queue until every predecessor in
    ``edge_ids`` has *completed* (all of its blocks dispatched and
    drained).  Launch order must therefore be topological; a
    non-topological order deadlocks the gate and raises ``ValueError``
    instead of spinning.

    Zero-work kernels (no instructions, no demands — the synthetic
    join markers slice expansion introduces, see
    :func:`repro.slice.slicer.join_profile`) are pure synchronisation
    points: once their predecessors have drained they retire
    *instantly* without occupying a unit or joining a cohort, so a
    join never inflates the gated makespan.  No kernel outside the
    slice subsystem is zero-work, so ungated runs (the 0-edge
    float-identity pin vs ``EventSimulator``) are unaffected.

    This is the oracle implementation of the gated model; the
    optimized twin with flat-tuple state is
    :class:`repro.graph.delta._FastGatedSim`, property-tested against
    this class for exact float equality
    (``tests/test_gated_delta.py``), full runs and checkpoint resumes
    alike.
    """

    device: DeviceModel
    edge_ids: set = field(default_factory=set)

    def simulate(self, order: Sequence[KernelProfile], *,
                 start_state: EventCheckpoint | None = None,
                 record: bool = False, trace=None):
        """Gated execution time of ``order``.

        ``start_state`` resumes from a previously recorded
        :class:`~repro.core.simulator.EventCheckpoint`; ``order`` must
        agree with the checkpoint's source order at every position
        before ``start_state.pos``.  With ``record=True`` returns
        ``(time, checkpoints)`` — one checkpoint per order position,
        captured the first time the dispatcher examines it (before the
        ready gate consults predecessor state, which itself depends
        only on earlier positions); otherwise returns the time alone.

        ``trace`` (a :class:`repro.obs.ScheduleTrace`) records one
        span per drained cohort and per-unit busy time, exactly like
        the flat reference, plus a device-scoped **instant** per
        zero-work join retirement (category ``"join"``).  Tracing
        only reads state, so gated times are bit-identical with and
        without it; the span/busy conservation property holds for
        fresh (non-resumed) runs.
        """
        dev = self.device
        dims = tuple(dev.caps)
        preds: dict[int, list[int]] = {}
        for u, v in self.edge_ids:
            preds.setdefault(v, []).append(u)
        grid: dict[int, int] = {id(k): k.n_blocks for k in order}
        if start_state is None:
            units = [_Unit(used={d: 0.0 for d in dims})
                     for _ in range(dev.n_units)]
            start_pos, rr, t = 0, 0, 0.0
            retired: dict[int, int] = {id(k): 0 for k in order}
        else:
            units = []
            for used, n_res, cohorts in start_state.units:
                u = _Unit(used=dict(zip(dims, used)), n_resident=n_res,
                          cohorts=[_Cohort(k, nb, fl, ta)
                                   for k, nb, fl, ta in cohorts])
                u.recompute_rate(dev)
                units.append(u)
            start_pos, rr, t = (start_state.pos, start_state.rr,
                                start_state.time)
            # Derived gate state: every position < start_pos was fully
            # dispatched before the checkpoint was captured, so its
            # retired count is its grid size minus the blocks still
            # resident in the checkpoint's cohorts (zero-work joins
            # never enter a cohort, so they derive fully retired).
            retired = {id(k): 0 for k in order}
            for p in range(start_pos):
                retired[id(order[p])] = grid[id(order[p])]
            for _, _, cohorts in start_state.units:
                for k, nb, _, _ in cohorts:
                    retired[id(k)] -= nb

        def ready(k: KernelProfile) -> bool:
            return all(retired.get(p, 0) >= grid.get(p, 0)
                       for p in preds.get(id(k), []))

        def zero_work(k: KernelProfile) -> bool:
            return (k.inst_per_block == 0.0 and
                    all(k.demands.get(d, 0.0) == 0.0 for d in dev.caps))

        pending: deque[list] = deque(
            [order[p], order[p].n_blocks, p]
            for p in range(start_pos, len(order)))
        ckpts: list[EventCheckpoint] = []
        next_ckpt = start_pos

        def fits(u: _Unit, k: KernelProfile) -> bool:
            if u.n_resident + 1 > dev.max_resident:
                return False
            return all(u.used[dim] + k.demands[dim] <= dev.cap(dim) + _EPS
                       for dim in dev.caps)

        def try_admit() -> None:
            nonlocal rr, next_ckpt
            touched: set[int] = set()
            while pending:
                k, _, pos = pending[0]
                if record and pos == next_ckpt:
                    # First examination of position ``pos``: no block
                    # of it placed yet, and the ready gate's verdict
                    # depends only on earlier positions — capture
                    # before consulting it.
                    ckpts.append(EventCheckpoint.capture(
                        pos, pending[0][1], t, rr, units, dims))
                    next_ckpt = pos + 1
                if not ready(k):
                    break  # admission gate: predecessors still in flight
                if zero_work(k):
                    # Synchronisation marker (slice join): retires the
                    # instant its predecessors drain, occupying nothing.
                    retired[id(k)] = grid[id(k)]
                    pending.popleft()
                    if trace is not None:
                        trace.instant(k.name, t, unit=None, cat="join")
                    continue
                placed = False
                for off in range(dev.n_units):
                    ui = (rr + off) % dev.n_units
                    u = units[ui]
                    if fits(u, k):
                        for dim in dev.caps:
                            u.used[dim] += k.demands[dim]
                        u.n_resident += 1
                        for c in u.cohorts:
                            if c.kernel is k and c.t_admit == t:
                                c.n_blocks += 1
                                break
                        else:
                            u.cohorts.append(_Cohort(k, 1, t_admit=t))
                        touched.add(ui)
                        rr = (ui + 1) % dev.n_units
                        pending[0][1] -= 1
                        if pending[0][1] == 0:
                            pending.popleft()
                        placed = True
                        break
                if not placed:
                    break  # head blocks the queue (strict FIFO)
            for ui in touched:
                units[ui].recompute_rate(dev)

        try_admit()
        guard = 0
        while any(u.cohorts for u in units) or pending:
            guard += 1
            if guard > 1_000_000:
                raise RuntimeError("DagEventSimulator failed to converge")
            if not any(u.cohorts for u in units):
                k, nb, _ = pending[0]
                if not ready(k):
                    # Units are drained, so every dispatched block has
                    # retired; an unready head means a predecessor was
                    # launched after it.
                    raise ValueError(
                        f"launch order violates precedence at {k.name!r}")
                # Oversized head runs alone (same accumulation as
                # EventSimulator's forced single-block passes).
                pending.popleft()
                used1 = {dim: k.demands[dim] for dim in dev.caps}
                eff_c = max(dev.compute_efficiency(used1), _EPS)
                eff_m = max(dev.memory_efficiency(used1), _EPS)
                t1 = max(k.inst_per_block / (dev.compute_rate * eff_c),
                         k.mem_per_block() / (dev.mem_bw * eff_m))
                for p in range(math.ceil(nb / dev.n_units)):
                    t += t1
                    if trace is not None:
                        for ui in range(min(dev.n_units,
                                            nb - p * dev.n_units)):
                            trace.span(ui, k.name, t - t1, t,
                                       blocks=1, cat="solo")
                            trace.add_busy(ui, t1)
                retired[id(k)] = grid[id(k)]
                try_admit()
                continue
            dt = min(c.frac_left / u.lam
                     for u in units if u.cohorts for c in u.cohorts)
            t += dt
            freed = False
            for ui, u in enumerate(units):
                if not u.cohorts:
                    continue
                if trace is not None:
                    trace.add_busy(ui, dt)
                done = []
                for c in u.cohorts:
                    c.frac_left -= u.lam * dt
                    if c.frac_left <= 1e-9:
                        done.append(c)
                if done:
                    freed = True
                    for c in done:
                        u.cohorts.remove(c)
                        for dim in dev.caps:
                            u.used[dim] -= c.kernel.demands[dim] * c.n_blocks
                        u.n_resident -= c.n_blocks
                        retired[id(c.kernel)] = (
                            retired.get(id(c.kernel), 0) + c.n_blocks)
                        if trace is not None:
                            trace.span(ui, c.kernel.name, c.t_admit, t,
                                       blocks=c.n_blocks)
                    u.recompute_rate(dev)
            if freed:
                try_admit()
        if record:
            return t, ckpts
        return t
