"""Kernel dependency graphs: precedence-aware scheduling inputs.

The paper's Algorithm 1 — and everything built on it through
``fastscore.greedy_order_fast`` / ``refine_order`` — assumes all
kernels are mutually *independent*.  Real model workloads are layer
graphs: within one request, attention feeds the MLP feeds the next
layer's mixer, so only kernels from *different* requests (or different
micro-batches) are actually free to co-schedule.  This module supplies
the graph abstraction the constrained scheduler
(:mod:`repro.graph.constrained`) and the gated simulator
(:mod:`repro.graph.streams`) consume:

* :class:`KernelGraph` — ``KernelProfile`` nodes plus precedence edges
  ``(u, v)`` meaning *u must complete before v may start*, with
  adjacency/indegree bookkeeping, cycle validation, topological-order
  checking and seeded random topological sampling (the paper's Fig. 1
  "random launch orders" baseline generalized to DAG workloads),
* :func:`trace_arch` — builds the graph a model config *implies*: it
  walks the per-layer work-item chain each serving request emits
  (mixer -> ffn -> mixer -> ... in layer order), emitting intra-request
  edges while leaving cross-request kernels independent.  The per-item
  roofline characterisation reuses the serving substrate's
  :func:`repro.core.tpu.prefill_profile` / ``decode_profile`` with the
  layer's parameter share, so intensities stay consistent with what
  ``ServingEngine`` models for whole-request items.
"""

from __future__ import annotations

import random as _random
from dataclasses import dataclass, field, replace
from typing import Iterable, Sequence

from repro.core.resources import KernelProfile
from repro.core.tpu import TpuWorkItem, decode_profile, prefill_profile
from repro.models.common import ModelConfig

__all__ = ["KernelGraph", "TracedWorkload", "trace_arch",
           "arch_kv_bytes_per_token", "estimate_n_params"]


@dataclass
class KernelGraph:
    """A DAG of :class:`KernelProfile` nodes with precedence edges.

    Edges are index pairs ``(u, v)``: kernel ``u`` must *complete*
    before kernel ``v`` may start (data dependence, not mere launch
    ordering).  An empty edge set degenerates to the independent-batch
    case the rest of the repo schedules; ``greedy_order_dag`` is
    property-tested to reproduce ``greedy_order_fast`` exactly there.
    """

    kernels: list[KernelProfile]
    edges: set = field(default_factory=set)

    def __post_init__(self):
        self.kernels = list(self.kernels)
        given = self.edges
        self.edges = set()
        self._succs: list[list[int]] = [[] for _ in self.kernels]
        self._preds: list[list[int]] = [[] for _ in self.kernels]
        for u, v in given:
            self.add_edge(u, v)

    # -- construction ---------------------------------------------------
    def add_edge(self, u: int, v: int) -> None:
        n = len(self.kernels)
        if not (0 <= u < n and 0 <= v < n):
            raise ValueError(f"edge ({u}, {v}) out of range for n={n}")
        if u == v:
            raise ValueError(f"self-edge ({u}, {v})")
        if (u, v) in self.edges:
            return
        self.edges.add((u, v))
        self._succs[u].append(v)
        self._preds[v].append(u)

    # -- topology -------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.kernels)

    def succs(self, u: int) -> list[int]:
        return list(self._succs[u])

    def preds(self, v: int) -> list[int]:
        return list(self._preds[v])

    def indegrees(self) -> list[int]:
        return [len(p) for p in self._preds]

    def validate(self) -> None:
        """Raise ``ValueError`` if the edge set contains a cycle."""
        indeg = self.indegrees()
        ready = [i for i in range(self.n) if indeg[i] == 0]
        seen = 0
        while ready:
            u = ready.pop()
            seen += 1
            for v in self._succs[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
        if seen != self.n:
            raise ValueError("precedence edges contain a cycle")

    def index_of(self) -> dict[int, int]:
        """``id(kernel) -> node index`` (profiles are unique objects)."""
        return {id(k): i for i, k in enumerate(self.kernels)}

    def edges_by_id(self) -> set:
        """Edge set keyed by kernel object identity, for consumers that
        see reordered kernel lists (simulators, stream assignment)."""
        ks = self.kernels
        return {(id(ks[u]), id(ks[v])) for u, v in self.edges}

    def is_topological(self, order: Sequence[KernelProfile]) -> bool:
        """True iff ``order`` is a permutation of the graph's kernels
        in which every edge points forward."""
        if len(order) != self.n:
            return False
        idx = self.index_of()
        pos: dict[int, int] = {}
        for p, k in enumerate(order):
            i = idx.get(id(k))
            if i is None or i in pos:
                return False
            pos[i] = p
        return all(pos[u] < pos[v] for u, v in self.edges)

    # -- random topological orders (Fig. 1 baseline on DAGs) ------------
    def random_topological_order(
            self, rng: _random.Random) -> list[KernelProfile]:
        """One uniform-tie-break Kahn order (not uniform over all
        topological orders, but unbiased among the ready frontier at
        every step — the natural 'random legal launch order')."""
        indeg = self.indegrees()
        ready = [i for i in range(self.n) if indeg[i] == 0]
        out: list[KernelProfile] = []
        while ready:
            u = ready.pop(rng.randrange(len(ready)))
            out.append(self.kernels[u])
            for v in self._succs[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
        if len(out) != self.n:
            raise ValueError("precedence edges contain a cycle")
        return out

    def random_topological_orders(self, n: int, seed: int = 0
                                  ) -> list[list[KernelProfile]]:
        rng = _random.Random(seed)
        return [self.random_topological_order(rng) for _ in range(n)]

    def schedule(self, device):
        """Convenience: the constrained greedy over this graph."""
        from .constrained import greedy_order_dag
        return greedy_order_dag(self.kernels, device, edges=self.edges)


# ---------------------------------------------------------------------------
# Architecture tracing: config -> per-layer work-item chains
# ---------------------------------------------------------------------------

def _attn_params(cfg: ModelConfig) -> float:
    d = cfg.d_model
    if cfg.attn_type == "mla":
        q_in = cfg.q_lora_rank or d
        q = (d * cfg.q_lora_rank if cfg.q_lora_rank else 0.0) + \
            q_in * cfg.n_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        kv = d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) + \
            cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_head_dim +
                                              cfg.v_head_dim)
        o = cfg.n_heads * cfg.v_head_dim * d
        return float(q + kv + o)
    return float(d * cfg.n_heads * cfg.head_dim * 2 +
                 d * cfg.n_kv_heads * cfg.head_dim * 2)


def _mixer_params(cfg: ModelConfig, i: int) -> float:
    d = cfg.d_model
    kind = cfg.layer_kind(i)
    if kind == "attn":
        return _attn_params(cfg)
    if kind == "mamba":
        di = cfg.mamba_d_inner
        return float(2 * d * di + di * (cfg.dt_rank + 2 * cfg.mamba_d_state)
                     + cfg.dt_rank * di + di * d)
    # mlstm / slstm: projection up + gates + projection down
    pf = cfg.xlstm_proj_factor
    return float(3 * d * d * pf)


def _ffn_params(cfg: ModelConfig, i: int, *, active: bool) -> float:
    """Parameter bytes-relevant count of layer ``i``'s ffn/moe stage.

    ``active=True`` counts only routed-active experts (the decode-time
    weight stream); ``active=False`` counts the full expert bank (the
    prefill case, where a long chunk touches every expert)."""
    d = cfg.d_model
    if cfg.is_moe_layer(i) and cfg.n_experts:
        per_expert = 3.0 * d * cfg.moe_d_ff
        n_live = (cfg.top_k + cfg.n_shared_experts if active
                  else cfg.n_experts + cfg.n_shared_experts)
        return float(n_live * per_expert + d * cfg.n_experts)
    if cfg.d_ff <= 0:
        return 0.0
    mult = 3.0 if cfg.act == "swiglu" else 2.0
    return float(mult * d * cfg.d_ff)


def estimate_n_params(cfg: ModelConfig) -> float:
    """Analytic parameter-count estimate (embeddings + all layers,
    full expert banks).  Used to normalise per-layer shares when the
    caller supplies a measured ``n_params``."""
    total = float(cfg.vocab * cfg.d_model)
    if not cfg.tie_embeddings:
        total += cfg.vocab * cfg.d_model
    for i in range(cfg.n_layers):
        total += _mixer_params(cfg, i)
        total += _ffn_params(cfg, i, active=False)
    return total


def arch_kv_bytes_per_token(cfg: ModelConfig) -> float:
    """Total KV-cache bytes per token across all attention layers
    (bf16), mirroring ``ServingEngine._kv_bytes_per_token``."""
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.layer_kind(i) == "attn")
    if cfg.attn_type == "mla":
        per = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    else:
        per = 2 * cfg.n_kv_heads * cfg.head_dim
    return float(n_attn * per * 2)


@dataclass
class TracedWorkload:
    """A traced serving snapshot: per-layer work items, the precedence
    graph over their profiles (``graph.kernels[i] is items[i].profile()``
    output, same order), and which request each item belongs to."""

    items: list[TpuWorkItem]
    graph: KernelGraph
    owners: list[int]          # item index -> request index
    tail_of: list[int]         # request index -> index of its last item


#: default traced snapshot: a continuous-batching queue where two
#: prompts are mid-prefill while six earlier requests decode at
#: spread-out kv lengths — prefill compute and decode memory coexist.
_DEFAULT_REQUESTS = (("prefill", 512), ("prefill", 256),
                     ("decode", 512), ("decode", 1024), ("decode", 2048),
                     ("decode", 3072), ("decode", 4096), ("decode", 6144))


def trace_arch(cfg: ModelConfig,
               requests: Iterable[tuple[str, int]] | None = None,
               *,
               n_params: float | None = None,
               kv_bytes_per_token: float | None = None,
               max_stages: int | None = None) -> TracedWorkload:
    """Trace a model config into per-layer work-item chains.

    Each request ``("prefill", seq_len)`` / ``("decode", kv_len)``
    expands into the chain of stages its forward pass runs — layer 0
    mixer, layer 0 ffn, layer 1 mixer, ... — with one
    :class:`~repro.core.tpu.TpuWorkItem` per stage carrying that
    stage's parameter share (MoE ffn stages stream only routed-active
    experts on decode) and, for attention mixers, the layer's slice of
    the KV traffic.  Intra-request edges chain consecutive stages;
    cross-request items stay independent — exactly the structure the
    serving engine's per-request items flatten away.

    ``max_stages`` coarsens deep models by grouping consecutive stages
    into at most that many segments per request (shares and traffic
    sum), keeping graph sizes schedulable for 40-60 layer configs.
    """
    reqs = list(requests if requests is not None else _DEFAULT_REQUESTS)
    kvb_total = (kv_bytes_per_token if kv_bytes_per_token is not None
                 else arch_kv_bytes_per_token(cfg))
    n_attn = max(1, sum(1 for i in range(cfg.n_layers)
                        if cfg.layer_kind(i) == "attn"))
    kvb_layer = kvb_total / n_attn
    est = estimate_n_params(cfg)
    scale = (n_params / est) if n_params else 1.0

    items: list[TpuWorkItem] = []
    owners: list[int] = []
    tail_of: list[int] = []
    edges: set[tuple[int, int]] = set()
    for rid, (kind, length) in enumerate(reqs):
        if kind not in ("prefill", "decode"):
            raise ValueError(f"unknown request kind {kind!r}")
        # stage list: (label, param_share, kv_bytes_per_token)
        stages: list[tuple[str, float, float]] = []
        for i in range(cfg.n_layers):
            lk = cfg.layer_kind(i)
            stages.append((f"L{i}:{lk}", scale * _mixer_params(cfg, i),
                           kvb_layer if lk == "attn" else 0.0))
            ffn = _ffn_params(cfg, i, active=(kind == "decode"))
            if ffn > 0.0:
                lbl = "moe" if cfg.is_moe_layer(i) else "mlp"
                stages.append((f"L{i}:{lbl}", scale * ffn, 0.0))
        if max_stages is not None and len(stages) > max_stages:
            per = -(-len(stages) // max_stages)  # ceil
            grouped = []
            for s in range(0, len(stages), per):
                seg = stages[s:s + per]
                grouped.append((f"{seg[0][0]}..{seg[-1][0].split(':')[0]}",
                                sum(p for _, p, _ in seg),
                                sum(b for _, _, b in seg)))
            stages = grouped
        prev = None
        for label, share, kvb in stages:
            name = f"r{rid}:{kind[0]}:{label}"
            if kind == "prefill":
                it = prefill_profile(name, n_params=share, seq_len=length,
                                     kv_bytes_per_token=kvb)
            else:
                it = decode_profile(name, n_params=share, kv_len=length,
                                    kv_bytes_per_token=kvb)
            it = replace(it, weight_bytes=2.0 * share)  # bf16 stream
            idx = len(items)
            items.append(it)
            owners.append(rid)
            if prev is not None:
                edges.add((prev, idx))
            prev = idx
        tail_of.append(len(items) - 1)
    graph = KernelGraph([it.profile() for it in items], edges)
    return TracedWorkload(items=items, graph=graph, owners=owners,
                          tail_of=tail_of)
