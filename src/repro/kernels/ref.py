"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "decode_attention_ref", "rmsnorm_ref",
           "mamba_scan_ref"]

_NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, scale, causal=True, window=None):
    """q: (BH, S, D), k/v: (BH, T, D)."""
    BH, S, D = q.shape
    T = k.shape[1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    s = jnp.where(ok, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, scale):
    """q: (BH, 1, D), k/v: (BH, T, D), lengths: (BH, 1)."""
    BH, _, D = q.shape
    T = k.shape[1]
    s = jnp.einsum("bqd,btd->bqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ok = jnp.arange(T)[None, None, :] < lengths[:, :, None]
    s = jnp.where(ok, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqt,btd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def rmsnorm_ref(x, scale, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) *
            scale.astype(jnp.float32)).astype(x.dtype)


def mamba_scan_ref(x, dt, bm, cm, a, d_skip):
    """x/dt: (B, T, Dc); bm/cm: (B, T, S); a: (Dc, S); d: (Dc,)."""
    B, T, Dc = x.shape
    S = bm.shape[-1]

    def one(xb, dtb, bb, cb):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            dA = jnp.exp(dtt[:, None] * a.astype(jnp.float32))
            dBx = (dtt * xt)[:, None] * bt[None, :]
            h = h * dA + dBx
            y = jnp.sum(h * ct[None, :], axis=1) + \
                d_skip.astype(jnp.float32) * xt
            return h, y

        h0 = jnp.zeros((Dc, S), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xb.astype(jnp.float32),
                                        dtb.astype(jnp.float32),
                                        bb.astype(jnp.float32),
                                        cb.astype(jnp.float32)))
        return ys

    out = jax.vmap(one)(x, dt, bm, cm)
    return out.astype(x.dtype)
