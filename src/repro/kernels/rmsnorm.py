"""Pallas TPU fused RMSNorm kernel.

x: (R, D) rows; one pass: mean-of-squares reduction + rsqrt + scale in
VMEM, f32 accumulation regardless of input dtype.  Grid tiles rows in
``block_r`` chunks; D stays whole per program (lane-dim aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_rows"]


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (br, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_rows(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
                 block_r: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (R, D), scale: (D,) -> (R, D)."""
    R, D = x.shape
    block_r = min(block_r, R)
    while R % block_r:
        block_r //= 2
    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, scale)
