"""Pallas TPU flash-attention (forward) kernel.

Layout: inputs are head-flattened — q: (BH, S, D), k/v: (BH, T, D)
(GQA head repetition is resolved in :mod:`repro.kernels.ops`).

Grid: ``(BH, S // block_q)``.  Each program owns one (block_q, D) query
tile in VMEM and streams K/V tiles of ``block_k`` rows through the MXU
with the online-softmax recurrence (m, l running statistics in f32).
Block shapes are MXU-aligned (multiples of 128 on the contracting and
lane dims; D is padded by ops.py when a model uses head_dim < 128).

Causal and sliding-window masks are applied with iota comparisons on
the fly — no (S, T) mask tensor ever exists.  For causal programs the
KV loop stops at the tile covering the query block's last row; for
sliding windows it also starts at the first in-window tile, so compute
is O(S·window), matching the XLA twin (``blockwise_sdpa``).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_bh"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
            window: int | None, block_q: int, block_k: int, kv_len: int):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale           # (bq, D)
    bq, D = q.shape
    q_start = qi * block_q

    n_kv = kv_len // block_k
    if causal:
        # last tile index touching row (q_start + bq - 1)
        hi = (q_start + bq - 1) // block_k + 1
    else:
        hi = n_kv
    lo = 0
    if window is not None and causal:
        lo = jnp.maximum(q_start - window, 0) // block_k

    def body(ki, carry):
        m_acc, l_acc, o_acc = carry
        k = k_ref[pl.ds(ki * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(ki * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
        k_idx = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_k), 1)
        ok = jnp.ones((bq, block_k), jnp.bool_)
        if causal:
            ok &= k_idx <= q_idx
        if window is not None:
            ok &= k_idx > q_idx - window
        s = jnp.where(ok, s, _NEG_INF)
        m_new = jnp.maximum(m_acc, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_acc - m_new)
        l_new = l_acc * corr + jnp.sum(p, axis=1)
        o_new = o_acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return m_new, l_new, o_new

    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    o0 = jnp.zeros((bq, D), jnp.float32)
    m, l, o = jax.lax.fori_loop(lo, hi, body, (m0, l0, o0))
    o_ref[...] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_bh(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       scale: float, causal: bool = True,
                       window: int | None = None, block_q: int = 128,
                       block_k: int = 128,
                       interpret: bool = False) -> jnp.ndarray:
    """q: (BH, S, D), k/v: (BH, T, D) -> (BH, S, D)."""
    BH, S, D = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    grid = (BH, S // block_q)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=T)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, T, D), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, T, D), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
