"""jit'd public wrappers over the Pallas kernels.

Handle model-level layouts (GQA head grouping, head_dim padding to the
128-lane MXU width) and select ``interpret=True`` automatically off-TPU
so the same call sites validate on CPU and run compiled on TPU.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_bh
from .mamba_scan import mamba_scan_bd
from .flash_attention import flash_attention_bh
from .rmsnorm import rmsnorm_rows

__all__ = ["flash_attention", "decode_attention", "rmsnorm",
           "mamba_scan", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_d(x, to: int = 128):
    D = x.shape[-1]
    if D % to == 0:
        return x, D
    pad = to - D % to
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]), D


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, S, H, D), k/v: (B, T, Hkv, D) -> (B, S, H, D)."""
    interpret = default_interpret() if interpret is None else interpret
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    # repeat kv heads to match q heads, flatten (B, H)
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    qf, D0 = _pad_d(qf)
    kf, _ = _pad_d(kf)
    vf, _ = _pad_d(vf)
    out = flash_attention_bh(qf, kf, vf, scale=scale, causal=causal,
                             window=window, interpret=interpret)
    out = out[..., :D0]
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, lengths, *,
                     interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, H, D), k/v: (B, T, Hkv, D), lengths: (B,) -> (B, H, D)."""
    interpret = default_interpret() if interpret is None else interpret
    B, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    qf = q.reshape(B * H, 1, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    qf, D0 = _pad_d(qf)
    kf, _ = _pad_d(kf)
    vf, _ = _pad_d(vf)
    lens = jnp.repeat(lengths[:, None], H, axis=1).reshape(B * H, 1)
    out = decode_attention_bh(qf, kf, vf, lens, scale=scale,
                              interpret=interpret)
    return out[..., :D0].reshape(B, H, D)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6,
            interpret: bool | None = None) -> jnp.ndarray:
    """x: (..., D), scale: (D,)."""
    interpret = default_interpret() if interpret is None else interpret
    shape = x.shape
    rows = x.reshape(-1, shape[-1])
    out = rmsnorm_rows(rows, scale, eps=eps, interpret=interpret)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mamba_scan(x, dt, bm, cm, a, d_skip, *,
               interpret: bool | None = None) -> jnp.ndarray:
    """Selective scan: x/dt (B,T,Dc), bm/cm (B,T,S), a (Dc,S), d (Dc,)."""
    interpret = default_interpret() if interpret is None else interpret
    return mamba_scan_bd(x, dt, bm, cm, a, d_skip, interpret=interpret)
