"""Pallas TPU selective-scan (Mamba-1 recurrence) kernel.

Recurrence per channel c and state s:

    h[t] = exp(dt[t,c] * A[c,s]) * h[t-1] + dt[t,c] * B[t,s] * x[t,c]
    y[t,c] = sum_s h[t] * C[t,s] + D[c] * x[t,c]

Layout: inputs are batch-flattened — x/dt: (B, T, Dc), Bm/Cm: (B, T, S),
A: (Dc, S), D: (Dc,).  Grid: ``(B, Dc // block_d)``; each program owns a
(block_d, S) state tile in VMEM and walks the sequence in ``block_t``
chunks (sequential inner loop — the recurrence is inherently serial in
T, the parallelism is over channels x batch, which is exactly how the
official CUDA kernel is organised; on TPU the (block_d, S) tile keeps
the MXU/VPU busy per step).

This is the hardware-adapted analogue of Mamba's fused scan: the HBM
traffic is one read of (x, dt, B, C) and one write of y — intermediate
states never leave VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mamba_scan_bd"]


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, *,
            block_t: int, seq_len: int):
    # refs: x/dt (T, bd); b/c (T, S); a (bd, S); d (bd,); y (T, bd)
    bd = a_ref.shape[0]
    S = a_ref.shape[1]
    a = a_ref[...].astype(jnp.float32)                    # (bd, S)
    d_skip = d_ref[...].astype(jnp.float32)               # (bd,)

    def chunk(tc, h):
        t0 = tc * block_t
        x = x_ref[pl.ds(t0, block_t), :].astype(jnp.float32)   # (bt, bd)
        dt = dt_ref[pl.ds(t0, block_t), :].astype(jnp.float32)
        bm = b_ref[pl.ds(t0, block_t), :].astype(jnp.float32)  # (bt, S)
        cm = c_ref[pl.ds(t0, block_t), :].astype(jnp.float32)

        def step(i, carry):
            h = carry
            dA = jnp.exp(dt[i][:, None] * a)                   # (bd, S)
            dBx = (dt[i] * x[i])[:, None] * bm[i][None, :]     # (bd, S)
            h = h * dA + dBx
            y = jnp.sum(h * cm[i][None, :], axis=1)            # (bd,)
            y = y + d_skip * x[i]
            y_ref[t0 + i, :] = y.astype(y_ref.dtype)
            return h

        return jax.lax.fori_loop(0, block_t, step, h)

    h0 = jnp.zeros((bd, S), jnp.float32)
    jax.lax.fori_loop(0, seq_len // block_t, chunk, h0)


def mamba_scan_bd(x, dt, bm, cm, a, d_skip, *, block_d: int = 128,
                  block_t: int = 128, interpret: bool = False):
    """x/dt: (B, T, Dc); bm/cm: (B, T, S); a: (Dc, S); d: (Dc,).

    Returns y: (B, T, Dc)."""
    B, T, Dc = x.shape
    S = bm.shape[-1]
    block_d = min(block_d, Dc)
    while Dc % block_d:
        block_d //= 2
    block_t = min(block_t, T)
    while T % block_t:
        block_t //= 2
    kernel = functools.partial(_kernel, block_t=block_t, seq_len=T)
    return pl.pallas_call(
        kernel,
        grid=(B, Dc // block_d),
        in_specs=[
            pl.BlockSpec((None, T, block_d), lambda b, dc: (b, 0, dc)),
            pl.BlockSpec((None, T, block_d), lambda b, dc: (b, 0, dc)),
            pl.BlockSpec((None, T, S), lambda b, dc: (b, 0, 0)),
            pl.BlockSpec((None, T, S), lambda b, dc: (b, 0, 0)),
            pl.BlockSpec((block_d, S), lambda b, dc: (dc, 0)),
            pl.BlockSpec((block_d,), lambda b, dc: (dc,)),
        ],
        out_specs=pl.BlockSpec((None, T, block_d), lambda b, dc: (b, 0, dc)),
        out_shape=jax.ShapeDtypeStruct((B, T, Dc), x.dtype),
        interpret=interpret,
    )(x, dt, bm, cm, a, d_skip)
