"""Pallas TPU kernels (validated in interpret mode on CPU).

Each kernel ships three pieces: the ``pl.pallas_call`` implementation
with explicit BlockSpec VMEM tiling, a pure-jnp oracle in ``ref.py``,
and a jit'd public wrapper in ``ops.py``.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
