"""Pallas TPU kernels (validated in interpret mode on CPU).

Each kernel ships three pieces: the ``pl.pallas_call`` implementation
with explicit BlockSpec VMEM tiling, a pure-jnp oracle in ``ref.py``,
and a jit'd public wrapper in ``ops.py``.

:mod:`repro.kernels.event_scan` is the odd one out: not a model
kernel but the scheduler's own event-dispatcher admission/completion
scan, dispatched per candidate order (grid over the move batch) and
property-tested against ``repro.core.refine._FastEventSim``.
"""

from . import event_scan, ops, ref

__all__ = ["event_scan", "ops", "ref"]
