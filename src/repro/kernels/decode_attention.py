"""Pallas TPU decode-attention (flash-decode) kernel.

One new query token per (batch, head) against a long KV cache:
q: (BH, 1, D), k/v: (BH, T, D), valid length per row: (BH, 1).

Grid: ``(BH, T // block_k)`` — the KV axis is the *sequential* grid
dimension (TPU executes the last grid axis in order), so partial
(m, l, acc) online-softmax statistics accumulate in VMEM scratch and
are finalised by the last program.  Long caches therefore stream
through VMEM in ``block_k`` tiles; this is the kernel shape that makes
the ``long_500k`` cells viable on the sequence-sharded cache layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces; interpret mode accepts them too
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

__all__ = ["decode_attention_bh"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_k: int):
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale            # (1, D)
    k = k_ref[...].astype(jnp.float32)                    # (bk, D)
    v = v_ref[...].astype(jnp.float32)
    valid_len = len_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, bk)
    idx = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    s = jnp.where(idx < valid_len, s, _NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]               # (1,), (1,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    @pl.when(ki == n_k - 1)
    def _final():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


def decode_attention_bh(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        lengths: jnp.ndarray, *, scale: float,
                        block_k: int = 512,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (BH, 1, D), k/v: (BH, T, D), lengths: (BH, 1) -> (BH, 1, D)."""
    BH, _, D = q.shape
    T = k.shape[1]
    block_k = min(block_k, T)
    assert T % block_k == 0
    grid = (BH, T // block_k)
    kernel = functools.partial(_kernel, scale=scale, block_k=block_k)
    scratch = [
        _VMEM((1,), jnp.float32),      # m
        _VMEM((1,), jnp.float32),      # l
        _VMEM((1, D), jnp.float32),    # acc
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 1, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((None, block_k, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((None, block_k, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((None, 1), lambda bh, ki: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((None, 1, D), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v, lengths)
