"""Pallas admission/completion scan for the event-model dispatcher.

The inner loop of :class:`repro.core.refine._FastEventSim` — admit the
head kernel's blocks round-robin first-fit, advance time to the next
cohort retirement, repeat — is a per-candidate sequential scan with a
small fixed-shape state (per-unit ``used``/residency plus
``max_resident`` cohort slots per unit).  That shape is exactly what an
accelerator wants: grid over the B candidate orders, each program
walking its own order row with the state resident in VMEM, the shared
kernel table broadcast to every program.  One dispatch then scores a
whole move batch — the dispatch-discipline requirement (see
``repro.core.batched``) that makes device-side scheduling pay for its
launch.

Three pieces, same float32 arithmetic:

* :func:`event_scan_core` — the scan over one order row as a pure jax
  function (``lax.while_loop`` over events, per-block admission with
  the reference's same-instant cohort merge).
* :func:`event_times_jax` — ``jit(vmap(core))`` over the batch; the
  kernel table is broadcast (``in_axes=None``).
* :func:`event_times_pallas` — ``pl.pallas_call`` with ``grid=(B,)``,
  one ``(1, n)`` order row per program and broadcast table operands;
  ``interpret=True`` (the default off-TPU) runs the same kernel on CPU
  for tier-1 tests, the compiled path is exercised under the
  ``requires_jax_device`` marker.

float32 deviations from the float64 reference, all documented and
property-tested (``tests/test_batched.py``):

* admission slack — the reference admits on ``used + dem <= cap +
  1e-12``; in float32 the accumulated ``used`` carries ~1e-7 relative
  rounding, so the scan uses ``cap * F32_FIT_RTOL`` slack instead,
  sized well below any per-block demand (which is what real rejections
  are measured in) but above float32 accumulation noise, keeping
  admission *decisions* identical to the reference's.
* retirement threshold — the reference retires a cohort at
  ``frac <= 1e-9``; float32 cannot resolve 1e-9 against O(1) block
  fractions, so the scan retires at ``frac <= 1e-6`` (still below any
  modelled work quantum).
* times — event instants accumulate float32 rounding over O(n) events;
  :data:`F32_EVENT_RTOL` bounds the relative error vs the reference.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

try:  # pragma: no cover - exercised implicitly by import
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    HAS_JAX = True
except Exception:  # pragma: no cover
    jax = jnp = pl = None
    HAS_JAX = False

__all__ = ["HAS_JAX", "F32_EVENT_RTOL", "F32_FIT_RTOL", "EventScanConfig",
           "config_for_device", "event_scan_core", "event_times_jax",
           "event_times_pallas", "event_times_reference"]

#: relative tolerance of float32 scan times vs the float64
#: ``_FastEventSim`` (audited in tests; observed error is ~1e-6).
F32_EVENT_RTOL = 5e-4

#: admission slack as a fraction of each capacity (see module docstring).
F32_FIT_RTOL = 1e-5

#: float32 retirement threshold (reference: 1e-9 in float64).
_RETIRE_EPS = 1e-6

_EPS = 1e-12


class EventScanConfig(NamedTuple):
    """Static (hashable) device geometry for the scan."""

    caps: tuple          # per-dim capacities, device.caps order
    n_units: int
    max_resident: int
    sat_idx: int         # index of sat_dim in caps order, -1 if absent
    compute_rate: float
    mem_bw: float
    sat_compute: float
    sat_memory: float


def config_for_device(device) -> EventScanConfig:
    dims = tuple(device.caps)
    return EventScanConfig(
        caps=tuple(device.cap(d) for d in dims),
        n_units=int(device.n_units),
        max_resident=int(device.max_resident),
        sat_idx=(dims.index(device.sat_dim)
                 if device.sat_dim in dims else -1),
        compute_rate=float(device.compute_rate),
        mem_bw=float(device.mem_bw),
        sat_compute=float(device.sat_compute),
        sat_memory=float(device.sat_memory),
    )


def event_scan_core(row, nbk, dem, inst_b, mem_b, caps,
                    cfg: EventScanConfig):
    """Event-model makespan of one order ``row`` ((n,) int32 indices
    into the kernel table) — float32, pure jax, shape-static.

    ``caps`` is the (D,) float32 capacity vector, passed as an operand
    (not closed over) so the same body traces as a Pallas kernel.

    Mirrors ``_FastEventSim.simulate`` from a fresh start: per-block
    cyclic first-fit admission from the round-robin pointer with
    same-instant cohort merge, rate recompute from cohort work sums,
    completion events at ``min(frac / lam)``, oversized heads draining
    alone in ``ceil(blocks / n_units)`` solo passes.
    """
    n = row.shape[0]
    U, C = cfg.n_units, max(cfg.max_resident, 1)
    D = len(cfg.caps)
    fit_slack = caps * F32_FIT_RTOL + _EPS
    max_res = cfg.max_resident
    f32 = jnp.float32

    def rates(used, ckn, cnb, cin, cmb):
        occ_m = cnb > 0
        sum_c = jnp.sum(cin * cnb.astype(f32), axis=1)      # (U,)
        sum_m = jnp.sum(cmb * cnb.astype(f32), axis=1)
        if cfg.sat_idx >= 0:
            occ = used[:, cfg.sat_idx]
            eff_c = jnp.maximum(jnp.minimum(1.0, occ / cfg.sat_compute),
                                _EPS)
            eff_m = jnp.maximum(jnp.minimum(1.0, occ / cfg.sat_memory),
                                _EPS)
        else:
            eff_c = eff_m = jnp.ones((U,), f32)
        lam = jnp.minimum(
            cfg.compute_rate * eff_c / jnp.maximum(sum_c, _EPS),
            cfg.mem_bw * eff_m / jnp.maximum(sum_m, _EPS))
        return jnp.where(occ_m.any(axis=1), lam, 0.0)

    # state: t, head, bleft, rr, used (U,D), nres (U,),
    # ckn/cnb (U,C) int32, cfr/cta/cin/cmb (U,C) f32.
    def admit_one(s):
        """Place one block of the head kernel (cond guarantees fit)."""
        (t, head, bleft, rr, used, nres, ckn, cnb, cfr, cta,
         cin, cmb) = s
        kid = row[jnp.minimum(head, n - 1)]
        dk = dem[kid]                                        # (D,)
        fits = ((nres + 1 <= max_res) &
                jnp.all(used + dk[None, :] <= caps[None, :] +
                        fit_slack[None, :], axis=1))         # (U,)
        off = (jnp.arange(U, dtype=jnp.int32) - rr) % U
        u = jnp.argmin(jnp.where(fits, off, U).astype(jnp.int32))
        used = used.at[u].add(dk)
        nres = nres.at[u].add(1)
        # same-instant cohort merge: a (kernel, instant) cohort is
        # unique per unit, so at most one slot matches.
        match = (cnb[u] > 0) & (ckn[u] == kid) & (cta[u] == t)
        slot = jnp.where(match.any(), jnp.argmax(match),
                         jnp.argmin(cnb[u] > 0))             # first free
        cnb = cnb.at[u, slot].add(1)
        ckn = ckn.at[u, slot].set(kid)
        cfr = cfr.at[u, slot].set(jnp.where(match.any(), cfr[u, slot],
                                            f32(1.0)))
        cta = cta.at[u, slot].set(t)
        cin = cin.at[u, slot].set(inst_b[kid])
        cmb = cmb.at[u, slot].set(mem_b[kid])
        rr = (u.astype(jnp.int32) + 1) % U
        bleft = bleft - 1
        adv = bleft == 0
        head = head + jnp.where(adv, 1, 0)
        nxt = row[jnp.minimum(head, n - 1)]
        bleft = jnp.where(adv & (head < n), nbk[nxt], bleft)
        return (t, head, bleft, rr, used, nres, ckn, cnb, cfr, cta,
                cin, cmb)

    def can_admit(s):
        (t, head, bleft, rr, used, nres, ckn, cnb, cfr, cta,
         cin, cmb) = s
        kid = row[jnp.minimum(head, n - 1)]
        dk = dem[kid]
        fits = ((nres + 1 <= max_res) &
                jnp.all(used + dk[None, :] <= caps[None, :] +
                        fit_slack[None, :], axis=1))
        return (head < n) & fits.any()

    def step(s):
        s = jax.lax.while_loop(can_admit, admit_one, s)
        (t, head, bleft, rr, used, nres, ckn, cnb, cfr, cta,
         cin, cmb) = s
        nres_tot = nres.sum()

        def oversized(s):
            (t, head, bleft, rr, used, nres, ckn, cnb, cfr, cta,
             cin, cmb) = s
            kid = row[jnp.minimum(head, n - 1)]
            occ = dem[kid, cfg.sat_idx] if cfg.sat_idx >= 0 else f32(0.0)
            eff_c = jnp.maximum(jnp.minimum(1.0, occ / cfg.sat_compute),
                                _EPS) if cfg.sat_idx >= 0 else f32(1.0)
            eff_m = jnp.maximum(jnp.minimum(1.0, occ / cfg.sat_memory),
                                _EPS) if cfg.sat_idx >= 0 else f32(1.0)
            t1 = jnp.maximum(inst_b[kid] / (cfg.compute_rate * eff_c),
                             mem_b[kid] / (cfg.mem_bw * eff_m))
            passes = jnp.ceil(bleft.astype(f32) / U).astype(jnp.int32)
            t = t + passes.astype(f32) * t1
            head = head + 1
            nxt = row[jnp.minimum(head, n - 1)]
            bleft = jnp.where(head < n, nbk[nxt], bleft)
            return (t, head, bleft, rr, used, nres, ckn, cnb, cfr, cta,
                    cin, cmb)

        def complete(s):
            (t, head, bleft, rr, used, nres, ckn, cnb, cfr, cta,
             cin, cmb) = s
            lam = rates(used, ckn, cnb, cin, cmb)            # (U,)
            occ_m = cnb > 0
            ttf = jnp.where(occ_m, cfr / lam[:, None], jnp.inf)
            dt = ttf.min()
            t = t + dt
            cfr = jnp.where(occ_m, cfr - lam[:, None] * dt, cfr)
            fin = occ_m & (cfr <= _RETIRE_EPS)
            nb_f = jnp.where(fin, cnb, 0)
            used = used - jnp.sum(
                dem[ckn] * nb_f.astype(f32)[:, :, None], axis=1)
            nres = nres - nb_f.sum(axis=1)
            cnb = jnp.where(fin, 0, cnb)
            return (t, head, bleft, rr, used, nres, ckn, cnb, cfr, cta,
                    cin, cmb)

        return jax.lax.cond((nres_tot == 0) & (head < n), oversized,
                            lambda s: jax.lax.cond(nres_tot > 0,
                                                   complete,
                                                   lambda x: x, s), s)

    def not_done(s):
        t, head, bleft, rr, used, nres = s[:6]
        return (head < n) | (nres.sum() > 0)

    s0 = (f32(0.0), jnp.int32(0), nbk[row[0]], jnp.int32(0),
          jnp.zeros((U, D), f32), jnp.zeros((U,), jnp.int32),
          jnp.full((U, C), -1, jnp.int32), jnp.zeros((U, C), jnp.int32),
          jnp.zeros((U, C), f32), jnp.full((U, C), -1.0, f32),
          jnp.zeros((U, C), f32), jnp.zeros((U, C), f32))
    out = jax.lax.while_loop(not_done, step, s0)
    return out[0]


def _pack_f32(table):
    """Kernel-table arrays for the scan, cached on the ProfileTable."""
    cached = getattr(table, "_event_scan_pack", None)
    if cached is not None:
        return cached
    dev = table.device
    dims = tuple(dev.caps)
    dem = np.stack([
        np.array([k.demands.get(d, 0.0) for d in dims], dtype=np.float32)
        for k in table.kernels])
    pack = (
        np.array([int(k.n_blocks) for k in table.kernels], dtype=np.int32),
        dem,
        np.array([k.inst_per_block for k in table.kernels],
                 dtype=np.float32),
        np.array([k.mem_per_block() for k in table.kernels],
                 dtype=np.float32),
    )
    table._event_scan_pack = pack
    return pack


def event_times_jax(rows: np.ndarray, table) -> np.ndarray:
    """``jit(vmap)`` batch of :func:`event_scan_core` — rows (B, n)
    int indices into ``table.kernels``; returns (B,) float32 times."""
    if not HAS_JAX:
        raise RuntimeError("event_times_jax requires jax")
    nbk, dem, inst_b, mem_b = _pack_f32(table)
    cfg = config_for_device(table.device)
    fn = _jax_batch(cfg)
    return np.asarray(fn(jnp.asarray(rows, jnp.int32), jnp.asarray(nbk),
                         jnp.asarray(dem), jnp.asarray(inst_b),
                         jnp.asarray(mem_b),
                         jnp.asarray(cfg.caps, jnp.float32)))


@functools.lru_cache(maxsize=None)
def _jax_batch(cfg: EventScanConfig):
    core = functools.partial(event_scan_core, cfg=cfg)
    return jax.jit(jax.vmap(core,
                            in_axes=(0, None, None, None, None, None)))


def event_times_pallas(rows: np.ndarray, table, *,
                       interpret: bool | None = None) -> np.ndarray:
    """Pallas dispatch of the scan: ``grid=(B,)``, one order row per
    program, kernel table broadcast to all programs.  ``interpret``
    defaults to True unless a TPU is attached (tier-1 runs on CPU)."""
    if not HAS_JAX:
        raise RuntimeError("event_times_pallas requires jax")
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu",)
    nbk, dem, inst_b, mem_b = _pack_f32(table)
    cfg = config_for_device(table.device)
    B, n = rows.shape
    K, D = dem.shape

    def kernel(row_ref, nbk_ref, dem_ref, inst_ref, mem_ref, caps_ref,
               out_ref):
        out_ref[0] = event_scan_core(
            row_ref[0, :], nbk_ref[...], dem_ref[...], inst_ref[...],
            mem_ref[...], caps_ref[...], cfg)

    call = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, n), lambda b: (b, 0)),
            pl.BlockSpec((K,), lambda b: (0,)),
            pl.BlockSpec((K, D), lambda b: (0, 0)),
            pl.BlockSpec((K,), lambda b: (0,)),
            pl.BlockSpec((K,), lambda b: (0,)),
            pl.BlockSpec((D,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )
    return np.asarray(call(jnp.asarray(rows, jnp.int32),
                           jnp.asarray(nbk), jnp.asarray(dem),
                           jnp.asarray(inst_b), jnp.asarray(mem_b),
                           jnp.asarray(cfg.caps, jnp.float32)))


def event_times_reference(rows: np.ndarray, table) -> np.ndarray:
    """float64 oracle: ``_FastEventSim`` on each row (for tests)."""
    from repro.core.refine import _FastEventSim

    sim = _FastEventSim(table.device)
    out = np.empty(rows.shape[0], dtype=np.float64)
    for b in range(rows.shape[0]):
        order = [table.kernels[i] for i in rows[b]]
        out[b] = sim.simulate(order)[0]
    return out
