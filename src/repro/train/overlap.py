"""Compute/communication overlap scheduling for training.

The training-side use of the paper's reordering insight: gradient
all-reduce buckets (interconnect-bound, intensity ~0) and backward
compute tasks (compute-bound) are independent work items within a step
window.  Ordering bucket launches so each "round" pairs a comm-bound
bucket with compute-bound work keeps both the ICI links and the MXU
busy — the same ScoreGen machinery composes the schedule.

On the XLA side the actual overlap is performed by the latency-hiding
scheduler once collectives are *emitted in the chosen order*; this
module decides bucket membership and launch order, and provides a
roofline estimate of exposed (non-overlapped) communication time for
the chosen schedule, which the tests assert improves on naive ordering.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core import DeviceModel, KernelProfile, greedy_order_fast

__all__ = ["CommTask", "ComputeTask", "make_overlap_device",
           "overlap_schedule", "exposed_comm_time"]


@dataclass(frozen=True)
class ComputeTask:
    name: str
    flops: float


@dataclass(frozen=True)
class CommTask:
    name: str
    bytes: float


def make_overlap_device(*, peak_flops: float = 197e12,
                        link_bw: float = 50e9) -> DeviceModel:
    """One 'execution unit' whose two resources are MXU time and link
    time; R is flops/byte so compute tasks sit far above R_B and comm
    tasks far below — the paper's mixing rule pairs them."""
    return DeviceModel(
        name="overlap", n_units=1,
        caps={"slots": 64.0},
        max_resident=64,
        compute_rate=peak_flops,
        mem_bw=link_bw,
        r_balanced=peak_flops / link_bw,
        r_weight=4.0, residual_weight=0.5,
        combined_r="harmonic",
    )


def _profile(task, device) -> KernelProfile:
    if isinstance(task, ComputeTask):
        return KernelProfile(task.name, 1, {"slots": 1.0},
                             inst_per_block=task.flops,
                             r=1e9)          # pure compute
    return KernelProfile(task.name, 1, {"slots": 1.0},
                         inst_per_block=task.bytes * 1e-9,
                         r=1e-9)             # pure comm ("memory" = link)


def overlap_schedule(tasks: Sequence, device: DeviceModel | None = None
                     ) -> list[str]:
    """Launch order (task names) from Algorithm 1."""
    device = device or make_overlap_device()
    profs = [_profile(t, device) for t in tasks]
    sched = greedy_order_fast(profs, device)
    return [k.name for k in sched.order]


def exposed_comm_time(order: Sequence[str], tasks: Sequence,
                      device: DeviceModel | None = None,
                      window: int = 2) -> float:
    """Roofline estimate of non-overlapped communication: tasks are
    issued in ``order``; within each consecutive window the comm time
    hides under compute time, max(c, m); across windows it serialises."""
    device = device or make_overlap_device()
    by = {t.name: t for t in tasks}
    total = 0.0
    for i in range(0, len(order), window):
        grp = [by[n] for n in order[i:i + window]]
        c = sum(t.flops for t in grp if isinstance(t, ComputeTask))
        m = sum(t.bytes for t in grp if isinstance(t, CommTask))
        total += max(c / device.compute_rate, m / device.mem_bw)
    return total
