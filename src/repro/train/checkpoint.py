"""Sharded, atomic, async checkpointing with cross-mesh restore.

Layout::

    <dir>/step_000123/
        MANIFEST.json           # tree structure, shapes, dtypes, step,
                                # data-pipeline state, mesh shape
        shard_<host>.npz        # host-local flattened leaves
    <dir>/LATEST                # atomic pointer file

Design points for large fleets:

* **atomic publish** — shards are written to ``step_*.tmp`` and the
  directory is renamed before ``LATEST`` is swapped, so a killed host
  never leaves a half-checkpoint visible (restart reads the previous
  one),
* **async save** — a background thread serialises device-fetched
  arrays so the train loop only blocks for the device->host copy,
* **elastic restore** — leaves are stored with their *global* logical
  shapes; a restart on a different mesh re-shards via
  ``jax.make_array_from_callback`` against the new sharding, so scaling
  from 256 to 512 chips (or down to 1 CPU for debugging) is a restore,
  not a conversion.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

PyTree = Any


def _flatten_with_paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _treedef_of(tree: PyTree):
    return jax.tree_util.tree_structure(tree)


def latest_step(ckpt_dir: str) -> int | None:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        txt = f.read().strip()
    return int(txt) if txt else None


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    extra: dict | None = None, host_id: int = 0,
                    n_hosts: int = 1) -> str:
    """Synchronous sharded save with atomic publish."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "extra": extra or {},
        "n_hosts": n_hosts,
        "leaves": [{"key": k,
                    "shape": list(np.shape(v)),
                    "dtype": str(np.asarray(v).dtype
                                 if not hasattr(v, "dtype") else v.dtype)}
                   for k, v in leaves],
    }
    arrays = {}
    for i, (k, v) in enumerate(leaves):
        a = np.asarray(jax.device_get(v))
        if a.dtype.name == "bfloat16":
            a = a.astype(np.float32)  # npz-portable; dtype in manifest
        arrays[f"leaf_{i}"] = a
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **arrays)
    if host_id == 0:
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
    # Atomic publish.
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def restore_checkpoint(ckpt_dir: str, target: PyTree, step: int | None = None,
                       shardings: PyTree | None = None, host_id: int = 0
                       ) -> tuple[PyTree, dict]:
    """Restore into the structure of ``target``; reshard if ``shardings``
    (a pytree of ``NamedSharding`` matching target) is given."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, f"shard_{host_id}.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(len(manifest["leaves"]))]
    flat_t, treedef = jax.tree_util.tree_flatten(target)
    assert len(flat_t) == len(leaves), \
        f"checkpoint has {len(leaves)} leaves, target {len(flat_t)}"
    if shardings is not None:
        flat_s = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda s: hasattr(s, "spec"))[0]
        out = []
        for arr, tgt, shd in zip(leaves, flat_t, flat_s):
            arr = arr.astype(tgt.dtype)
            out.append(jax.make_array_from_callback(
                arr.shape, shd, lambda idx, a=arr: a[idx]))
        leaves = out
    else:
        leaves = [jnp.asarray(a, dtype=t.dtype)
                  for a, t in zip(leaves, flat_t)]
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (one in flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, tree: PyTree, extra: dict | None = None
             ) -> None:
        self.wait()
        # device_get on the caller thread (consistent snapshot), write
        # on the background thread.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def run():
            save_checkpoint(self.ckpt_dir, step, host_tree, extra)
            self.last_saved = step
            self._gc()

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(int(d.split("_")[1])
                       for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
