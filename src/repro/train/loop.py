"""Fault-tolerant training loop.

Wraps the pjit'd train step with the operational machinery a fleet
deployment needs:

* auto-resume from the latest valid checkpoint (atomic-publish format,
  see :mod:`repro.train.checkpoint`), including data-pipeline state,
* periodic async checkpoints,
* retry-with-backoff around transient step failures (preemption,
  flaky interconnect); after ``max_retries`` the loop re-raises so the
  cluster scheduler can reschedule the job — which then auto-resumes,
* NaN/inf loss guard: skip the update (grads discarded) and count it;
  abort if the guard trips persistently,
* elastic restart: the checkpoint stores global logical shapes, so the
  same ``resume()`` works after the mesh changed (see
  ``checkpoint.restore_checkpoint(shardings=...)``).

Straggler note: on real fleets the per-step all-reduce acts as a
barrier; mitigation here is (a) deterministic host-sharded data (any
host can be replaced and replays its stream from the manifest step) and
(b) bounded-staleness checkpoint cadence so a lost host costs at most
``ckpt_every`` steps of work.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.data.pipeline import SyntheticLM
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint)

__all__ = ["LoopConfig", "TrainLoop"]


@dataclass
class LoopConfig:
    total_steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    max_retries: int = 3
    retry_backoff_s: float = 1.0
    max_nan_skips: int = 10


@dataclass
class TrainLoop:
    step_fn: Callable          # (params, opt_state, batch) -> (p, o, metrics)
    data: SyntheticLM
    cfg: LoopConfig
    log_fn: Callable[[int, dict], None] = lambda s, m: None

    nan_skips: int = 0

    def resume_or_init(self, params, opt_state, shardings=None):
        """Returns (params, opt_state, start_step)."""
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        tree = {"params": params, "opt": opt_state}
        tree, extra = restore_checkpoint(self.cfg.ckpt_dir, tree,
                                         shardings=shardings)
        self.data.load_state_dict(extra.get("data", {"step": 0}))
        return tree["params"], tree["opt"], int(extra.get("step", step))

    def run(self, params, opt_state, start_step: int = 0) -> tuple:
        ckpt = AsyncCheckpointer(self.cfg.ckpt_dir, keep=self.cfg.keep)
        metrics_hist = []
        for step in range(start_step, self.cfg.total_steps):
            batch = self.data.next_batch()
            for attempt in range(self.cfg.max_retries + 1):
                try:
                    new_p, new_o, metrics = self.step_fn(
                        params, opt_state, batch)
                    break
                except Exception:
                    if attempt == self.cfg.max_retries:
                        ckpt.wait()
                        raise
                    time.sleep(self.cfg.retry_backoff_s * (2 ** attempt))
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                self.nan_skips += 1
                if self.nan_skips > self.cfg.max_nan_skips:
                    ckpt.wait()
                    raise FloatingPointError(
                        f"loss non-finite {self.nan_skips} times")
                continue  # skip the poisoned update
            params, opt_state = new_p, new_o
            metrics_hist.append(loss)
            if step % self.cfg.log_every == 0:
                self.log_fn(step, metrics)
            if self.cfg.ckpt_every and (step + 1) % self.cfg.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extra={"step": step + 1,
                                 "data": self.data.state_dict()})
        ckpt.save(self.cfg.total_steps,
                  {"params": params, "opt": opt_state},
                  extra={"step": self.cfg.total_steps,
                         "data": self.data.state_dict()})
        ckpt.wait()
        return params, opt_state, metrics_hist
