"""Loss and train-step builders (pjit-ready pure functions)."""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = ["cross_entropy", "loss_fn", "make_train_step", "make_eval_step",
           "init_train_state"]

PyTree = Any


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  ignore_id: int = -1) -> jnp.ndarray:
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(x: jnp.ndarray, head_w: jnp.ndarray,
                          labels: jnp.ndarray, *, n_chunks: int = 0,
                          ignore_id: int = -1) -> jnp.ndarray:
    """CE over (B,S,d) features without materialising (B*S, V) logits.

    Tokens are processed in ``n_chunks`` scanned, remat'd chunks — peak
    memory is one chunk of logits; backward recomputes each chunk.
    ``n_chunks=0`` sizes chunks to ~64k global tokens.
    """
    B, S, d = x.shape
    T_ = B * S
    if n_chunks <= 0:
        n_chunks = max(1, T_ // 65536)
    n_chunks = min(n_chunks, T_)
    while T_ % n_chunks:
        n_chunks -= 1
    xf = x.reshape(n_chunks, T_ // n_chunks, d)
    lf = labels.reshape(n_chunks, T_ // n_chunks)

    @jax.checkpoint
    def chunk(carry, inp):
        xc, lc = inp
        logits = (xc @ head_w.astype(xc.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, lc[:, None].astype(jnp.int32), axis=-1)[:, 0]
        mask = (lc != ignore_id).astype(jnp.float32)
        num, den = carry
        return (num + jnp.sum((lse - ll) * mask), den + jnp.sum(mask)), None

    (num, den), _ = jax.lax.scan(chunk, (jnp.float32(0), jnp.float32(0)),
                                 (xf, lf))
    return num / jnp.maximum(den, 1.0)


def cast_matmul_params(params: PyTree, dtype=jnp.bfloat16) -> PyTree:
    """Mixed precision: one bf16 copy of every >=2-D f32 weight, made
    ONCE per step before the layer loop.  FSDP all-gathers and gradient
    reduce-scatters then move bf16 instead of f32 — half the collective
    bytes (measured in EXPERIMENTS.md §Perf).  1-D leaves (norms,
    biases, gates) stay f32; the f32 master copy lives in the optimizer
    update path."""
    def cast(p):
        if p.dtype == jnp.float32 and p.ndim >= 2:
            return p.astype(dtype)
        return p

    return jax.tree.map(cast, params)


def loss_fn(params: PyTree, cfg: ModelConfig, batch: dict,
            *, lb_weight: float = 0.01, z_weight: float = 1e-3,
            remat: bool = True, loss_chunks: int = 0,
            unroll: bool = False,
            mixed_precision: bool = True) -> tuple[jnp.ndarray, dict]:
    if mixed_precision:
        params = cast_matmul_params(params)
    feats, aux = T.forward_features(params, cfg, batch["inputs"],
                                    remat=remat, unroll=unroll)
    ce = chunked_cross_entropy(feats, T.head_matrix(params, cfg),
                               batch["labels"], n_chunks=loss_chunks)
    loss = ce + lb_weight * aux["moe_lb_loss"] + z_weight * aux["moe_z_loss"]
    metrics = {"loss": loss, "ce": ce, **aux}
    return loss, metrics


def init_train_state(key, cfg: ModelConfig) -> tuple[PyTree, PyTree]:
    params = T.init(key, cfg)
    return params, adamw_init(params)


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, *,
                    accum: int = 1, remat: bool = True,
                    unroll: bool = False):
    """Returns ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)``.  With ``accum > 1`` the batch's leading dim is split
    into microbatches and gradients are accumulated in f32 (scanned, so
    the lowered HLO stays one microbatch wide)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, remat=remat,
                                   unroll=unroll)
        return grads, metrics

    def step(params, opt_state, batch):
        if accum == 1:
            grads, metrics = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                g, m = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        params, opt_state, opt_metrics = adamw_update(
            opt, params, grads, opt_state)
        return params, opt_state, {**metrics, **opt_metrics}

    return step


def make_eval_step(cfg: ModelConfig):
    def step(params, batch):
        _, metrics = loss_fn(params, cfg, batch, remat=False)
        return metrics

    return step
