"""Training substrate: step builders, loop, checkpointing."""

from .checkpoint import (AsyncCheckpointer, latest_step, restore_checkpoint,
                         save_checkpoint)
from .loop import LoopConfig, TrainLoop
from .step import (chunked_cross_entropy, cross_entropy, init_train_state,
                   loss_fn, make_eval_step, make_train_step)

__all__ = ["AsyncCheckpointer", "latest_step", "restore_checkpoint",
           "save_checkpoint", "LoopConfig", "TrainLoop",
           "chunked_cross_entropy", "cross_entropy", "init_train_state",
           "loss_fn", "make_eval_step", "make_train_step"]
