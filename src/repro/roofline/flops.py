"""Analytic per-cell FLOPs / HBM-bytes model.

``cost_analysis()`` counts every ``while`` body once regardless of trip
count (verified in EXPERIMENTS.md §Roofline), which silently drops the
layer scan, microbatch accumulation, blockwise-attention KV streaming
and recurrent scans.  The roofline therefore uses this analytic model —
exact for the matmul-dominated terms because the einsum dimensions are
known — and validates it against two-point depth extrapolation of the
compiled dry-run (``flops(2 units) - flops(1 unit)`` = one unit's true
cost; see ``repro.roofline.correction``).

Conventions:
* forward FLOPs = 2 * (weights touched) per token + attention core;
* training multiplies forward by 4 (backward ~2x + full-remat
  recompute ~1x), inference by 1;
* bytes: parameter/optimizer streams per device + activation traffic
  + attention KV streams (restreamed once per query block by both the
  Pallas kernel and its XLA twin).
"""

from __future__ import annotations

from repro.configs import SHAPES, get_config
from repro.models import transformer as T
from repro.models.common import ModelConfig

__all__ = ["cell_flops", "cell_bytes", "layer_fwd_flops_per_token"]

_TRAIN_MULT = 4.0   # fwd + bwd(2x) + remat recompute(1x)


def _attn_core_ctx(cfg: ModelConfig, spec) -> float:
    """Average attended context length per query token."""
    S = spec.seq_len
    if spec.kind == "decode":
        ctx = S
    else:
        ctx = (S + 1) / 2 if cfg.causal else S
    if cfg.sliding_window is not None:
        ctx = min(ctx, cfg.sliding_window)
    return float(ctx)


def layer_fwd_flops_per_token(cfg: ModelConfig, i: int, ctx: float) -> float:
    d = cfg.d_model
    kind = cfg.layer_kind(i)
    f = 0.0
    if kind == "attn":
        if cfg.attn_type == "mla":
            H = cfg.n_heads
            dn, dr, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                          cfg.v_head_dim)
            r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
            w = d * r_kv + d * dr + r_kv * H * (dn + dv) + H * dv * d
            w += (d * r_q + r_q * H * (dn + dr)) if r_q else d * H * (dn + dr)
            f += 2 * w
            f += 2 * H * ((dn + dr) + dv) * ctx  # scores + PV
        else:
            H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            w = d * hd * (H + 2 * Hkv) + H * hd * d
            f += 2 * w
            f += 2 * H * hd * 2 * ctx
    elif kind == "mamba":
        di, ds, dtr, dc = (cfg.mamba_d_inner, cfg.mamba_d_state,
                           cfg.dt_rank, cfg.mamba_d_conv)
        w = d * 2 * di + di * (dtr + 2 * ds) + dtr * di + di * d
        f += 2 * w + 2 * dc * di + 10 * di * ds
    elif kind == "mlstm":
        di = int(cfg.d_model * cfg.xlstm_proj_factor)
        di = -(-di // cfg.n_heads) * cfg.n_heads
        H = cfg.n_heads
        hd = di // H
        ck = 256.0
        w = d * 2 * di + 3 * di * di + di * d + di * 2 * H
        f += 2 * w + 4 * H * hd * ck + 6 * H * hd * hd
    elif kind == "slstm":
        H = cfg.n_heads
        hd = d // H
        ffd = int(d * 4 / 3)
        w = d * 4 * d + 4 * H * hd * hd + d * 2 * ffd + ffd * d
        f += 2 * w
    # ffn / moe
    if cfg.is_moe_layer(i):
        ff = cfg.moe_d_ff
        k_act = cfg.top_k * cfg.capacity_factor + cfg.n_shared_experts
        mult = 3 if cfg.act == "swiglu" else 2
        f += 2 * mult * d * ff * k_act + 2 * d * cfg.n_experts
    elif kind in ("attn", "mamba") and cfg.d_ff:
        mult = 3 if cfg.act == "swiglu" else 2
        f += 2 * mult * d * cfg.d_ff
    return f


def cell_flops(arch: str, shape_name: str) -> float:
    """Total true FLOPs of one step of the cell (all devices)."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    ctx = _attn_core_ctx(cfg, spec)
    per_tok = sum(layer_fwd_flops_per_token(cfg, i, ctx)
                  for i in range(cfg.n_layers))
    head = 2 * cfg.d_model * cfg.vocab
    if spec.kind == "decode":
        tokens = float(spec.global_batch)
        head_tokens = tokens
    elif spec.kind == "prefill":
        tokens = float(spec.global_batch * spec.seq_len)
        head_tokens = float(spec.global_batch)  # last position only
    else:
        tokens = float(spec.global_batch * spec.seq_len)
        head_tokens = tokens
    mult = _TRAIN_MULT if spec.kind == "train" else 1.0
    return (per_tok * tokens + head * head_tokens) * mult


def _param_count(cfg: ModelConfig) -> float:
    import jax
    shapes = jax.eval_shape(
        lambda: T.init(jax.random.PRNGKey(0), cfg))
    return float(sum(int(x.size) for x in jax.tree.leaves(shapes)))


def cell_bytes(arch: str, shape_name: str, n_devices: int,
               accum: int = 1) -> float:
    """Per-device HBM traffic of one step (analytic)."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    P = _param_count(cfg)
    d = cfg.d_model
    if spec.kind == "decode":
        tokens_dev = spec.global_batch / min(spec.global_batch, n_devices)
        tokens_dev = max(spec.global_batch / n_devices, 1.0)
    else:
        tokens_dev = spec.global_batch * spec.seq_len / n_devices
    if spec.kind == "train":
        # params f32 read (fwd+bwd+remat ~3x), grad write+read, m/v rw
        # (bf16), param write — per microbatch the params are re-read.
        p_dev = P / n_devices
        param_traffic = p_dev * (3 * 4 * accum + 4 + 4 + 4 * 2 + 4)
        act = tokens_dev * d * cfg.n_layers * 2 * 2 * 3   # save+read, bf16
        kv_stream = _attn_stream_bytes(cfg, spec, tokens_dev) * 3
        return param_traffic + act + kv_stream
    # inference
    p_dev = P / n_devices
    param_traffic = p_dev * 2            # bf16-equivalent stream
    act = tokens_dev * d * cfg.n_layers * 2 * 2
    kv = _attn_stream_bytes(cfg, spec, tokens_dev)
    return param_traffic + act + kv


def _attn_stream_bytes(cfg: ModelConfig, spec, tokens_dev: float) -> float:
    """KV bytes streamed by attention per step per device."""
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.layer_kind(i) == "attn")
    if n_attn == 0:
        return 0.0
    ctx = _attn_core_ctx(cfg, spec)
    if cfg.attn_type == "mla":
        if spec.kind == "decode":
            # absorbed decode attends the compressed cache directly
            per_ctx_tok = (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2
        else:
            # prefill/train decompress K/V per head
            per_ctx_tok = cfg.n_heads * (cfg.qk_nope_head_dim +
                                         cfg.qk_rope_head_dim +
                                         cfg.v_head_dim) * 2
    else:
        per_ctx_tok = 2 * cfg.n_kv_heads * cfg.head_dim * 2
    if spec.kind == "decode":
        return tokens_dev * n_attn * ctx * per_ctx_tok
    # prefill/train: blockwise attention restreams KV once per q block
    q_blocks = max(spec.seq_len // 1024, 1)
    share = ctx / spec.seq_len
    return (tokens_dev * n_attn * per_ctx_tok * q_blocks * share)
