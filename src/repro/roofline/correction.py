"""Two-point depth extrapolation: validate the analytic cost model and
correct while-body-once undercounts from the compiled dry-run.

For a cell, lower + compile the SAME full-width config at reduced
depths ``prefix + 1*period`` and ``prefix + 2*period`` layers (accum=1).
The difference of any additive metric between the two compiles is one
layer-unit's true cost — XLA cannot hide it in a loop body because the
depth change is materialised in the program:

    unit_X  = X(2 units) - X(1 unit)
    total_X ~= X_measured_full + unit_X * (reps_full - 1)

Used two ways:
* ``validate_flops``: compare unit FLOPs against the analytic model of
  ``repro.roofline.flops`` (EXPERIMENTS.md appendix),
* ``corrected_collectives``: collective bytes with the per-unit slope
  restored (raw HLO parsing sees the scan body once).

Run from a fresh process (needs the 512-device host platform):

  PYTHONPATH=src python -m repro.roofline.correction --arch qwen1.5-0.5b
"""

from __future__ import annotations

import os

if __name__ == "__main__":  # device count must be set pre-jax-import
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")

import argparse
import sys


def measure_depths(arch: str, shape_name: str) -> dict:
    """Compile depth-1 and depth-2 variants; return per-unit metrics."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config
    from repro.dist.context import set_activation_axes
    from repro.dist.sharding import batch_spec, named, param_specs
    from repro.launch.dryrun import _collective_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs, state_specs
    from repro.models import transformer as T
    from repro.models.transformer import unit_period
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import make_train_step

    cfg_full = get_config(arch, "full")
    spec = SHAPES[shape_name]
    prefix, period = unit_period(cfg_full)
    mesh = make_production_mesh()
    out = {}
    with jax.set_mesh(mesh):
        dp = batch_spec(mesh)
        set_activation_axes(dp=dp[0], tp="model", mesh=mesh)
        for k in (1, 2):
            cfg = cfg_full.replace(n_layers=prefix + k * period)
            inp = input_specs(cfg, spec)
            if spec.kind == "train":
                state = state_specs(cfg, with_opt=True,
                                    opt_dtype=jnp.bfloat16)
                pspecs = param_specs(state["params"], mesh)
                ospecs = {"m": pspecs, "v": pspecs, "step": P()}
                bspecs = {kk: P(dp[0], *([None] * (len(v.shape) - 1)))
                          for kk, v in inp.items()}
                step = make_train_step(
                    cfg, AdamWConfig(state_dtype="bfloat16"), accum=1,
                    unroll=True)
                jitted = jax.jit(
                    step,
                    in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                                  named(mesh, bspecs)),
                    out_shardings=(named(mesh, pspecs),
                                   named(mesh, ospecs), None),
                    donate_argnums=(0, 1))
                compiled = jitted.lower(state["params"],
                                        state["opt_state"], inp).compile()
            else:
                state = state_specs(cfg, with_opt=False,
                                    param_dtype=jnp.bfloat16)
                pspecs = param_specs(state["params"], mesh, mode="serve")
                bspec = P(dp[0], *([None] * (len(inp["inputs"].shape) - 1)))
                def fwd(p, x):
                    feats, _ = T.forward_features(p, cfg, x, remat=False,
                                                  unroll=True)
                    h = T.head_matrix(p, cfg)
                    return feats[:, -1, :] @ h.astype(feats.dtype)
                jitted = jax.jit(
                    fwd, in_shardings=(named(mesh, pspecs),
                                       NamedSharding(mesh, bspec)))
                compiled = jitted.lower(state["params"],
                                        inp["inputs"]).compile()
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            out[k] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "coll": sum(_collective_bytes(compiled.as_text()).values()),
            }
    reps_full = (cfg_full.n_layers - prefix) // period
    unit = {m: out[2][m] - out[1][m] for m in ("flops", "bytes", "coll")}
    return {"arch": arch, "shape": shape_name, "prefix": prefix,
            "period": period, "reps_full": reps_full,
            "depth1": out[1], "depth2": out[2], "unit": unit}


def validate_flops(arch: str, shape_name: str) -> dict:
    """Measured per-unit FLOPs (x chips) vs the analytic model."""
    from repro.configs import SHAPES, get_config
    from repro.roofline.flops import (_attn_core_ctx,
                                      layer_fwd_flops_per_token)
    m = measure_depths(arch, shape_name)
    cfg = get_config(arch, "full")
    spec = SHAPES[shape_name]
    ctx = _attn_core_ctx(cfg, spec)
    per_tok = sum(layer_fwd_flops_per_token(cfg, cfg.first_dense_layers + u,
                                            ctx)
                  for u in range(m["period"]))
    tokens = spec.global_batch * spec.seq_len
    mult = 4.0 if spec.kind == "train" else 1.0
    analytic_unit = per_tok * tokens * mult
    measured_unit = m["unit"]["flops"] * 256  # per-partition -> global
    return {**m, "analytic_unit_flops": analytic_unit,
            "measured_unit_flops": measured_unit,
            "ratio": measured_unit / max(analytic_unit, 1.0)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args(argv)
    r = validate_flops(args.arch, args.shape)
    print(f"{r['arch']} x {r['shape']}: unit(period={r['period']}) "
          f"measured {r['measured_unit_flops']:.3e} vs analytic "
          f"{r['analytic_unit_flops']:.3e} FLOPs -> ratio "
          f"{r['ratio']:.3f}")
    print(f"per-unit collective bytes: {r['unit']['coll'] / 2**20:.1f} MiB "
          f"(x{r['reps_full']} units for the corrected total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
