"""Roofline analysis from dry-run artifacts + analytic cost model."""

from .analysis import HW, analyse, load_records, model_flops, roofline_row
from .flops import cell_bytes, cell_flops

__all__ = ["HW", "analyse", "load_records", "model_flops", "roofline_row",
           "cell_bytes", "cell_flops"]
