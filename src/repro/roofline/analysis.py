"""Three-term roofline analysis from dry-run artifacts.

Per (arch x shape x mesh) cell::

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Hardware constants (TPU v5e): 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, which under-reports every scan (layer stack, microbatch
accumulation, blockwise attention).  We correct it by lowering the same
cell at two reduced depths and extrapolating linearly:
``body = (f(2u) - f(1u)) / u`` layers, so
``total = f(full) + body * (L_full - L_lowered)`` — exact for
depth-linear programs, which scan-over-identical-units programs are.
The correction factor per cell is recorded alongside the raw numbers.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass

from repro.configs import SHAPES, get_config, shape_plan
from repro.models.common import ModelConfig

__all__ = ["HW", "roofline_row", "model_flops", "active_params",
           "load_records", "analyse"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # bytes/s / chip
    link_bw: float = 50e9             # bytes/s / link (ICI)


def active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (dense N, or N_active for MoE)."""
    d, L = cfg.d_model, cfg.n_layers
    n = cfg.vocab * d  # embedding (+ head if untied ~ counted once)
    if not cfg.tie_embeddings:
        n += cfg.vocab * d
    for i in range(L):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            if cfg.attn_type == "mla":
                dn, dr, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                              cfg.v_head_dim)
                H = cfg.n_heads
                n += d * cfg.kv_lora_rank + d * dr
                if cfg.q_lora_rank:
                    n += d * cfg.q_lora_rank + cfg.q_lora_rank * H * (dn + dr)
                else:
                    n += d * H * (dn + dr)
                n += cfg.kv_lora_rank * H * (dn + dv) + H * dv * d
            else:
                hd, H, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
                n += d * hd * (H + 2 * Hkv) + H * hd * d
        elif kind == "mamba":
            di, ds, dtr = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.dt_rank
            n += d * 2 * di + di * (dtr + 2 * ds) + dtr * di + di * d
        elif kind in ("mlstm", "slstm"):
            if kind == "mlstm":
                di = int(d * cfg.xlstm_proj_factor)
                n += d * 2 * di + 3 * di * di + di * d
            else:
                n += d * 4 * d + 4 * (d // cfg.n_heads) * d + \
                    d * int(d * 4 / 3) * 3
        if cfg.is_moe_layer(i):
            # active experts only
            ff = cfg.moe_d_ff
            k_active = cfg.top_k + cfg.n_shared_experts
            n += 3 * d * ff * k_active + d * cfg.n_experts  # router
        elif kind in ("attn", "mamba") and cfg.d_ff:
            mult = 3 if cfg.act == "swiglu" else 2
            n += mult * d * cfg.d_ff
    return float(n)


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    n_act = active_params(cfg)
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n_act * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * spec.global_batch


def roofline_row(rec: dict, hw: HW = HW()) -> dict:
    """One EXPERIMENTS.md row from a dry-run record.

    FLOPs/bytes use the analytic model (``repro.roofline.flops``) —
    ``cost_analysis()`` counts while bodies once (verified), so raw
    numbers are floor values and are reported alongside.  Collective
    bytes come from the HLO, scaled by the measured per-layer slope
    when a correction record is attached (``coll_correction``)."""
    if "skipped" in rec or "error" in rec:
        return dict(rec)
    from repro.launch.dryrun import TRAIN_ACCUM
    from repro.roofline.flops import cell_bytes, cell_flops
    chips = rec["n_devices"]
    arch, shape = rec["arch"], rec["shape"]
    accum = rec.get("accum", TRAIN_ACCUM.get(arch, 1))
    flops_total = cell_flops(arch, shape)
    flops_dev = flops_total / chips
    bytes_dev = cell_bytes(arch, shape, chips, accum=accum)
    coll = rec.get("coll_corrected",
                   sum(rec.get("collectives", {}).values()))
    # Depth-extrapolation correction (roofline_correction.json): raw
    # HLO parsing sees the layer-scan body once; restore the per-unit
    # collective slope for train cells.
    corr = _load_corrections().get(arch, {})
    if ("coll_corrected" not in rec and shape == "train_4k" and
            "unit_coll_bytes" in corr):
        coll = coll + corr["unit_coll_bytes"] * (corr["reps_full"] - 1)
    t_compute = flops_dev / hw.peak_flops
    t_memory = bytes_dev / hw.hbm_bw
    t_coll = coll / hw.link_bw
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(arch, shape)
    bound = max(t_compute, t_memory, t_coll)
    # Roofline fraction: ideal useful-work time (MODEL_FLOPS at peak)
    # over the step-time bound.  1.0 = every cycle is useful matmul.
    t_ideal = mf / chips / hw.peak_flops
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "n_devices")},
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_time_bound_s": bound,
        "roofline_fraction": min(t_ideal / bound, 1.0) if bound else 0.0,
        "model_flops": mf,
        "hlo_flops_total": flops_total,
        "useful_flops_ratio": mf / max(flops_total, 1.0),
        "raw_cost_flops_dev": rec["cost"]["flops"],
        "raw_coll_bytes_dev": sum(rec.get("collectives", {}).values()),
    }


_CORR: dict | None = None


def _load_corrections() -> dict:
    global _CORR
    if _CORR is None:
        import os
        _CORR = {}
        if os.path.exists("roofline_correction.json"):
            with open("roofline_correction.json") as f:
                _CORR = json.load(f)
    return _CORR


def load_records(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


def analyse(path: str, hw: HW = HW()) -> list[dict]:
    return [roofline_row(r, hw) for r in load_records(path)]
