"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state.  Single pod: 16x16 = 256 chips (v5e pod),
axes ("data", "model").  Multi-pod: 2x16x16 = 512 chips, axes
("pod", "data", "model") — "pod" is pure data parallelism over DCN/ICI.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the local device (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
