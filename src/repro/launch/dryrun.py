import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# NOTE: the two lines above MUST execute before any other import (JAX
# locks the device count at first init), which is why the module
# docstring lives in this comment block instead of the top of the file.
#
# from __future__ import annotations

import argparse
import json
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, arch_names, get_config, shape_plan
from repro.dist.sharding import (batch_spec, cache_specs, named,
                                 param_specs)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cache_shape, input_specs, state_specs
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.train.step import make_train_step

__all__ = ["dryrun_cell", "main"]

#: Gradient-accumulation factor per arch for the train_4k cell: the
#: production answer for fitting 1M-token global steps in 16 GB v5e HBM.
#: Microbatches are scanned, so the lowered HLO stays one microbatch
#: wide; the global batch spec is unchanged.
TRAIN_ACCUM = {
    "deepseek-v2-236b": 16,
    "jamba-v0.1-52b": 4,
    "mixtral-8x7b": 4,
    "internlm2-20b": 4,
    "mistral-nemo-12b": 4,
    "pixtral-12b": 4,
    "starcoder2-7b": 4,
    "hubert-xlarge": 2,
    "xlstm-125m": 2,
    "qwen1.5-0.5b": 1,
}


def _collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum operand bytes of collective ops in the optimized HLO."""
    import re
    out: dict[str, float] = {}
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "f64": 8, "pred": 1, "s64": 8,
                   "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2,
                   "u16": 2}
    pat = re.compile(
        r"(\w[\w-]*)\s*=\s*(?:\(([^)]*)\)|(\S+?))\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)")
    for m in pat.finditer(hlo_text):
        outspec = m.group(2) or m.group(3)
        kind = m.group(4)
        total = 0.0
        for shape in re.finditer(r"(\w+)\[([\d,]*)\]", outspec):
            dt, dims = shape.group(1), shape.group(2)
            if dt not in dtype_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dtype_bytes[dt]
        out[kind] = out.get(kind, 0.0) + total
    return out


def _mem_summary(compiled) -> dict[str, float]:
    try:
        ma = compiled.memory_analysis()
        alias = float(getattr(ma, "alias_size_in_bytes", 0.0))
        out = {
            "argument_bytes": float(ma.argument_size_in_bytes),
            "output_bytes": float(ma.output_size_in_bytes),
            "temp_bytes": float(ma.temp_size_in_bytes),
            "alias_bytes": alias,
            # donated inputs alias outputs: don't double count them
            "peak_bytes": float(ma.argument_size_in_bytes +
                                ma.output_size_in_bytes +
                                ma.temp_size_in_bytes - alias),
        }
        return out
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _cost_summary(compiled) -> dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return {"flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                collect_hlo: bool = True) -> dict:
    """Lower+compile one cell; returns the roofline record."""
    cfg = get_config(arch, "full")
    spec = SHAPES[shape_name]
    plan = shape_plan(cfg)
    if plan[shape_name] is not None:
        return {"arch": arch, "shape": shape_name,
                "skipped": plan[shape_name]}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name,
              "mesh": "x".join(map(str, mesh.devices.shape)),
              "n_devices": mesh.devices.size}

    from repro.dist.context import set_activation_axes
    with jax.set_mesh(mesh):
        inp = input_specs(cfg, spec)
        dp = batch_spec(mesh)
        set_activation_axes(dp=dp[0], tp="model", mesh=mesh)
        if spec.kind == "train":
            state = state_specs(cfg, with_opt=True, opt_dtype=jnp.bfloat16)
            pspecs = param_specs(state["params"], mesh)
            # NOTE: mode="zero1" (pod-sharded optimizer moments) was
            # measured and REFUTED for this workload — the one-shot
            # update respec costs 2x the resident savings in cross-pod
            # traffic (EXPERIMENTS.md §Perf, deepseek iteration 3).
            ospecs = {"m": pspecs, "v": pspecs, "step": P()}
            bspecs = {k: P(dp[0], *([None] * (len(v.shape) - 1)))
                      for k, v in inp.items()}
            accum = TRAIN_ACCUM.get(arch, 1)
            record["accum"] = accum
            step = make_train_step(
                cfg, AdamWConfig(state_dtype="bfloat16"), accum=accum,
                remat=True)
            jitted = jax.jit(
                step,
                in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                              named(mesh, bspecs)),
                out_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                               None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(state["params"], state["opt_state"], inp)
        elif spec.kind == "prefill":
            state = state_specs(cfg, with_opt=False,
                                param_dtype=jnp.bfloat16)
            pspecs = param_specs(state["params"], mesh, mode="serve")
            bspec = P(dp[0], *([None] * (len(inp["inputs"].shape) - 1)))

            def fwd(params, inputs):
                # serving prefill: last-position logits only (the
                # (B, S, V) tensor never exists — see §Perf)
                return T.prefill_logits(params, cfg, inputs)

            out_spec = P(dp[0], "model") if cfg.vocab % 16 == 0 else \
                P(dp[0], None)
            jitted = jax.jit(
                fwd,
                in_shardings=(named(mesh, pspecs),
                              NamedSharding(mesh, bspec)),
                out_shardings=NamedSharding(mesh, out_spec),
            )
            lowered = jitted.lower(state["params"], inp["inputs"])
        else:  # decode
            state = state_specs(cfg, with_opt=False,
                                param_dtype=jnp.bfloat16)
            pspecs = param_specs(state["params"], mesh, mode="serve")
            # Unrolling is only safe with resident (TP-only) weights;
            # with FSDP fallback the hoisted per-layer all-gathers
            # would all be live at once (measured: 72 GiB on
            # deepseek-v2) — keep the scan so gathers stay in-loop.
            from repro.dist.sharding import serve_weights_resident
            unroll = serve_weights_resident(state["params"], mesh)
            cshape = cache_shape(cfg, spec)
            cspecs = cache_specs(cshape, mesh)
            tok_rank = len(inp["tok"].shape)
            tspec = P(dp[0], *([None] * (tok_rank - 1)))
            if spec.global_batch % _dp_size(mesh) != 0:
                tspec = P(*([None] * tok_rank))

            def serve(params, tok, cache, pos):
                return T.decode_step(params, cfg, tok, cache, pos,
                                     unroll=unroll)

            jitted = jax.jit(
                serve,
                in_shardings=(named(mesh, pspecs),
                              NamedSharding(mesh, tspec),
                              named(mesh, cspecs), None),
                out_shardings=(None, named(mesh, cspecs)),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(state["params"], inp["tok"], cshape,
                                   inp["pos"])
        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)
        record["memory"] = _mem_summary(compiled)
        record["cost"] = _cost_summary(compiled)
        if collect_hlo:
            record["collectives"] = _collective_bytes(compiled.as_text())
    return record


def _dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"],
                    default="no")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    archs = arch_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[
        args.multi_pod]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                try:
                    r = dryrun_cell(arch, shape, multi_pod=mp)
                except Exception as e:
                    r = {"arch": arch, "shape": shape, "multi_pod": mp,
                         "error": f"{type(e).__name__}: {e}"}
                    results.append(r)
                    print(f"[FAIL] {arch} x {shape} mp={mp}: "
                          f"{r['error'][:200]}", flush=True)
                    continue
                results.append(r)
                if "skipped" in r:
                    print(f"[skip] {arch} x {shape}: {r['skipped']}",
                          flush=True)
                    continue
                mem = r["memory"].get("peak_bytes", float("nan")) / 2**30
                fl = r["cost"].get("flops", float("nan"))
                coll = sum(r.get("collectives", {}).values()) / 2**30
                print(f"[ok]  {arch} x {shape} mesh={r['mesh']} "
                      f"peak={mem:.2f}GiB flops={fl:.3e} "
                      f"coll={coll:.2f}GiB "
                      f"(lower {r['lower_s']}s compile {r['compile_s']}s)",
                      flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    # Non-zero exit if any non-skipped cell failed.
    bad = [r for r in results
           if "skipped" not in r and
           ("error" in r or "error" in r.get("memory", {}))]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
