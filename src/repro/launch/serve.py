"""Serving driver: load (or init) a model, run batched requests through
the symbiotic engine, print generations + scheduling stats.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --variant smoke --requests 8 --policy symbiotic
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import arch_names, get_config
from repro.models import transformer as T
from repro.serve import Request, SchedulerPolicy, ServingEngine
from repro.train.checkpoint import latest_step, restore_checkpoint

__all__ = ["main", "serve"]


def serve(arch: str, *, variant: str = "smoke", n_requests: int = 8,
          policy: str = "symbiotic", max_len: int = 96,
          max_new_tokens: int = 8, ckpt_dir: str | None = None,
          seed: int = 0) -> dict:
    cfg = get_config(arch, variant)
    if not cfg.causal:
        raise SystemExit(f"{arch} is encoder-only: no autoregressive "
                         "serving (use the forward path)")
    params = T.init(jax.random.PRNGKey(seed), cfg)
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        tree, _ = restore_checkpoint(ckpt_dir, {"params": params,
                                                "opt": None})
        params = tree["params"]
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(4, max(5, max_len // 4)))
        reqs.append(Request(i, rng.integers(0, cfg.vocab, size=plen),
                            max_new_tokens=max_new_tokens))
    eng = ServingEngine(cfg, params, max_len=max_len,
                        policy=SchedulerPolicy(kind=policy))
    eng.submit(reqs)
    t0 = time.time()
    stats = eng.run()
    stats["wall_s"] = time.time() - t0
    return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=arch_names())
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--policy", default="symbiotic",
                    choices=["fifo", "symbiotic", "refined"])
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)
    stats = serve(args.arch, variant=args.variant,
                  n_requests=args.requests, policy=args.policy,
                  max_len=args.max_len,
                  max_new_tokens=args.max_new_tokens,
                  ckpt_dir=args.ckpt_dir)
    print(f"policy={args.policy} rounds={stats['rounds']} "
          f"new_tokens={stats['total_new_tokens']} "
          f"modelled={stats['modelled_time_s'] * 1e3:.2f}ms "
          f"wall={stats['wall_s']:.1f}s")
    for rid, toks in sorted(stats["outputs"].items())[:4]:
        print(f"  req {rid}: {toks[:10]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
