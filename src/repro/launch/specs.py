"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

Nothing here allocates: params/opt-state shapes come from
``jax.eval_shape`` over the real initialisers, inputs are synthesized
per the assigned shape table.  ``[audio]``/``[vlm]`` archs receive
precomputed frame/patch embeddings (the modality frontend is a stub).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.optim.adamw import adamw_init

__all__ = ["input_specs", "state_specs", "cache_shape"]

Sds = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict[str, Any]:
    """Model inputs for one cell, as ShapeDtypeStructs."""
    B, S = spec.global_batch, spec.seq_len
    if spec.kind in ("train", "prefill"):
        if cfg.input_mode == "tokens":
            inputs = Sds((B, S), jnp.int32)
        else:
            inputs = Sds((B, S, cfg.d_model), jnp.bfloat16)
        out = {"inputs": inputs}
        if spec.kind == "train":
            out["labels"] = Sds((B, S), jnp.int32)
        return out
    # decode: one new token against a cache of S tokens.
    if cfg.input_mode == "tokens":
        tok = Sds((B,), jnp.int32)
    else:
        tok = Sds((B, 1, cfg.d_model), jnp.bfloat16)
    return {"tok": tok, "pos": Sds((), jnp.int32)}


def state_specs(cfg: ModelConfig, *, with_opt: bool = True,
                opt_dtype=jnp.float32,
                param_dtype=None) -> dict[str, Any]:
    """abstract params (+ optimizer state) via eval_shape — no allocation.

    ``param_dtype=jnp.bfloat16`` models inference deployments (resident
    bf16 weights)."""
    params = jax.eval_shape(
        lambda: T.init(jax.random.PRNGKey(0), cfg))
    if param_dtype is not None:
        params = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, param_dtype if l.dtype == jnp.float32 else l.dtype),
            params)
    out = {"params": params}
    if with_opt:
        out["opt_state"] = jax.eval_shape(
            lambda p: adamw_init(p, opt_dtype), params)
    return out


def cache_shape(cfg: ModelConfig, spec: ShapeSpec) -> Any:
    """Abstract KV/state cache sized for the cell's context length."""
    return jax.eval_shape(
        lambda: T.init_cache(cfg, spec.global_batch, spec.seq_len))
