"""Training driver.

Runs on anything from 1 CPU (smoke/examples) to the production mesh
(``--mesh single|multi``): builds the mesh, shards params/optimizer by
the path rules, wires the fault-tolerant loop (auto-resume, async
checkpoints, NaN guard) around the pjit'd step.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --variant smoke --steps 100 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import arch_names, get_config
from repro.data import DataConfig, SyntheticLM
from repro.dist.context import set_activation_axes
from repro.dist.sharding import batch_spec, named, param_specs
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.train import LoopConfig, TrainLoop, make_train_step

__all__ = ["main", "train"]


def train(arch: str, *, variant: str = "smoke", steps: int = 100,
          global_batch: int = 8, seq_len: int = 128, accum: int = 1,
          lr: float = 3e-4, ckpt_dir: str = "/tmp/repro_ckpt",
          ckpt_every: int = 50, mesh_kind: str = "host",
          log_fn=None) -> dict:
    cfg = get_config(arch, variant)
    if mesh_kind == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    dp = batch_spec(mesh)
    with jax.set_mesh(mesh):
        set_activation_axes(dp=dp[0], tp="model", mesh=mesh)
        key = jax.random.PRNGKey(0)
        params = T.init(key, cfg)
        opt_state = adamw_init(params)
        pspecs = param_specs(params, mesh)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        params = jax.device_put(params, named(mesh, pspecs))
        opt_state = jax.device_put(opt_state, named(mesh, ospecs))

        opt = AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                          total_steps=steps)
        step = make_train_step(cfg, opt, accum=accum)
        bspec = {"inputs": P(dp[0], None), "labels": P(dp[0], None)}
        jstep = jax.jit(
            step,
            in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                          named(mesh, bspec)),
            out_shardings=(named(mesh, pspecs), named(mesh, ospecs), None),
            donate_argnums=(0, 1))

        data = SyntheticLM(DataConfig(
            vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch))

        losses = []

        def log(s, m):
            loss = float(m["loss"])
            if log_fn:
                log_fn(s, m)
            else:
                print(f"step {s:5d} loss {loss:.4f} "
                      f"gnorm {float(m['grad_norm']):.3f}", flush=True)

        loop = TrainLoop(
            step_fn=jstep, data=data,
            cfg=LoopConfig(total_steps=steps, ckpt_every=ckpt_every,
                           ckpt_dir=ckpt_dir, log_every=10),
            log_fn=log)
        params, opt_state, start = loop.resume_or_init(params, opt_state)
        t0 = time.time()
        params, opt_state, losses = loop.run(params, opt_state, start)
        dt = time.time() - t0
    return {"losses": losses, "seconds": dt,
            "first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=arch_names())
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    args = ap.parse_args(argv)
    out = train(args.arch, variant=args.variant, steps=args.steps,
                global_batch=args.batch, seq_len=args.seq,
                accum=args.accum, lr=args.lr, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, mesh_kind=args.mesh)
    print(f"done: loss {out['first_loss']:.4f} -> {out['last_loss']:.4f} "
          f"in {out['seconds']:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
