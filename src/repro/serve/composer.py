"""Round composition for the serving engine (split out of the engine
monolith in PR 7).

:class:`Composer` is the per-step composition pipeline, parameterized
by :class:`~repro.serve.engine.SchedulerPolicy`: it turns the engine's
pending work items into execution rounds — fifo packing, Algorithm 1
greedy (flat or ready-set DAG), optional slicing and refinement, the
arrival-order cost-model guard, and the :class:`ScheduleCache` replay
/ warm-start paths.  It owns no queue and runs nothing: the engine
(:class:`~repro.serve.engine.ServingEngine`) keeps the step loop and
exact execution, and the live-composition layer
(:class:`~repro.serve.live.LiveComposition`) keeps cross-step frontier
state; both drive their composition through this class.

:class:`GatedGuard` is the per-step gated-makespan oracle for
``dag_guard="gated"``: one object per compose step, reusing
:class:`~repro.graph.delta.GatedDeltaEvaluator` checkpoints across
the step's candidate compositions so the guard stops paying two full
gated simulations per step (the fifo baseline pays the one full
recorded simulation; every same-kernel-set candidate after it resumes
from the checkpoint at its first divergence).  Saved full-sim
equivalents accumulate in ``ScheduleCache.gated_sims_saved``.
"""

from __future__ import annotations

from collections import Counter, deque

from repro.core import Schedule
from repro.core.fastscore import greedy_order_fast, warm_start_insert
from repro.core.refine import refine_order
from repro.core.tpu import fifo_rounds, round_time
from repro.graph.constrained import greedy_order_dag, refine_order_dag
from repro.graph.delta import GatedDeltaEvaluator
from repro.graph.streams import fifo_rounds_dag
from repro.obs import DriftMonitor, QualityAuditor
from repro.slice import KernelSlicer, greedy_order_slices, join_item

from .cache import ScheduleCache

__all__ = ["Composer", "GatedGuard"]


class GatedGuard:
    """Per-compose-step gated-event makespan oracle with checkpoint
    reuse across the step's candidate compositions.

    Rebuilds the dependency structure from item names so replayed
    compositions — whose slices were re-cut from cached patterns —
    are scored too: parent edges come from the traced graph, a sliced
    parent's in-edges fan out to its slices, its out-edges hang off
    the ``#join`` marker, and slices close the diamond on the join.
    A flat order that is not topological (a corrupted replay) scores
    ``inf`` and is rejected by the guard.

    Unlike the pre-PR 7 guard, item profiles are built once per step
    and one :class:`~repro.graph.delta.GatedDeltaEvaluator` is kept
    per distinct kernel set: the first candidate over a set pays the
    full recorded simulation, every later candidate over the same set
    (e.g. the greedy composition scored right after the fifo baseline,
    on the unsliced path where both orders run over the same items)
    resumes from the checkpoint at its first divergence and pays only
    the suffix fraction.  ``1 - fraction`` accumulates per delta call
    in ``ScheduleCache.gated_sims_saved``.  Candidates over a
    *different* kernel set (a sliced composition vs the unsliced
    fifo) get their own evaluator — no reuse, exactly the old cost.
    """

    def __init__(self, device, traced, cache: ScheduleCache):
        self.device = device
        self.traced = traced
        self.cache = cache
        #: id(item) -> (item, profile) — the item reference keeps the
        #: id from being recycled by a different object.
        self._profs: dict[int, tuple] = {}
        #: frozenset(profile ids) -> (evaluator, base order, base time)
        self._evals: dict[frozenset, tuple] = {}

    def _profile_of(self, it):
        v = self._profs.get(id(it))
        if v is None:
            v = (it, it.profile())
            self._profs[id(it)] = v
        return v[1]

    def _pairs(self, profs) -> set[tuple[int, int]]:
        names = {p.name: p for p in profs}
        slices: dict[str, list] = {}
        for p in profs:
            parent, sep, sub = p.name.partition("#")
            if sep and sub.startswith("s"):
                slices.setdefault(parent, []).append(p)
        ks = self.traced.graph.kernels
        pairs: set[tuple[int, int]] = set()
        for u, v in self.traced.graph.edges:
            a, b = ks[u].name, ks[v].name
            srcs = ([names.get(a + "#join")] if a in slices
                    else [names.get(a)])
            dsts = slices[b] if b in slices else [names.get(b)]
            for s in srcs:
                for d in dsts:
                    if s is not None and d is not None:
                        pairs.add((id(s), id(d)))
        for parent, parts in slices.items():
            j = names.get(parent + "#join")
            if j is not None:
                for s in parts:
                    pairs.add((id(s), id(j)))
        return pairs

    def time(self, rounds) -> float:
        """Gated-event makespan of a composition's flat launch order
        (``inf`` for a non-topological order)."""
        profs = [self._profile_of(trip[0]) for rd in rounds
                 for trip in rd]
        key = frozenset(id(p) for p in profs)
        ent = self._evals.get(key)
        if ent is None:
            ev = GatedDeltaEvaluator(self.device, self._pairs(profs))
            try:
                t = ev.rebase(profs)
            except ValueError:
                return float("inf")
            self._evals[key] = (ev, list(profs), t)
            return t
        ev, base, base_t = ent
        first = len(profs)
        for i, (a, b) in enumerate(zip(base, profs)):
            if a is not b:
                first = i
                break
        if first == len(profs):
            # Identical launch order: the cached total, a whole full
            # simulation saved.
            self.cache.gated_sims_saved += 1.0
            return base_t
        if not ev.legal(profs):
            return float("inf")
        try:
            t, frac = ev.evaluate_costed(profs, first)
        except ValueError:
            return float("inf")
        self.cache.gated_sims_saved += max(0.0, 1.0 - frac)
        return t


class Composer:
    """The per-step round-composition pipeline.

    Stateless across steps apart from the shared
    :class:`ScheduleCache` (and the counters it carries); the policy
    object is shared with the engine, so runtime knob changes (tests
    flip ``replay_drift_tol``) are seen immediately.
    """

    def __init__(self, policy, device, weights_bytes: float,
                 cache: ScheduleCache, recorder=None):
        self.policy = policy
        self.device = device
        self.weights_bytes = weights_bytes
        self.cache = cache
        #: optional :class:`repro.obs.FlightRecorder` — schedule
        #: decisions, cache outcomes and rebuild reasons are emitted
        #: as discrete events when set (``None`` is the zero-cost
        #: null path, same contract as ``trace=``).
        self.recorder = recorder
        #: the online Fig.-1 sampler (PR 9); also owns the deprecated
        #: ``warm_audit_frac`` warm-regret path, so the composer no
        #: longer inlines it.
        self.auditor = QualityAuditor(policy, device, cache.metrics,
                                      recorder=recorder)
        #: EWMA modelled-vs-revalidated drift per cache namespace,
        #: fed by :meth:`replay_ok` and the live frontier's ratio
        #: backstop.
        self.drift = DriftMonitor(cache.metrics)

    def _note(self, kind: str, **fields) -> None:
        """Flight-recorder emission (no-op without a recorder)."""
        if self.recorder is not None:
            self.recorder.event(kind, **fields)

    # -- shared currencies ---------------------------------------------
    @staticmethod
    def dag_stage_key(name: str) -> str:
        """``r3:d:L0:attn`` -> ``L0:attn``: the layer stage, dropping
        the owning request — co-scheduled copies of one stage share
        its weight stream.  Slice metadata after ``#``
        (``r3:d:L0:attn#s1of4``, ``...#join``) is stripped too: slices
        of one stage share the *parent's* stream, so a round charges
        it once per distinct parent stage, never per slice."""
        return name.split(":", 2)[2].split("#", 1)[0]

    def dag_round_time(self, rd) -> float:
        """Round time on the respect_deps path: the weight stream
        charged is the sum over the round's *distinct* layer stages of
        that stage's own parameter share (``TpuWorkItem.weight_bytes``,
        set by trace_arch; max across copies, so a prefill stage that
        touches the full expert bank dominates a routed decode copy).
        Charging the engine-wide ``weights_bytes`` here would bill the
        whole model once per stage round — many times per step."""
        shares: dict[str, float] = {}
        for it, _, _ in rd:
            key = self.dag_stage_key(it.name)
            shares[key] = max(shares.get(key, 0.0), it.weight_bytes)
        return round_time([t[0] for t in rd], self.device,
                          sum(shares.values()))

    def flat_round_time(self, rd) -> float:
        return round_time([t[0] for t in rd], self.device,
                          self.weights_bytes)

    def dag_gated_time(self, rounds, traced) -> float:
        """One-shot gated makespan of a composition (a fresh
        :class:`GatedGuard` with no reuse) — kept for callers scoring
        a single composition outside a compose step."""
        return GatedGuard(self.device, traced, self.cache).time(rounds)

    def dag_guard_fn(self, traced):
        """The guard currency for one compose step
        (``policy.dag_guard``): the round cost model, or a per-step
        :class:`GatedGuard` whose checkpoints are shared across every
        candidate the step scores.  Every call is timed into the
        ``phase_guard`` histogram (the profiling hook for the guard
        phase of a compose step)."""
        if self.policy.dag_guard == "gated":
            return self._timed_guard(
                GatedGuard(self.device, traced, self.cache).time)
        return self._timed_guard(
            lambda rounds: sum(self.dag_round_time(rd)
                               for rd in rounds))

    def _timed_guard(self, fn):
        """Wrap a guard currency so each candidate scoring lands in
        the ``phase_guard`` wall-clock histogram."""
        metrics = self.cache.metrics

        def timed(rounds):
            with metrics.timer("phase_guard"):
                return fn(rounds)

        return timed

    # -- DAG path -------------------------------------------------------
    def dag_fifo(self, triples, traced) -> list[list]:
        """Dependency-aware arrival-order packing of the traced step
        (the guard baseline; plain ``fifo_rounds`` could co-schedule a
        stage with its own predecessor)."""
        profs = traced.graph.kernels
        by_name = {p.name: trip for p, trip in zip(profs, triples)}
        dem = lambda k: k.demands  # noqa: E731 — profiles, not items
        return [[by_name[p.name] for p in rd]
                for rd in fifo_rounds_dag(profs, self.device,
                                          traced.graph.edges_by_id(),
                                          demands_of=dem)]

    def dag_cold(self, triples, traced, frontier=None) -> list[list]:
        """Cold composition of a traced step: the ready-set greedy
        (:func:`repro.graph.greedy_order_dag`) — slice-aware
        (:func:`repro.slice.greedy_order_slices`) when
        ``policy.slice_policy`` is set, with the chain tail's exact
        execution moved to the slice join — plus the
        precedence-respecting local search for ``kind="refined"``.
        ``frontier`` threads a
        :class:`repro.graph.constrained.GreedyFrontier` sink through
        to the greedy (the live-composition seed)."""
        profs = traced.graph.kernels
        eids = traced.graph.edges_by_id()
        by_name = {p.name: trip for p, trip in zip(profs, triples)}
        dem = lambda k: k.demands  # noqa: E731 — profiles, not items
        sp = self.policy.slice_policy
        if sp is None:
            sched = greedy_order_dag(profs, self.device,
                                     edges=traced.graph.edges,
                                     frontier=frontier)
            names, sl_eids = by_name, eids
        else:
            slicer = KernelSlicer(sp, self.device)
            extra: dict[str, tuple] = {}

            def mk_slices(prof, k):
                it, r, kind = by_name[prof.name]
                parts = slicer.slice_item(it, k)
                for part in parts:
                    extra[part.name] = (part, r, "frag")
                ji = join_item(it)
                # The chain tail's exact execution moves to the join:
                # it still runs exactly once, after every slice.
                extra[ji.name] = (ji, r, kind)
                return [part.profile() for part in parts]

            def mk_join(prof):
                return extra[prof.name.split("#", 1)[0] + "#join"][0] \
                    .profile()

            sl = greedy_order_slices(profs, self.device,
                                     edges=traced.graph.edges,
                                     policy=sp, make_slices=mk_slices,
                                     make_join=mk_join,
                                     frontier=frontier)
            sched = sl.schedule
            names = dict(by_name)
            names.update(extra)
            sl_eids = sl.edges_by_id()
        if self.policy.kind == "refined":
            model = (self.policy.refine_model
                     if self.policy.refine_model in ("round", "event",
                                                     "gated")
                     else "round")
            with self.cache.metrics.timer("phase_refine"):
                order, _, _ = refine_order_dag(
                    sched.order, self.device, edge_ids=sl_eids,
                    model=model,
                    budget=self.policy.refine_budget,
                    neighborhood=self.policy.neighborhood,
                    batch_size=(self.policy.refine_batch
                                if self.policy.refine_backend == "batched"
                                else None),
                    metrics=self.cache.metrics)
            prof_rounds = fifo_rounds_dag(order, self.device, sl_eids,
                                          demands_of=dem)
        else:
            prof_rounds = [rd.kernels for rd in sched.rounds]
        return [[names[p.name] for p in rd] for rd in prof_rounds]

    def compose_dag(self, triples, traced) -> list[list]:
        """Round composition over the per-layer dependency graph.

        The ready-set greedy (:func:`repro.graph.greedy_order_dag`)
        composes rounds that mix stages of *different* requests while
        every chain stays ordered across rounds; ``kind="refined"``
        additionally runs the precedence-respecting local search on
        the flat order (see :meth:`dag_cold`).  The cost-model guard
        compares against the dependency-aware arrival-order packing
        in the currency ``policy.dag_guard`` selects: the round cost
        model, or the gated-event makespan (which is what lets slice
        rounds win, see :class:`GatedGuard`).

        The ScheduleCache participates with coarsened per-request
        *chain* signatures (kind, kv bucket, stage count) so that
        steady-state decode mixes replay cached DAG patterns
        (``dag_hits``); replayed patterns pass the same stale-replay
        re-validation as the flat path.  Only ``"dag"``-namespace keys
        are ever consulted here (asserted in
        :meth:`ScheduleCache.lookup` — the flat-signature key space is
        structurally unreachable from traced steps).
        """
        guard_time = self.dag_guard_fn(traced)
        fifo = self.dag_fifo(triples, traced)
        if self.policy.kind == "fifo":
            return fifo
        key = labels = None
        if self.policy.cache:
            key, labels = self.dag_key_and_labels(triples, traced)
            pattern = self.cache.lookup(key, namespace="dag")
            if pattern is not None:
                replay = self.dag_apply_pattern(pattern, triples,
                                                labels)
                if replay is not None and self.replay_ok(
                        key, replay, self.dag_round_time):
                    # Counted a hit only when the replay is actually
                    # served; rejected/failed replays recompose cold.
                    self.cache.dag_hits += 1
                    # The replay honours the same fifo guard as a cold
                    # composition, so the "never modelled-worse than
                    # dep-aware arrival order" invariant survives
                    # cache hits.
                    if guard_time(fifo) < guard_time(replay):
                        self._note("schedule", path="dag",
                                   served="fifo", source="replay",
                                   rounds=len(fifo))
                        return fifo
                    self._note("schedule", path="dag",
                               served="replay", rounds=len(replay))
                    return replay
                if pattern is not None:
                    self._note("cache", namespace="dag",
                               outcome=("stale" if replay is not None
                                        else "unmappable"))
        composed = self.dag_cold(triples, traced)
        # Same guard as the flat path: never accept a composition the
        # guard currency says is worse than (dep-aware) arrival order.
        result = fifo if guard_time(fifo) < guard_time(composed) \
            else composed
        self._note("schedule", path="dag",
                   served=("fifo" if result is fifo else "cold"),
                   rounds=len(result))
        if key is not None:
            self.dag_store(key, result, labels)
        return result

    # -- DAG-path ScheduleCache (coarsened chain signatures) -----------
    def dag_key_and_labels(self, triples, traced):
        """Cache key + per-item labels for the respect_deps path.

        Fine-grained layer-stage signatures re-key every step (kv-lens
        drift through every attention stage), so the key coarsens to
        the multiset of per-request *chain* signatures: (kind-bucketed
        length via :meth:`ScheduleCache.signature`, chain stage
        count).  Items are labelled ``(chain_sig, rank, chain_pos)``
        — requests with equal signatures are interchangeable, ranked
        by arrival order — which is what lets a cached round pattern
        replay onto a signature-equivalent step.
        """
        cache = self.cache
        owners = traced.owners
        n_req = len(traced.tail_of)
        chain_len = [0] * n_req
        for o in owners:
            chain_len[o] += 1
        chain_sig = []
        for rid in range(n_req):
            it, r, kind = triples[traced.tail_of[rid]]
            length = r.pos if kind == "decode" else it.tokens
            chain_sig.append((cache.signature(kind, length),
                              chain_len[rid]))
        seen = Counter()
        rank = []
        for s in chain_sig:
            rank.append(seen[s])
            seen[s] += 1
        labels = {}
        pos_ctr = [0] * n_req
        for i, (it, _, _) in enumerate(triples):
            rid = owners[i]
            labels[it.name] = (chain_sig[rid], rank[rid], pos_ctr[rid])
            pos_ctr[rid] += 1
        key = ("dag", self.policy.kind,
               ScheduleCache.key_of(chain_sig))
        return key, labels

    def dag_store(self, key, result, labels) -> None:
        """Store a DAG composition as a label pattern.  Sliced items
        record their slice tag alongside the parent stage's label so a
        replay can re-cut a signature-equivalent step identically."""
        def label_of(name):
            parent, _, sub = name.partition("#")
            return labels[parent] + (sub,)
        try:
            pattern = tuple(tuple(label_of(t[0].name) for t in rd)
                            for rd in result)
        except KeyError:           # defensive: unlabelled item
            return
        t_model = sum(self.dag_round_time(rd) for rd in result)
        self.cache.store(key, pattern, t_model)

    def dag_apply_pattern(self, pattern, triples, labels):
        """Replay a cached DAG pattern onto the current step.

        Whole-stage labels map straight onto the current traced items;
        labels carrying slice tags re-cut the current stage with the
        cached slice count (exact accounting on *current* demands —
        the replayed modelled time is honest, which is what the drift
        re-validation inspects).  Any mismatch — a label the current
        step lacks, a slice count the stage can no longer support —
        returns None and the engine recomposes cold."""
        by_label = {}
        for trip in triples:
            by_label[labels[trip[0].name]] = trip
        # slice counts demanded per parent label
        need: dict[tuple, int] = {}
        for rd in pattern:
            for lab in rd:
                *parent, sub = lab
                if sub.startswith("s"):
                    try:
                        k = int(sub.split("of", 1)[1])
                    except (IndexError, ValueError):
                        return None
                    need[tuple(parent)] = k
                elif sub not in ("", "join"):
                    return None
        sp = self.policy.slice_policy
        expanded: dict[tuple, tuple] = {}
        if need:
            if sp is None:
                return None
            slicer = KernelSlicer(sp, self.device)
            for parent, k in need.items():
                trip = by_label.get(parent)
                if trip is None:
                    return None
                it, r, kind = trip
                parts = slicer.slice_item(it, k)
                if len(parts) != k:
                    return None  # stage can no longer support the cut
                for j, part in enumerate(parts):
                    expanded[parent + (f"s{j}of{k}",)] = (part, r, "frag")
                expanded[parent + ("join",)] = (join_item(it), r, kind)
        out = []
        used = set()
        for rd in pattern:
            row = []
            for lab in rd:
                if lab in used:
                    return None
                used.add(lab)
                *parent, sub = lab
                trip = (expanded.get(lab) if sub
                        else by_label.get(tuple(parent)))
                if trip is None:
                    return None
                row.append(trip)
            out.append(row)
        # every current item must be covered exactly once
        want = {labels[t[0].name] + ("",) for t in triples}
        got = {(lab if lab[-1] == "" else tuple(lab[:-1]) + ("",))
               for lab in used}
        if got != want:
            return None
        return out

    def round_fits(self, rd) -> bool:
        """Capacity re-check of one replayed round on actual demands
        (solo rounds are always legal — oversized stages run alone)."""
        if len(rd) <= 1:
            return True
        used = {d: 0.0 for d in self.device.caps}
        for it, _, _ in rd:
            for d, v in it.profile().demands.items():
                if d in used:  # items may demand untracked dims
                    used[d] += v
        return all(used[d] <= self.device.cap(d) * (1 + 1e-9)
                   for d in used)

    def replay_ok(self, key, rounds, time_of) -> bool:
        """Stale-replay re-validation: a replayed pattern whose
        modelled time drifts beyond ``policy.replay_drift_tol`` from
        the stored composition's — or that violates capacity on actual
        demands — is rejected and the step recomposes cold.  Every
        re-validation feeds the per-namespace :class:`DriftMonitor`
        with *how far* the replay drifted (accepted or not), the
        magnitude signal the reject counter alone can't show."""
        tol = self.policy.replay_drift_tol
        if tol is None or tol <= 0:
            return True            # legacy optimistic replay
        cache = self.cache
        t0 = cache.time_of(key)
        t_now = sum(time_of(rd) for rd in rounds)
        rel = (abs(t_now / t0 - 1.0)
               if t0 is not None and t0 > 0 else None)
        if rel is not None:
            self.drift.observe(key[0], rel)
        drifted = rel is not None and rel > tol
        if drifted or not all(self.round_fits(rd) for rd in rounds):
            cache.replay_revalidations += 1
            self._note("cache", namespace=key[0], outcome="revalidated",
                       drift=rel, reason=("drift" if drifted
                                          else "capacity"))
            return False
        return True

    # -- flat path ------------------------------------------------------
    def compose(self, items) -> list[list]:
        """Group pending work items into execution rounds per policy.

        Returns a list of rounds; each round is a list of
        (TpuWorkItem, Request, kind) triples."""
        by_name = {it.name: trip for trip in items for it in (trip[0],)}
        if self.policy.kind == "fifo":
            rounds = fifo_rounds([t[0] for t in items], self.device)
            return [[by_name[it.name] for it in rd] for rd in rounds]
        sigs = [self.signature_of(trip) for trip in items]
        key = None
        stale = False
        if self.policy.cache:
            key = ("flat", self.policy.kind, ScheduleCache.key_of(sigs))
            pattern = self.cache.lookup(key, namespace="flat")
            if pattern is not None:
                replay = self.apply_pattern(pattern, items, sigs)
                if self.replay_ok(key, replay, self.flat_round_time):
                    self._note("schedule", path="flat",
                               served="replay", rounds=len(replay))
                    return replay
                # Stale replay: recompose cold (the fresh composition
                # re-stores under the same key).  Warm-start adaptation
                # is skipped too — a one-signature-away pattern shares
                # the rejected pattern's staleness and performs no
                # capacity/drift re-validation of its own.
                stale = True
            if self.policy.warm_start and not stale:
                warm = self.cache.near_miss(key)
                if warm is not None:
                    result = self.warm_adapt(warm, items, sigs)
                    if result is not None:
                        self._note("schedule", path="flat",
                                   served="warm", rounds=len(result))
                        return self.cache_store(key, result, items, sigs)
        profs = [t[0].profile() for t in items]
        sched: Schedule = greedy_order_fast(profs, self.device)
        if self.policy.kind == "refined":
            if self.policy.refine_model in ("event", "round"):
                # flat-order refinement under the core simulator,
                # delta-evaluated (suffix re-simulation from cached
                # admission checkpoints), then re-rounded by capacity
                with self.cache.metrics.timer("phase_refine"):
                    order, _, _ = refine_order(
                        sched.order, self.device,
                        model=self.policy.refine_model,
                        budget=self.policy.refine_budget,
                        neighborhood=self.policy.neighborhood,
                        batch_size=(self.policy.refine_batch
                                    if self.policy.refine_backend
                                    == "batched" else None),
                        metrics=self.cache.metrics)
            else:
                # local search over the flat order, re-rounded by
                # greedy capacity packing under the round cost model
                def tfn(order_profs):
                    its = [by_name[p.name][0] for p in order_profs]
                    rds = fifo_rounds(its, self.device)
                    return sum(round_time(r, self.device,
                                          self.weights_bytes)
                               for r in rds)

                with self.cache.metrics.timer("phase_refine"):
                    order, _, _ = refine_order(
                        sched.order, self.device, time_fn=tfn,
                        budget=self.policy.refine_budget,
                        neighborhood=self.policy.neighborhood,
                        metrics=self.cache.metrics)
            its = [by_name[p.name][0] for p in order]
            rounds = fifo_rounds(its, self.device)
            result = [[by_name[it.name] for it in rd] for rd in rounds]
            self._note("schedule", path="flat", served="refined",
                       rounds=len(result))
            return self.cache_store(key, result, items, sigs)
        composed = [[by_name[p.name] for p in rd.kernels]
                    for rd in sched.rounds]
        # Cost-model guard: Algorithm 1 is profile-greedy; never accept
        # a composition the round cost model says is worse than arrival
        # order (the scheduler's own timing model is always available).
        with self.cache.metrics.timer("phase_guard"):
            t_alg = sum(round_time([t[0] for t in rd], self.device,
                                   self.weights_bytes)
                        for rd in composed)
            fifo = fifo_rounds([t[0] for t in items], self.device)
            t_fifo = sum(round_time(r, self.device, self.weights_bytes)
                         for r in fifo)
        if t_fifo < t_alg:
            result = [[by_name[it.name] for it in rd] for rd in fifo]
        else:
            result = composed
        self._note("schedule", path="flat",
                   served=("fifo" if t_fifo < t_alg else "cold"),
                   rounds=len(result))
        return self.cache_store(key, result, items, sigs)

    def signature_of(self, trip) -> tuple[str, int]:
        it, r, kind = trip
        length = r.pos if kind == "decode" else it.tokens
        return self.cache.signature(kind, length)

    def cache_store(self, key, result, items, sigs):
        if key is not None:
            name_sig = {trip[0].name: s for trip, s in zip(items, sigs)}
            pattern = tuple(tuple(name_sig[t[0].name] for t in rd)
                            for rd in result)
            t_model = sum(self.flat_round_time(rd) for rd in result)
            self.cache.store(key, pattern, t_model)
        return result

    def apply_pattern(self, pattern, items, sigs):
        """Replay a cached round pattern onto the current (signature-
        equivalent) work items."""
        groups: dict[tuple[str, int], deque] = {}
        for trip, s in zip(items, sigs):
            groups.setdefault(s, deque()).append(trip)
        return [[groups[s].popleft() for s in rd] for rd in pattern]

    def warm_adapt(self, warm, items, sigs):
        """Seed this step's composition from a near-miss cached one.

        One request left: drop its signature's occurrence from the
        cached pattern and replay.  One request joined: replay the
        pattern on the matching items, then place the newcomer into
        the round Algorithm 1's own scoring picks
        (:func:`repro.core.fastscore.warm_start_insert`).  The result
        still passes the fifo cost-model guard; returns None when the
        adaptation cannot be applied.
        """
        pattern, added, removed = warm
        pat = [list(rd) for rd in pattern]
        if removed:
            s = removed[0]
            for rd in pat:
                if s in rd:
                    rd.remove(s)
                    break
            pat = [rd for rd in pat if rd]
        groups: dict[tuple[str, int], deque] = {}
        for trip, s in zip(items, sigs):
            groups.setdefault(s, deque()).append(trip)
        if added:
            extra = groups[added[0]].popleft()
        try:
            result = [[groups[s].popleft() for s in rd] for rd in pat]
        except (KeyError, IndexError):
            return None  # stale pattern shape: fall back to recompute
        if added:
            ri = warm_start_insert(
                [[t[0].profile() for t in rd] for rd in result],
                extra[0].profile(), self.device)
            if ri >= 0:
                result[ri].append(extra)
            else:
                result.append([extra])
        # Same guard as the cold path: never accept a composition the
        # round cost model says is worse than arrival order.
        t_warm = sum(round_time([t[0] for t in rd], self.device,
                                self.weights_bytes) for rd in result)
        fifo = fifo_rounds([t[0] for t in items], self.device)
        t_fifo = sum(round_time(r, self.device, self.weights_bytes)
                     for r in fifo)
        if t_fifo < t_warm:
            by_name = {t[0].name: t for t in items}
            result = [[by_name[it.name] for it in rd] for rd in fifo]
        else:
            self.cache.warm_hits += 1
            # Warm-start quality audit: deprecated-but-aliased onto
            # the online auditor (PR 9) — same deterministic
            # integer-crossing sampling on the warm-hit counter, same
            # ``warm_regret_mean`` / ``warm_sampled`` stats keys.
            self.auditor.warm_audit(self.cache, items, t_warm, t_fifo,
                                    self.weights_bytes)
        return result
