"""Serving substrate: KV-cache engine + symbiotic round scheduler.

A package since PR 7: :mod:`.engine` (step loop + exact execution),
:mod:`.composer` (the per-step composition pipeline), :mod:`.cache`
(the namespaced ScheduleCache), :mod:`.live` (cross-step incremental
composition).  PR 10 adds the async layer: :mod:`.frontend` (arrival
queue, cost-modelled admission control, continuous-batching dispatch
over engine replicas on a virtual clock) and :mod:`.loadgen` (seeded
Poisson/bursty/diurnal load generation).  The historical flat import
surface is preserved here and in :mod:`.engine`.
"""

from .cache import ScheduleCache, Signature
from .composer import Composer, GatedGuard
from .engine import (Request, SchedulerPolicy, ServingEngine,
                     build_dag_triples)
from .frontend import AdmissionPolicy, ServingFrontend, VirtualClock
from .live import LiveComposition
from .loadgen import (ARRIVAL_PROCESSES, LoadGenerator, bursty_arrivals,
                      diurnal_arrivals, make_workload, poisson_arrivals)

__all__ = ["Request", "ScheduleCache", "SchedulerPolicy",
           "ServingEngine", "Signature", "Composer", "GatedGuard",
           "LiveComposition", "build_dag_triples",
           "AdmissionPolicy", "ServingFrontend", "VirtualClock",
           "ARRIVAL_PROCESSES", "LoadGenerator", "bursty_arrivals",
           "diurnal_arrivals", "make_workload", "poisson_arrivals"]
