"""Serving substrate: KV-cache engine + symbiotic round scheduler."""

from .engine import Request, ScheduleCache, SchedulerPolicy, ServingEngine

__all__ = ["Request", "ScheduleCache", "SchedulerPolicy", "ServingEngine"]
