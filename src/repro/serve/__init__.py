"""Serving substrate: KV-cache engine + symbiotic round scheduler.

A package since PR 7: :mod:`.engine` (step loop + exact execution),
:mod:`.composer` (the per-step composition pipeline), :mod:`.cache`
(the namespaced ScheduleCache), :mod:`.live` (cross-step incremental
composition).  The historical flat import surface is preserved here
and in :mod:`.engine`.
"""

from .cache import ScheduleCache, Signature
from .composer import Composer, GatedGuard
from .engine import (Request, SchedulerPolicy, ServingEngine,
                     build_dag_triples)
from .live import LiveComposition

__all__ = ["Request", "ScheduleCache", "SchedulerPolicy",
           "ServingEngine", "Signature", "Composer", "GatedGuard",
           "LiveComposition", "build_dag_triples"]
