"""Serving substrate: KV-cache engine + symbiotic round scheduler."""

from .engine import Request, SchedulerPolicy, ServingEngine

__all__ = ["Request", "SchedulerPolicy", "ServingEngine"]
