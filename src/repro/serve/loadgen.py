"""Seeded load generation for the serving front end (PR 10).

Three arrival processes, all driven by plain :mod:`random` seeded
generators so fixed-seed traces are bit-stable goldens
(``tests/test_loadgen.py``):

* :func:`poisson_arrivals` — homogeneous Poisson: i.i.d. exponential
  inter-arrival gaps at ``rate`` requests per virtual second.
* :func:`bursty_arrivals` — on/off modulated Poisson: bursts of
  ``burst`` arrivals at ``rate * (1 + on_off_ratio)``, separated by
  exponential off-gaps sized so the long-run mean rate stays ``rate``.
* :func:`diurnal_arrivals` — inhomogeneous Poisson by thinning:
  ``lambda(t) = rate * (1 + depth * sin(2*pi*t / period))`` (a
  day/night cycle compressed to virtual seconds).

:func:`make_workload` turns a trace into ``(t_arrive, Request)`` pairs
with seeded prompt lengths and token budgets;
:class:`LoadGenerator` is the closed-loop driver: it shares the
frontend's virtual clock (arrivals beyond capacity queue up, so the
report captures real backpressure) and reduces
``ServingFrontend.stats()`` to the flat serving report —
p50/p99 latency, queue depth, goodput, rejection rate — that
``benchmarks/serving.py``'s ``frontend_bench`` section pins in
``BENCH_serving.json``.  Every number in the report derives from
seeded draws and modelled round times; none from the wall clock.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field

import numpy as np

from .engine import Request

__all__ = ["poisson_arrivals", "bursty_arrivals", "diurnal_arrivals",
           "ARRIVAL_PROCESSES", "make_workload", "LoadGenerator"]


def poisson_arrivals(n: int, rate: float, *, seed: int = 0,
                     t0: float = 0.0) -> list[float]:
    """``n`` arrival instants of a homogeneous Poisson process."""
    if rate <= 0:
        raise ValueError(f"rate must be positive (got {rate})")
    rng = random.Random(seed)
    t, out = float(t0), []
    for _ in range(n):
        t += rng.expovariate(rate)
        out.append(t)
    return out


def bursty_arrivals(n: int, rate: float, *, seed: int = 0,
                    t0: float = 0.0, burst: int = 8,
                    on_off_ratio: float = 9.0) -> list[float]:
    """On/off modulated Poisson: bursts at ``rate * (1+on_off_ratio)``
    with exponential off-gaps restoring the long-run mean ``rate``."""
    if rate <= 0 or on_off_ratio <= 0 or burst < 1:
        raise ValueError("need rate > 0, on_off_ratio > 0, burst >= 1")
    rng = random.Random(seed)
    hot = rate * (1.0 + on_off_ratio)
    gap_mean = burst * (1.0 / rate - 1.0 / hot)
    t, out = float(t0), []
    while len(out) < n:
        for _ in range(min(burst, n - len(out))):
            t += rng.expovariate(hot)
            out.append(t)
        t += rng.expovariate(1.0 / gap_mean)
    return out


def diurnal_arrivals(n: int, rate: float, *, seed: int = 0,
                     t0: float = 0.0, period: float = 32.0,
                     depth: float = 0.8) -> list[float]:
    """Inhomogeneous Poisson by thinning against the peak rate
    ``rate * (1 + depth)``; ``depth`` in [0, 1)."""
    if rate <= 0 or not 0.0 <= depth < 1.0 or period <= 0:
        raise ValueError("need rate > 0, 0 <= depth < 1, period > 0")
    rng = random.Random(seed)
    peak = rate * (1.0 + depth)
    t, out = float(t0), []
    while len(out) < n:
        t += rng.expovariate(peak)
        lam = rate * (1.0 + depth * math.sin(2.0 * math.pi * t / period))
        if rng.random() * peak <= lam:
            out.append(t)
    return out


ARRIVAL_PROCESSES = {
    "poisson": poisson_arrivals,
    "bursty": bursty_arrivals,
    "diurnal": diurnal_arrivals,
}


def make_workload(process: str, n: int, rate: float, *, seed: int = 0,
                  prompt_len: tuple[int, int] = (4, 8),
                  max_new_tokens: tuple[int, int] = (2, 6),
                  vocab: int = 128, rid0: int = 0,
                  **process_kw) -> list[tuple[float, Request]]:
    """``[(t_arrive, Request), ...]`` for a seeded arrival process.

    Request shapes (prompt length, token budget, prompt tokens) draw
    from an independent stream derived from the same seed, so the
    trace *and* the request mix are pinned together by one seed.
    """
    instants = ARRIVAL_PROCESSES[process](n, rate, seed=seed,
                                          **process_kw)
    rng = random.Random(seed ^ 0x5EED)
    out = []
    for k, t in enumerate(instants):
        plen = rng.randint(*prompt_len)
        prompt = np.array([rng.randrange(vocab) for _ in range(plen)],
                          np.int32)
        out.append((t, Request(rid0 + k, prompt,
                               max_new_tokens=rng.randint(
                                   *max_new_tokens))))
    return out


@dataclass
class LoadGenerator:
    """Closed-loop seeded load generator.

    :meth:`drive` runs the workload through a
    :class:`~repro.serve.frontend.ServingFrontend` on its virtual
    clock and returns :meth:`report` — the flat, fully deterministic
    serving summary (p50/p99, queue depth, goodput, rejection rate).
    """

    process: str = "poisson"
    n_requests: int = 16
    rate: float = 4.0
    seed: int = 0
    prompt_len: tuple[int, int] = (4, 8)
    max_new_tokens: tuple[int, int] = (2, 6)
    vocab: int = 128
    #: extra kwargs for the arrival process (burst=, period=, ...)
    process_kw: dict = field(default_factory=dict)

    def workload(self, *, rid0: int = 0) -> list[tuple[float, Request]]:
        return make_workload(self.process, self.n_requests, self.rate,
                             seed=self.seed, prompt_len=self.prompt_len,
                             max_new_tokens=self.max_new_tokens,
                             vocab=self.vocab, rid0=rid0,
                             **self.process_kw)

    def drive(self, frontend, *, rid0: int = 0) -> dict:
        frontend.run(self.workload(rid0=rid0))
        return self.report(frontend)

    def report(self, frontend) -> dict:
        st = frontend.stats()
        lat = st["latency"]
        return {
            "process": self.process,
            "n_requests": self.n_requests,
            "rate": self.rate,
            "seed": self.seed,
            "virtual_time_s": st["virtual_time_s"],
            "completed": lat["completed"],
            "p50_s": lat["p50_s"],
            "p99_s": lat["p99_s"],
            "queue_p50_s": lat["queue_p50_s"],
            "queue_p99_s": lat["queue_p99_s"],
            "queue_depth_max": st["queue_depth_max"],
            "goodput_rps": lat["goodput_rps"],
            "goodput_tokens_per_s": lat["goodput_tokens_per_s"],
            "rejection_rate": st["rejection_rate"],
            "rejected": st["rejected"],
            "deferred_events": st["deferred_events"],
            "max_deferrals": st["max_deferrals"],
            "replica_steps": [r["steps"] for r in st["replicas"]],
        }
