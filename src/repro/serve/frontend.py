"""Async continuous-batching serving front end (PR 10).

The paper's launch-order wins are reported by the engine in modelled
makespan; the north star ("heavy traffic from millions of users") needs
them as *request latency* under a real arrival process.  This module is
that lift: an arrival queue with cost-modelled admission control, a
continuous-batching dispatch loop over one-or-more
:class:`~repro.serve.engine.ServingEngine` replicas, and a management
plane with cache-aware routing — all on a deterministic **virtual
clock**, so the same seeded workload produces the same p50/p99 report
on every run and platform.

Design
------

* **Admission is priced in the composer's currency.**  A queued
  request is admitted to a replica only if the replica's *modelled
  next-step cost* — :func:`repro.core.tpu.fifo_rounds` packing of its
  live work items plus the candidate, each round priced by
  :func:`repro.core.tpu.round_time` — stays within
  :attr:`AdmissionPolicy.round_cost_budget_s`.  Requests are never
  counted; they are costed.  A request whose *solo* round cost exceeds
  the budget on every replica can never be admitted and is rejected at
  ingest (``reason="oversized"``), as is any arrival past
  :attr:`AdmissionPolicy.max_queue_depth` (``reason="queue_full"``).

* **Deferral is bounded (no starvation).**  Admission scans the wait
  queue in FIFO order and lets younger requests bypass a deferred head
  — but only :attr:`AdmissionPolicy.max_defer` times.  A request
  deferred that often *blocks* the queue: nothing behind it is
  admitted until it lands.  Because replicas drain (every dispatched
  step advances every live request by one token) and an idle replica
  has modelled cost 0, the blocked head is admitted as soon as any
  replica's queue drains far enough — bounded wait, pinned by
  ``tests/test_frontend.py``.

* **Continuous batching through the engine's own step loop.**  Admitted
  requests ``submit()`` into the chosen replica mid-flight; the next
  ``step()`` composes them into rounds with whatever is already live.
  With ``SchedulerPolicy.composition="incremental"`` the join flows
  through the :class:`~repro.serve.live.LiveComposition` frontier
  (``incremental_joins``/``incremental_leaves``); with the default
  ``"batch"`` composition each step recomposes from scratch — the
  fallback path.  Either way execution is exact per request, so
  frontend-served tokens are **bit-identical** to a synchronous
  ``step()`` loop over the same requests.

* **Virtual time.**  The dispatch loop is a discrete-event simulation:
  replica ``i``'s clock advances by the *modelled* round times of each
  step it runs (the same ``_round_times`` the engine reports), arrivals
  occur at their seeded instants, and the frontend's own
  :class:`~repro.obs.LatencyTracker` is fed explicit virtual
  timestamps.  No wall clock is read anywhere on the report path.

* **Cache-aware routing.**  ``route="cache_affinity"`` routes requests
  with the same prefill signature (the :class:`ScheduleCache` key
  currency) to the same replica so its pattern store stays warm;
  first-seen signatures fall back to the least-loaded replica (by
  modelled cost, deterministic index tie-break).  Replicas may share
  one :class:`~repro.serve.cache.ScheduleCache`
  (``ServingFrontend.build(..., shared_cache=True)``) or keep their
  own; ``tests/test_frontend.py`` pins lookup conservation across both
  modes.

Observability: the frontend owns a :class:`MetricsRegistry` with
``frontend_submitted`` / ``frontend_admitted`` / ``frontend_deferred``
/ ``frontend_rejected{reason=...}`` counters, a
``frontend_queue_depth`` gauge (plus depth histogram), per-replica
``replica_steps{replica=...}`` / ``replica_busy_s{replica=...}``
series, and the PR 9 latency histograms on virtual time.  With a
:class:`~repro.obs.FlightRecorder` attached it emits ``arrival`` /
``admit`` / ``defer`` / ``reject`` / ``frontend_step`` events; each
``frontend_step`` carries both the global dispatch ``tick`` and the
replica's **engine-local** step count, and audit sampling keys on the
latter (each replica's own ``QualityAuditor``), so ``audit_frac``
semantics are unchanged per replica.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

from repro.core.tpu import (decode_profile, fifo_rounds, prefill_profile,
                            round_time)
from repro.obs import LatencyTracker, MetricsRegistry

__all__ = ["AdmissionPolicy", "VirtualClock", "ServingFrontend"]


class VirtualClock:
    """Deterministic virtual time source.

    Advances only by explicit modelled durations — never reads the wall
    clock — and enforces monotonicity: a negative ``advance`` raises,
    ``advance_to`` a past instant is a no-op.  Bound ``now`` is a valid
    ``clock=`` for :class:`repro.obs.LatencyTracker`.
    """

    __slots__ = ("_t",)

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"virtual clock cannot go backwards (dt={dt})")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        if t > self._t:
            self._t = float(t)
        return self._t


@dataclass
class AdmissionPolicy:
    """Knobs for cost-modelled admission (see module docstring).

    ``round_cost_budget_s`` is in the composer's currency — modelled
    seconds of the replica's next step under the TPU round cost model —
    NOT a request count.
    """

    #: ceiling on a replica's modelled next-step cost (seconds under
    #: :func:`repro.core.tpu.round_time` over fifo-packed rounds);
    #: admission keeps every replica at or below it.
    round_cost_budget_s: float = 0.5
    #: arrivals beyond this many waiting requests are rejected
    #: (``reason="queue_full"``).
    max_queue_depth: int = 64
    #: how many times a waiting request may be bypassed by younger
    #: arrivals before it blocks the queue (starvation bound).
    max_defer: int = 8
    #: replica routing: ``cache_affinity`` (sticky by prefill
    #: signature, least-loaded for first-seen), ``least_loaded``
    #: (modelled cost argmin), or ``round_robin``.
    route: str = "cache_affinity"


class _Waiting:
    """A request in the frontend arrival queue."""

    __slots__ = ("req", "t_arrive", "deferrals")

    def __init__(self, req, t_arrive: float):
        self.req = req
        self.t_arrive = t_arrive
        self.deferrals = 0


class ServingFrontend:
    """Management plane over one-or-more engine replicas.

    ``engines`` are pre-built :class:`ServingEngine` replicas (use
    :meth:`build` for the common pool shapes, including a shared
    :class:`ScheduleCache`).  Drive it with :meth:`run` over a
    ``[(t_arrive, Request), ...]`` workload — e.g. from
    :func:`repro.serve.loadgen.make_workload` — then read
    :meth:`stats` / :meth:`outputs`.
    """

    def __init__(self, engines, admission: AdmissionPolicy | None = None,
                 *, metrics: MetricsRegistry | None = None,
                 recorder=None, clock: VirtualClock | None = None):
        if not engines:
            raise ValueError("ServingFrontend needs at least one engine")
        self.engines = list(engines)
        self.admission = admission or AdmissionPolicy()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.recorder = recorder
        self.clock = clock or VirtualClock()
        #: virtual-time latency spans (arrival → admission → completion)
        self.latency = LatencyTracker(self.metrics, clock=self.clock.now)
        self.queue: deque[_Waiting] = deque()
        #: virtual instant at which each replica's last step finishes
        self._t_replica = [0.0] * len(self.engines)
        self._busy_s = [0.0] * len(self.engines)
        self._steps = [0] * len(self.engines)
        self._tick = 0
        self._affinity: dict[tuple, int] = {}
        self._rr = 0
        self._done: set[int] = set()
        #: ``(rid, t_complete, replica)`` in dispatch order — the
        #: monotonicity property in ``tests/test_loadgen.py`` reads it.
        self.completions: list[tuple[int, float, int]] = []
        self._queue_depth_max = 0
        self._max_deferrals = 0

    # -- construction ---------------------------------------------------
    @classmethod
    def build(cls, cfg, params, *, n_replicas: int = 1, policy=None,
              admission: AdmissionPolicy | None = None,
              shared_cache: bool = False, max_len: int = 256,
              device=None, recorder=None, metrics=None, **engine_kw):
        """Build a replica pool over one model.

        ``shared_cache=True`` gives every replica the same
        :class:`ScheduleCache` (with its own registry); otherwise each
        engine keeps its per-replica cache in its own registry.
        """
        from .cache import ScheduleCache
        from .engine import SchedulerPolicy, ServingEngine

        policy = policy or SchedulerPolicy()
        shared = (ScheduleCache(kv_bucket=policy.kv_bucket)
                  if shared_cache else None)
        engines = [ServingEngine(cfg, params, max_len=max_len,
                                 policy=policy, device=device,
                                 recorder=recorder, schedule_cache=shared,
                                 **engine_kw)
                   for _ in range(n_replicas)]
        return cls(engines, admission, metrics=metrics,
                   recorder=recorder)

    # -- cost model (the composer's currency) ---------------------------
    def _item_of(self, eng, req):
        kvb = eng._kv_bytes_per_token()
        if req.cache is None:
            return prefill_profile(f"prefill:{req.rid}",
                                   n_params=eng.n_params,
                                   seq_len=int(len(req.prompt)),
                                   kv_bytes_per_token=kvb)
        return decode_profile(f"decode:{req.rid}", n_params=eng.n_params,
                              kv_len=req.pos, kv_bytes_per_token=kvb)

    def solo_cost_s(self, i: int, req) -> float:
        """Modelled round cost of ``req`` alone on replica ``i``."""
        eng = self.engines[i]
        return round_time([self._item_of(eng, req)], eng.device,
                          eng.weights_bytes)

    def step_cost_s(self, i: int, extra=()) -> float:
        """Modelled cost of replica ``i``'s next step: fifo-packed
        rounds over its live work items (plus ``extra`` candidate
        requests), each priced by :func:`round_time` with the weight
        stream charged once per round."""
        eng = self.engines[i]
        items = [t[0] for t in eng._work_items()]
        items += [self._item_of(eng, r) for r in extra]
        if not items:
            return 0.0
        return sum(round_time(rd, eng.device, eng.weights_bytes)
                   for rd in fifo_rounds(items, eng.device))

    # -- admission ------------------------------------------------------
    def _note(self, kind: str, **fields) -> None:
        if self.recorder is not None:
            self.recorder.event(kind, **fields)

    def _ingest(self, req) -> None:
        """Arrival at the current virtual instant: reject or enqueue."""
        self.metrics.counter("frontend_submitted").inc()
        now = self.clock.now()
        if len(self.queue) >= self.admission.max_queue_depth:
            self._reject(req, "queue_full", now)
            return
        if min(self.solo_cost_s(i, req)
               for i in range(len(self.engines))) > \
                self.admission.round_cost_budget_s:
            self._reject(req, "oversized", now)
            return
        self.queue.append(_Waiting(req, now))
        self._queue_depth_max = max(self._queue_depth_max, len(self.queue))
        self._depth()
        self.latency.arrive(req.rid, t=now)
        self._note("arrival", rid=req.rid, t=now)

    def _reject(self, req, reason: str, now: float) -> None:
        self.metrics.counter("frontend_rejected", reason=reason).inc()
        self._note("reject", rid=req.rid, reason=reason, t=now)

    def _depth(self) -> None:
        self.metrics.gauge("frontend_queue_depth").set(len(self.queue))
        self.metrics.histogram("frontend_queue_depth_hist").observe(
            float(len(self.queue)))

    def _route(self, req) -> int:
        a = self.admission
        if a.route == "round_robin":
            i = self._rr % len(self.engines)
            self._rr += 1
            return i
        by_load = min(range(len(self.engines)),
                      key=lambda j: (self.step_cost_s(j), j))
        if a.route == "least_loaded":
            return by_load
        if a.route != "cache_affinity":
            raise ValueError(f"unknown route {a.route!r}")
        # prefill signature: the ScheduleCache key currency for the
        # work the request brings on admission.
        sig = ("p", int(len(req.prompt)))
        if sig not in self._affinity:
            self._affinity[sig] = by_load
        return self._affinity[sig]

    def _admit(self) -> None:
        """One admission pass: FIFO scan with bounded bypass.

        Invariant (pinned by tests): a request is admitted to replica
        ``i`` only if ``step_cost_s(i, extra=(req,)) <=
        round_cost_budget_s``.  A head deferred ``max_defer`` times
        blocks all younger requests until it is admitted.
        """
        budget = self.admission.round_cost_budget_s
        out: deque[_Waiting] = deque()
        blocked = False
        while self.queue:
            w = self.queue.popleft()
            if blocked:
                out.append(w)
                continue
            routed = self._route(w.req)
            order = [routed] + sorted(
                (j for j in range(len(self.engines)) if j != routed),
                key=lambda j: (self.step_cost_s(j), j))
            target = None
            est_with = None
            for i in order:
                est_with = self.step_cost_s(i, extra=(w.req,))
                if est_with <= budget:
                    target = i
                    break
            if target is not None:
                self.engines[target].submit([w.req])
                self.metrics.counter("frontend_admitted",
                                     replica=str(target)).inc()
                now = self.clock.now()
                # close the queue span at the admission instant
                self.latency.attribute([w.req.rid], {}, t=now)
                self._note("admit", rid=w.req.rid, replica=target,
                           est_with=est_with, budget=budget, t=now,
                           waited=now - w.t_arrive,
                           deferrals=w.deferrals)
            else:
                w.deferrals += 1
                self._max_deferrals = max(self._max_deferrals,
                                          w.deferrals)
                self.metrics.counter("frontend_deferred").inc()
                self._note("defer", rid=w.req.rid,
                           deferrals=w.deferrals, t=self.clock.now())
                out.append(w)
                if w.deferrals >= self.admission.max_defer:
                    blocked = True
        self.queue = out
        self._depth()

    # -- dispatch -------------------------------------------------------
    @staticmethod
    def _live(eng) -> bool:
        return any(not r.done for r in eng.queue)

    def _dispatch(self, i: int) -> None:
        """Run one engine step on replica ``i`` at virtual ``now``."""
        eng = self.engines[i]
        n0 = len(eng._round_times)
        ran = eng.step()
        dt = float(sum(eng._round_times[n0:]))
        start = max(self._t_replica[i], self.clock.now())
        t_end = start + dt
        self._t_replica[i] = t_end
        self._busy_s[i] += dt
        self._steps[i] += 1
        self._tick += 1
        self.metrics.counter("replica_steps", replica=str(i)).inc()
        self.metrics.gauge("replica_busy_s", replica=str(i)).set(
            self._busy_s[i])
        # engine-local step count — the auditor keys its sampling on
        # this (each replica's own QualityAuditor), never on the
        # global tick (satellite 4).
        engine_step = int(eng.metrics.counter("engine_steps").value)
        self._note("frontend_step", replica=i, tick=self._tick,
                   engine_step=engine_step, rounds=ran, dt=dt,
                   t_start=start, t_end=t_end)
        for r in eng.queue:
            if r.done and r.rid not in self._done:
                self._done.add(r.rid)
                self.completions.append((r.rid, t_end, i))
                self.latency.complete(r.rid, tokens=len(r.generated),
                                      t=t_end)

    def run(self, workload, *, max_ticks: int = 100_000) -> dict:
        """Discrete-event loop over ``[(t_arrive, Request), ...]``.

        Events are processed in virtual-time order: an arrival at or
        before the next step's start is ingested (and admission
        re-tried) first, then the busiest-soonest replica runs one
        step.  Returns :meth:`stats`.
        """
        pending = deque(sorted(workload,
                               key=lambda p: (p[0], p[1].rid)))
        while self._tick < max_ticks:
            busy = [i for i in range(len(self.engines))
                    if self._live(self.engines[i])]
            t_arr = pending[0][0] if pending else None
            if busy:
                i = min(busy, key=lambda j: (self._t_replica[j], j))
                t_step = max(self._t_replica[i], self.clock.now())
            else:
                i, t_step = None, None
            if t_arr is not None and (t_step is None or t_arr <= t_step):
                t, req = pending.popleft()
                self.clock.advance_to(t)
                self._ingest(req)
                self._admit()
                continue
            if i is None:
                if not self.queue:
                    break                       # fully drained
                self._admit()                   # idle pool: must progress
                if not any(self._live(e) for e in self.engines):
                    break                       # nothing admissible left
                continue
            self.clock.advance_to(t_step)
            self._admit()
            self._dispatch(i)
        # report at the instant the last replica finishes
        self.clock.advance_to(max(self._t_replica))
        return self.stats()

    # -- reporting ------------------------------------------------------
    def outputs(self) -> dict:
        """``{rid: generated tokens}`` across the pool — the
        bit-identity comparison key against a synchronous run."""
        out = {}
        for eng in self.engines:
            for r in eng.queue:
                out[r.rid] = list(r.generated)
        return out

    def stats(self) -> dict:
        """Deterministic (virtual-time) serving report."""
        m = self.metrics
        submitted = int(m.counter("frontend_submitted").value)
        admitted = sum(
            int(m.counter("frontend_admitted", replica=str(i)).value)
            for i in range(len(self.engines)))
        rejected = sum(
            int(m.counter("frontend_rejected", reason=r).value)
            for r in ("queue_full", "oversized"))
        return {
            "virtual_time_s": self.clock.now(),
            "ticks": self._tick,
            "submitted": submitted,
            "admitted": admitted,
            "rejected": rejected,
            "deferred_events": int(
                m.counter("frontend_deferred").value),
            "max_deferrals": self._max_deferrals,
            "rejection_rate": rejected / max(submitted, 1),
            "queue_depth_max": self._queue_depth_max,
            "latency": self.latency.stats(max(self.clock.now(), 1e-12)),
            "replicas": [
                {"replica": i,
                 "steps": self._steps[i],
                 "busy_s": self._busy_s[i],
                 "t_done_s": self._t_replica[i],
                 "schedule_cache": eng.schedule_cache.stats()}
                for i, eng in enumerate(self.engines)],
        }
