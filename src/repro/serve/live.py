"""Live incremental composition (PR 7,
``SchedulerPolicy.composition="incremental"``).

The batch pipeline recomposes every ``step()`` from scratch even
though consecutive serving steps differ by one or two requests: a
join, a leave, a prefill chain turning into a decode chain.  This
module keeps the ready-set greedy's round-frontier state alive across
steps (:class:`repro.graph.constrained.GreedyFrontier`) and edits it:

* a **join** places the new request's chain stage by stage where
  Algorithm 1's own scoring puts it (the ``warm_start_insert`` rule
  generalized to precedence chains), slice-expanding a stage that fits
  nowhere when the policy allows
  (:func:`repro.slice.constrained.frontier_solo_expander`);
* a **leave** retires the chain's stages and re-folds the affected
  rounds' ProfileCombine states;
* every other chain is **refreshed** in place — swapped to the
  current step's drifted profiles (decode kv growth) without moving.

A request's phase change (prefill chain → decode chain) is a
leave + join pair: the chains are different workloads, not a drifted
copy of one.

Identity anchoring: :func:`repro.graph.kernel_graph.trace_arch` names
stages by the request's *index in the traced list* (``r0:d:L0:attn``),
so every leave renames all later requests' stages.  The frontier is
therefore tracked by **stable labels** ``(Request.rid, phase,
chain_pos, slice_sub)``; each step the previous step's member names
are translated through the labels onto the current step's items, and
slice cuts are re-applied to the current (drifted) stage before
refresh so the re-cut parts stay exact-accounting.

Backstops — any of these forces a cold recomposition
(``frontier_rebuilds`` in ``ScheduleCache.stats()``) and re-seeds the
frontier from its result:

* label bookkeeping fails to map (an untracked topology change);
* a frontier round violates device capacity on current demands;
* the incremental composition's modelled-time ratio against dep-aware
  arrival order drifts beyond ``policy.replay_drift_tol`` of the
  ratio recorded at the last cold (re)build — the same knob, and the
  same "validate against your own recorded baseline" discipline, as
  the stale-replay check;
* the step guard (``policy.dag_guard`` currency) prefers arrival
  order over the incremental composition.

Tokens are unaffected by any of this: execution is exact per request,
so ``composition="incremental"`` is bit-identical to ``"batch"`` (the
property ``tests/test_live.py`` pins across all traced archs).

On traced steps this layer *is* the cross-step memo, so it bypasses
the :class:`~repro.serve.cache.ScheduleCache` pattern store entirely
(no ``dag_hits`` accrue); the cache object still carries the
counters.
"""

from __future__ import annotations

from repro.graph.constrained import GreedyFrontier
from repro.slice import KernelSlicer, join_item
from repro.slice.constrained import frontier_solo_expander

__all__ = ["LiveComposition"]

#: stable member label: (Request.rid, phase, chain_pos, slice_sub)
#: with phase in ("prefill", "decode") and slice_sub "" for a whole
#: stage, "s{j}of{k}" for a slice, "join" for a slice join.
Label = tuple[int, str, int, str]


class _Drift(Exception):
    """Internal: label bookkeeping failed to map the current step —
    fall back to a cold rebuild."""


class LiveComposition:
    """Resumable round composition over the traced (respect_deps)
    serving path.  One instance per engine; composes via the shared
    :class:`~repro.serve.composer.Composer` on cold (re)builds and by
    frontier editing otherwise."""

    def __init__(self, composer):
        self.composer = composer
        self.frontier = GreedyFrontier(composer.device)
        self._seeded = False
        #: previous step's frontier member names -> stable labels
        self._label_of: dict[str, Label] = {}
        #: tracked chains: (rid, phase) -> stage count
        self._chains: dict[tuple[int, str], int] = {}
        #: modelled-time ratio (composition / dep-aware fifo, round
        #: currency) at the last cold (re)build — the drift baseline.
        self._ratio0: float | None = None

    # -- step decomposition --------------------------------------------
    @staticmethod
    def _chain_view(triples, traced):
        """Current step as chains: per traced request index, its
        ``(rid, phase)`` key, Request, and item indices in stage
        order."""
        n_req = len(traced.tail_of)
        chain_items: list[list[int]] = [[] for _ in range(n_req)]
        for i, o in enumerate(traced.owners):
            chain_items[o].append(i)
        chains = []
        for ridx in range(n_req):
            it, r, kind = triples[traced.tail_of[ridx]]
            chains.append(((r.rid, kind), r, chain_items[ridx]))
        return chains

    def compose_dag(self, triples, traced) -> list[list]:
        composer = self.composer
        policy = composer.policy
        if policy.kind == "fifo" or not triples:
            return composer.dag_fifo(triples, traced)
        chains = self._chain_view(triples, traced)
        if not self._seeded:
            return self._rebuild(triples, traced, chains, count=False,
                                 reason="seed")
        cur = {key: len(items) for key, _, items in chains}
        left = [key for key, n in self._chains.items()
                if cur.get(key) != n]
        joined = [key for key, n in cur.items()
                  if self._chains.get(key) != n]
        cache = composer.cache
        try:
            trip_by_name, fresh = self._map_step(triples, traced,
                                                 chains, set(left))
            if left:
                gone = {name for name, lab in self._label_of.items()
                        if (lab[0], lab[1]) in
                        {(k[0], k[1]) for k in left}}
                self.frontier.remove(gone)
                cache.incremental_leaves += len(left)
            self.frontier.refresh(fresh)
            if joined:
                on_solo = self._expander(trip_by_name)
                want = set(joined)
                for key, _, items in chains:
                    if key not in want:
                        continue
                    profs = [traced.graph.kernels[i] for i in items]
                    self.frontier.insert_chain(profs, on_solo=on_solo)
                    cache.incremental_joins += 1
            rounds = self._materialize(triples, trip_by_name)
        except _Drift:
            return self._rebuild(triples, traced, chains, count=True,
                                 reason="label_drift")
        # -- backstops: capacity, modelled-ratio drift, step guard ----
        fifo = composer.dag_fifo(triples, traced)
        with cache.metrics.timer("phase_guard"):
            t_inc = sum(composer.dag_round_time(rd) for rd in rounds)
            t_fifo = sum(composer.dag_round_time(rd) for rd in fifo)
        ratio = t_inc / max(t_fifo, 1e-30)
        tol = policy.replay_drift_tol
        if self._ratio0 is not None and self._ratio0 > 0:
            # "live" namespace drift: how far the maintained frontier's
            # modelled ratio has wandered from its last cold baseline.
            composer.drift.observe("live",
                                   ratio / self._ratio0 - 1.0)
        drifted = (tol is not None and tol > 0
                   and self._ratio0 is not None
                   and ratio > self._ratio0 * (1.0 + tol))
        if drifted:
            return self._rebuild(triples, traced, chains, count=True,
                                 reason="ratio_drift")
        if not all(composer.round_fits(rd) for rd in rounds):
            return self._rebuild(triples, traced, chains, count=True,
                                 reason="capacity")
        if policy.dag_guard == "gated":
            guard = composer.dag_guard_fn(traced)
            guard_rejects = guard(fifo) < guard(rounds)
        else:
            # the "rounds" guard currency is exactly the sums already
            # computed for the drift ratio — don't re-sum them
            guard_rejects = t_fifo < t_inc
        if guard_rejects:
            # The frontier produced a composition the guard rejects:
            # its state is stale relative to what a cold composition
            # would serve — rebuild rather than silently serving fifo
            # forever off a losing frontier.
            return self._rebuild(triples, traced, chains, count=True,
                                 reason="guard")
        if composer.recorder is not None:
            composer.recorder.event("schedule", path="live",
                                    served="incremental",
                                    rounds=len(rounds))
        self._commit(chains, rounds,
                     self._stable_items(chains, traced.graph.kernels))
        return rounds

    # -- label bookkeeping ---------------------------------------------
    @staticmethod
    def _stable_items(chains, kernels):
        """item name -> (rid, phase, chain_pos) for the current step."""
        out = {}
        for (rid, phase), _, items in chains:
            for pos, i in enumerate(items):
                out[kernels[i].name] = (rid, phase, pos)
        return out

    def _map_step(self, triples, traced, chains, left):
        """Translate the previous step's frontier member names onto
        the current step.

        Returns ``(trip_by_name, fresh)``: the current step's
        name -> (item, Request, kind) map (slice re-cuts included) and
        the old-member-name -> current-profile map for
        :meth:`GreedyFrontier.refresh`.  Raises :class:`_Drift` when a
        surviving label has no current counterpart."""
        kernels = traced.graph.kernels
        trip_by_name = {t[0].name: t for t in triples}
        by_stable = {}
        for (rid, phase), _, items in chains:
            for pos, i in enumerate(items):
                by_stable[(rid, phase, pos)] = trip_by_name[
                    kernels[i].name]
        # surviving slice cuts, grouped by parent stable label
        cuts: dict[tuple[int, str, int], int] = {}
        for name, (rid, phase, pos, sub) in self._label_of.items():
            if (rid, phase) in {(k[0], k[1]) for k in left}:
                continue
            if sub.startswith("s"):
                try:
                    cuts[(rid, phase, pos)] = int(sub.split("of", 1)[1])
                except (IndexError, ValueError):
                    raise _Drift from None
        new_prof_of: dict[Label, object] = {}
        if cuts:
            sp = self.composer.policy.slice_policy
            if sp is None:        # policy changed under a live cut
                raise _Drift
            slicer = KernelSlicer(sp, self.composer.device)
            for (rid, phase, pos), k in cuts.items():
                trip = by_stable.get((rid, phase, pos))
                if trip is None:
                    raise _Drift
                it, r, kind = trip
                parts = slicer.slice_item(it, k)
                if len(parts) != k:
                    raise _Drift  # stage no longer supports the cut
                for j, part in enumerate(parts):
                    trip_by_name[part.name] = (part, r, "frag")
                    new_prof_of[(rid, phase, pos, f"s{j}of{k}")] = \
                        part.profile()
                ji = join_item(it)
                trip_by_name[ji.name] = (ji, r, kind)
                new_prof_of[(rid, phase, pos, "join")] = ji.profile()
        fresh = {}
        gone_keys = {(k[0], k[1]) for k in left}
        for name, (rid, phase, pos, sub) in self._label_of.items():
            if (rid, phase) in gone_keys:
                continue
            if sub:
                prof = new_prof_of.get((rid, phase, pos, sub))
            else:
                trip = by_stable.get((rid, phase, pos))
                prof = None if trip is None else trip[0].profile()
            if prof is None:
                raise _Drift
            fresh[name] = prof
        return trip_by_name, fresh

    def _expander(self, trip_by_name):
        """Slice-expansion hook for live joins: cuts the backing work
        item (so the composed rounds stay executable) and registers
        the parts in this step's name map, exactly mirroring the
        engine's batch-path closures."""
        sp = self.composer.policy.slice_policy
        if sp is None:
            return None
        slicer = KernelSlicer(sp, self.composer.device)

        def mk_slices(prof, k):
            it, r, kind = trip_by_name[prof.name]
            parts = slicer.slice_item(it, k)
            for part in parts:
                trip_by_name[part.name] = (part, r, "frag")
            ji = join_item(it)
            # the chain tail's exact execution moves to the join
            trip_by_name[ji.name] = (ji, r, kind)
            return [part.profile() for part in parts]

        def mk_join(prof):
            return trip_by_name[prof.name.split("#", 1)[0]
                                + "#join"][0].profile()

        return frontier_solo_expander(slicer, mk_slices, mk_join)

    def _materialize(self, triples, trip_by_name) -> list[list]:
        """Frontier rounds -> executable (item, Request, kind) rounds,
        with a coverage check: every traced item appears exactly once
        (as itself, or fully expanded into slices + join)."""
        rounds = []
        seen: set[str] = set()
        parents: set[str] = set()
        for rd in self.frontier.rounds:
            row = []
            for k in rd.members:
                trip = trip_by_name.get(k.name)
                if trip is None or k.name in seen:
                    raise _Drift
                seen.add(k.name)
                parents.add(k.name.partition("#")[0])
                row.append(trip)
            rounds.append(row)
        if parents != {t[0].name for t in triples}:
            raise _Drift
        return rounds

    def _commit(self, chains, rounds, stable_by_name) -> None:
        """Refresh the stable-label map and tracked-chain set from the
        composition just served.  ``stable_by_name`` maps *parent*
        item names to their ``(rid, phase, pos)`` prefix
        (:meth:`_stable_items`); slice parts and joins inherit the
        prefix through the name before their ``#`` tag."""
        self._label_of = {}
        for rd in rounds:
            for it, _, _ in rd:
                parent, _, sub = it.name.partition("#")
                st = stable_by_name.get(parent)
                if st is None:
                    raise _Drift   # served an item no chain owns
                self._label_of[it.name] = st + (sub,)
        self._chains = {key: len(items) for key, _, items in chains}
        self._seeded = True

    # -- cold path ------------------------------------------------------
    def _rebuild(self, triples, traced, chains, *, count: bool,
                 reason: str = "unknown") -> list[list]:
        """Cold recomposition through the batch pipeline, re-seeding
        the frontier from whatever composition the guard serves.
        ``reason`` names the backstop that fired (``seed`` /
        ``label_drift`` / ``ratio_drift`` / ``capacity`` / ``guard``)
        — emitted to the flight recorder and counted per reason."""
        composer = self.composer
        cache = composer.cache
        if count:
            cache.metrics.counter("frontier_rebuild_reason",
                                  reason=reason).inc()
        if composer.recorder is not None:
            composer.recorder.event("rebuild", reason=reason,
                                    counted=count)
        self.frontier.reset()
        guard = composer.dag_guard_fn(traced)
        fifo = composer.dag_fifo(triples, traced)
        composed = composer.dag_cold(triples, traced,
                                     frontier=self.frontier)
        result = fifo if guard(fifo) < guard(composed) else composed
        want = [[t[0].name for t in rd] for rd in result]
        if self.frontier.round_names() != want:
            # refined re-rounding or a guard fifo win: the greedy's
            # own frontier doesn't match what is being served —
            # re-derive state from the served composition instead.
            self.frontier.seed([[t[0].profile() for t in rd]
                                for rd in result])
        t_res = sum(composer.dag_round_time(rd) for rd in result)
        t_fifo = sum(composer.dag_round_time(rd) for rd in fifo)
        self._ratio0 = t_res / max(t_fifo, 1e-30)
        if count:
            cache.frontier_rebuilds += 1
        self._commit(chains, result,
                     self._stable_items(chains, traced.graph.kernels))
        return result
