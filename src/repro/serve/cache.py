"""The serving ``ScheduleCache``: memoised round compositions.

Split out of the engine monolith in PR 7.  The cache knows nothing
about models or execution — it stores round *patterns* (partitions of
work-item signatures) keyed on the multiset of signatures in the step,
plus the counters the engine layers above it increment
(``dag_hits``, ``replay_revalidations``, the warm-start audit, and
since PR 7 the live-composition and gated-guard counters).

Keys are explicitly namespaced: every key is a 3-tuple
``(namespace, kind, sigs)`` with ``namespace`` one of

* ``"flat"`` — the per-request work-item path
  (:meth:`repro.serve.composer.Composer.compose`), ``sigs`` the sorted
  per-item signature tuple, and
* ``"dag"``  — the ``respect_deps`` traced-chain path
  (:meth:`repro.serve.composer.Composer.compose_dag`), ``sigs`` the
  sorted per-request *chain*-signature tuple.

The namespaces make the PR 3 cache-bypass wart structurally
impossible: a flat-signature pattern can never be consulted on a
traced step (and vice versa) because the key spaces are disjoint, and
:meth:`lookup` asserts the caller names the namespace it expects.
:meth:`near_miss` only ever scans the flat namespace — a one-request
warm adaptation of a *chain* pattern is the live-composition layer's
job (:class:`repro.serve.live.LiveComposition`), not the cache's.
"""

from __future__ import annotations

from collections import Counter, OrderedDict

from repro.obs import MetricsRegistry

__all__ = ["ScheduleCache", "Signature"]


def _metric(name: str, cast=int, doc: str | None = None) -> property:
    """Registry-backed counter exposed as a plain attribute.

    PR 8 moved the cache's counters onto the
    :class:`repro.obs.MetricsRegistry`, but every historical call
    site (composer, live composition, tests) reads and increments
    them as attributes — ``cache.dag_hits += 1``.  These properties
    keep that surface byte-for-byte: the getter reads the registry
    series (cast back to the legacy type), the setter makes augmented
    assignment work unchanged.
    """

    def fget(self):
        return cast(self.metrics.counter(name).value)

    def fset(self, v):
        self.metrics.counter(name).value = float(v)

    return property(fget, fset, doc=doc)

#: Work-item signature: what makes two items schedule-equivalent.
#: Prefill chunks are keyed by exact token count (compiled geometry);
#: decode steps by their kv-len bucket — within a bucket the demand
#: vectors are close enough that the greedy + guard + refine pipeline
#: composes the same round structure.
Signature = tuple[str, int]


class ScheduleCache:
    """Memoised round compositions keyed on the multiset of work-item
    signatures.

    Steady-state decode-heavy serving repeats near-identical
    compositions every ``step()``: the same live requests, each one
    kv-token longer.  Quantizing decode kv-lens into buckets makes
    consecutive steps hash to the same key, so the engine replays the
    cached round *pattern* (a partition of signatures) instead of
    re-running greedy + guard + refine.  Patterns are applied by
    matching signatures, never by request identity, so any same-mix
    step can reuse them; generated tokens are unaffected because
    execution is exact per request regardless of round membership.
    """

    #: near-miss adaptations that seeded a composition (see
    #: :meth:`near_miss`); every warm hit is also counted a miss,
    #: since :meth:`lookup` failed first.
    warm_hits = _metric("cache_warm_hits")
    #: hits served on the respect_deps path (coarsened per-request
    #: chain-signature keys); a subset of ``hits``.
    dag_hits = _metric("cache_dag_hits")
    #: replays rejected by the stale-replay re-validation (modelled
    #: drift above ``SchedulerPolicy.replay_drift_tol`` or a
    #: capacity violation on actual demands) and recomposed cold.
    replay_revalidations = _metric("cache_replay_revalidations")
    #: warm-start quality audit (ROADMAP item): on a sampled
    #: fraction of warm hits the engine also recomputes the cold
    #: greedy composition and records the modelled regret
    #: ``t_warm / t_cold - 1`` (round cost model; negative means
    #: the adapted composition modelled *better* than cold).
    warm_sampled = _metric("cache_warm_sampled")
    warm_regret_total = _metric("cache_warm_regret_total", cast=float)
    #: live-composition counters (PR 7,
    #: ``SchedulerPolicy.composition="incremental"``): chains
    #: extended into / retired from the live frontier, and cold
    #: recompositions forced by the drift backstop.
    incremental_joins = _metric("cache_incremental_joins")
    incremental_leaves = _metric("cache_incremental_leaves")
    frontier_rebuilds = _metric("cache_frontier_rebuilds")
    #: full gated simulations *not* paid because the per-step
    #: gated guard resumed from a checkpointed prefix instead of
    #: re-simulating from scratch (PR 7; fractional — each delta
    #: evaluation saves ``1 - suffix_fraction`` of a full sim).
    gated_sims_saved = _metric("cache_gated_sims_saved", cast=float)

    def __init__(self, kv_bucket: int = 256, max_entries: int = 256,
                 metrics: MetricsRegistry | None = None):
        self.kv_bucket = kv_bucket
        self.max_entries = max_entries
        #: the registry behind every counter attribute on this class;
        #: pass the engine's shared registry so cache series land in
        #: the same snapshot as the phase timers.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # Per-namespace hit/miss series, resolved once (lookup() is a
        # hot path): the legacy flat ``hits``/``misses`` totals are
        # derived sums over these.
        self._hit_c = {ns: self.metrics.counter("cache_hits",
                                                namespace=ns)
                       for ns in ("flat", "dag")}
        self._miss_c = {ns: self.metrics.counter("cache_misses",
                                                 namespace=ns)
                        for ns in ("flat", "dag")}
        self._store: OrderedDict[tuple, tuple[tuple[Signature, ...], ...]] \
            = OrderedDict()
        #: modelled time of the composition each pattern was stored
        #: from (same key space as ``_store``); the baseline the
        #: stale-replay drift check compares against.
        self._times: dict[tuple, float | None] = {}

    @property
    def hits(self) -> int:
        """Total lookup hits across both namespaces (legacy key)."""
        return int(self._hit_c["flat"].value + self._hit_c["dag"].value)

    @property
    def misses(self) -> int:
        """Total lookup misses across both namespaces (legacy key)."""
        return int(self._miss_c["flat"].value
                   + self._miss_c["dag"].value)

    def hit_breakdown(self) -> dict:
        """Per-namespace hit/miss counts (the satellite breakdown
        surfaced in :meth:`stats` under ``"by_namespace"``)."""
        return {ns: {"hits": int(self._hit_c[ns].value),
                     "misses": int(self._miss_c[ns].value)}
                for ns in ("flat", "dag")}

    def reset(self, *, store: bool = True) -> None:
        """Zero every counter; with ``store=True`` (default) also drop
        the cached patterns and their stored times.  Only the cache's
        own series (``cache_*``) are zeroed, so an engine-shared
        registry keeps its phase timers; the registry keeps its
        registered series (references held by the composer and
        live-composition layers stay valid)."""
        self.metrics.reset(prefix="cache_")
        if store:
            self._store.clear()
            self._times.clear()

    def signature(self, kind: str, length: int) -> Signature:
        if kind == "decode":
            return ("d", length // self.kv_bucket)
        return ("p", length)

    @staticmethod
    def key_of(sigs: list[Signature]) -> tuple:
        return tuple(sorted(sigs))

    def lookup(self, key: tuple, namespace: str | None = None):
        """Pattern stored under ``key``, bumping hit/miss counters.

        ``namespace`` asserts the key belongs to the path consulting
        it (``"flat"`` or ``"dag"``): a traced step consulting a
        flat-signature key — the PR 3 bypass wart — is a programming
        error, caught here instead of silently replaying a pattern
        from the wrong key space."""
        assert key[0] in ("flat", "dag"), f"un-namespaced cache key {key!r}"
        if namespace is not None:
            assert key[0] == namespace, \
                f"{namespace} path consulted a {key[0]!r} key"
        pat = self._store.get(key)
        if pat is None:
            self._miss_c[key[0]].inc()
            return None
        self._store.move_to_end(key)
        self._hit_c[key[0]].inc()
        return pat

    def store(self, key: tuple,
              pattern: tuple[tuple[Signature, ...], ...],
              t_model: float | None = None) -> None:
        assert key[0] in ("flat", "dag"), f"un-namespaced cache key {key!r}"
        self._store[key] = pattern
        self._times[key] = t_model
        # Assigning to an existing key does NOT reorder an OrderedDict:
        # without this, a refreshed entry keeps its stale position and
        # is evicted as if it were never re-stored.
        self._store.move_to_end(key)
        if len(self._store) > self.max_entries:
            old, _ = self._store.popitem(last=False)
            self._times.pop(old, None)

    def time_of(self, key: tuple) -> float | None:
        """Modelled time recorded when ``key``'s pattern was stored
        (None for patterns stored without one)."""
        return self._times.get(key)

    def near_miss(self, key: tuple):
        """Cached **flat** entry whose signature multiset differs from
        ``key`` by exactly one occurrence — one request joined or one
        left the mix since the cached step.

        ``key`` must have the engine's shape ``("flat", kind, sigs)``
        with ``sigs`` the sorted signature tuple from :meth:`key_of`.
        Returns ``(pattern, added, removed)`` — ``added`` the
        signatures present now but not in the cached mix (the joined
        request), ``removed`` the cached-only ones (the departed
        request) — or ``None``.  Most recently used entries are
        preferred.  Only the ``"flat"`` namespace is scanned: chain
        patterns adapt through the live frontier
        (:class:`repro.serve.live.LiveComposition`), not here.  Does
        not bump hit counters: callers count ``warm_hits`` only when
        the adaptation is actually used.
        """
        ns, kind, sigs = key
        assert ns == "flat", f"near_miss on a {ns!r} key"
        want = Counter(sigs)
        n = len(sigs)
        for k2 in reversed(self._store):
            if (k2[0] != "flat" or k2[1] != kind or k2 == key
                    or abs(len(k2[2]) - n) != 1):
                continue
            have = Counter(k2[2])
            added = list((want - have).elements())
            removed = list((have - want).elements())
            if len(added) + len(removed) == 1:
                return self._store[k2], added, removed
        return None

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def record_warm_regret(self, regret: float) -> None:
        self.warm_sampled += 1
        self.warm_regret_total += regret

    @property
    def warm_regret_mean(self) -> float:
        return (self.warm_regret_total / self.warm_sampled
                if self.warm_sampled else 0.0)

    def stats(self) -> dict:
        """Legacy-keyed counter snapshot (every pre-PR 8 key is
        preserved verbatim) plus the per-namespace ``by_namespace``
        hit/miss breakdown and (PR 9) the per-namespace EWMA replay
        drift — how wrong replayed/maintained compositions currently
        are, fed by the composer's re-validation path and the live
        frontier's ratio backstop.  All values are served by the
        :class:`repro.obs.MetricsRegistry` behind :attr:`metrics`."""
        self.metrics.gauge("cache_entries").set(len(self._store))
        return {"hits": self.hits, "misses": self.misses,
                "warm_hits": self.warm_hits,
                "dag_hits": self.dag_hits,
                "replay_revalidations": self.replay_revalidations,
                "warm_sampled": self.warm_sampled,
                "warm_regret_mean": self.warm_regret_mean,
                "incremental_joins": self.incremental_joins,
                "incremental_leaves": self.incremental_leaves,
                "frontier_rebuilds": self.frontier_rebuilds,
                "gated_sims_saved": self.gated_sims_saved,
                "hit_rate": self.hit_rate, "entries": len(self._store),
                "by_namespace": self.hit_breakdown(),
                "drift_ewma": {
                    ns: self.metrics.gauge("replay_drift_ewma",
                                           namespace=ns).value
                    for ns in ("flat", "dag", "live")}}
