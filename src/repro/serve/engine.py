"""Serving engine with symbiotic round scheduling (the paper's
technique as a first-class serving feature).

Every unit of pending work is characterised as a roofline work item:

* a **prefill chunk** (compute-bound: ~2·N FLOPs/token at intensity
  ~seq_len),
* a **decode step** (memory-bound: streams weights + KV/state at
  intensity ~batch),

and the *unmodified Algorithm 1* composes execution rounds that mix
compute-bound with memory-bound work near the hardware balance point
``R_B`` — the 2015 reordering insight independently rediscovering
chunked-prefill scheduling.

The engine actually executes (greedy decoding, CPU-sized models) in the
scheduled order, and reports per-round roofline times from the event
simulator so the ordering gain is measurable (see
``benchmarks/serving.py``).

Since PR 7 this module holds only the step loop and exact execution;
its composition pipeline lives in :mod:`repro.serve.composer`
(:class:`~repro.serve.composer.Composer`), the cache in
:mod:`repro.serve.cache`, and the cross-step incremental frontier in
:mod:`repro.serve.live` (:class:`~repro.serve.live.LiveComposition`).
The historical import surface — ``ScheduleCache``, ``Signature``, the
``ServingEngine._compose*`` helpers — is preserved here.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tpu import (TpuWorkItem, decode_profile,
                            make_serving_device, prefill_profile,
                            round_time)
from repro.graph.kernel_graph import trace_arch
from repro.obs import LatencyTracker, MetricsRegistry, phase_breakdown
from repro.models import transformer as T
from repro.models.common import ModelConfig

from .cache import ScheduleCache, Signature
from .composer import Composer
from .live import LiveComposition

__all__ = ["Request", "ServingEngine", "SchedulerPolicy",
           "ScheduleCache", "Signature", "build_dag_triples"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int = 16
    # runtime state
    generated: list[int] = field(default_factory=list)
    cache: object = None
    pos: int = 0
    done: bool = False


@dataclass
class SchedulerPolicy:
    kind: str = "symbiotic"               # fifo | symbiotic | refined
    refine_budget: int = 200
    #: local-search move set for kind="refined" (see repro.core.refine)
    neighborhood: str = "auto"
    #: Schedule the per-layer dependency graph instead of flat
    #: per-request items: each live request expands into its traced
    #: chain of layer-stage work items (repro.graph.trace_arch) and the
    #: ready-set greedy (repro.graph.greedy_order_dag) composes rounds
    #: that interleave *different* requests' stages while chains stay
    #: ordered.  The ScheduleCache participates with coarsened keys:
    #: instead of per-item layer-stage signatures (which re-key every
    #: step as kv-lens drift), the key is the multiset of per-request
    #: *chain* signatures (kind, kv bucket, stage count), so
    #: decode-heavy steady state gets warm hits on this path too
    #: (``dag_hits`` in ``ScheduleCache.stats()``).
    respect_deps: bool = False
    #: Kernelet-style slicing (repro.slice) on the respect_deps path:
    #: when set, a stage the ready-set greedy cannot pack with any
    #: frontier peer (a solo round) is cut per this
    #: :class:`repro.slice.SlicePolicy` into co-schedulable slices
    #: with exact accounting — slice profiles sum to the parent and
    #: the stage weight stream is still charged once per round.
    #: Default off.  Slicing only reshapes modelled rounds; chain
    #: tails still trigger exact execution (moved to the slice join),
    #: so generated tokens are bit-identical with or without it.
    slice_policy: object | None = None
    #: Optional stage coarsening for deep configs on the respect_deps
    #: path (see trace_arch(max_stages=...)); None = one item per
    #: layer stage.
    dag_max_stages: int | None = None
    #: objective for kind="refined": "rounds" re-rounds every candidate
    #: under the TPU round cost model (weight stream charged once per
    #: round); "event" / "round" refine the flat launch order under the
    #: corresponding core simulator, delta-evaluated via the
    #: checkpointing :class:`repro.core.refine.DeltaEvaluator` — the
    #: suffix re-simulation path that makes event-model refinement
    #: affordable on the serving hot path.  On the respect_deps path
    #: "gated" refines under the gated DAG makespan itself
    #: (:class:`repro.graph.delta.GatedDeltaEvaluator`) — the currency
    #: that actually scores dependency-aware schedules.
    refine_model: str = "rounds"
    #: Guard currency for the respect_deps/slice_policy path: "rounds"
    #: compares compositions against dep-aware arrival order under the
    #: TPU round cost model (each round charged its distinct stages'
    #: weight streams).  That currency structurally punishes slice
    #: rounds — every round touching a slice pays the full stage
    #: stream, so slicing wins that the gated dispatcher realizes
    #: (slices co-executing with decode work) are guarded away.
    #: "gated" compares gated-event makespans of the compositions'
    #: flat launch orders (:class:`repro.graph.DagEventSimulator` over
    #: the expanded slice/join edges) — the same currency
    #: ``benchmarks/slicing.py`` scores, letting serving accept
    #: compositions whose slice rounds genuinely co-execute.  Since
    #: PR 7 the gated guard is delta-evaluated per step: candidates
    #: over the same kernel set resume from the first candidate's
    #: checkpoints instead of re-simulating from scratch
    #: (:class:`repro.serve.composer.GatedGuard`; saved full-sim
    #: equivalents in ``ScheduleCache.stats()["gated_sims_saved"]``).
    #: The stale-replay drift re-validation stays in the round
    #: currency either way (it compares a replay against its own
    #: stored time, not against fifo).
    dag_guard: str = "rounds"
    #: ScheduleCache: reuse round compositions across steps whose
    #: work-item mix is equivalent (decode kv-lens bucketized).
    cache: bool = True
    kv_bucket: int = 256
    #: On a cache near-miss (exactly one request joined or left the
    #: mix since a cached step), adapt the cached composition instead
    #: of recomputing greedy + guard + refine from scratch.
    warm_start: bool = True
    #: Stale-replay re-validation: a replayed cached pattern whose
    #: modelled time drifts more than this fraction from the time
    #: recorded when the pattern was stored — or whose rounds no
    #: longer fit device capacity on actual demands — is not replayed
    #: optimistically; the engine re-validates and recomposes cold
    #: (counted as ``replay_revalidations`` in
    #: ``ScheduleCache.stats()``).  <= 0 disables (legacy optimistic
    #: replay).  ``composition="incremental"`` reuses the same knob as
    #: its drift backstop: the live composition's modelled ratio
    #: against dep-aware arrival order may drift at most this fraction
    #: from the ratio at the last cold (re)build.
    replay_drift_tol: float = 0.05
    #: Warm-start quality tracking: audit this fraction of warm hits
    #: by also recomputing the cold greedy composition and recording
    #: the modelled regret (warm time vs cold time, round cost model)
    #: in ``ScheduleCache.stats()``.  Deterministic sampling (every
    #: ``1/frac``-th warm hit).  Off by default: each audited hit
    #: pays the full cold greedy the warm start exists to skip, so
    #: only measurement runs (``benchmarks/serving.py``) opt in.
    #: **Deprecated alias** (PR 9): the sampling and regret recording
    #: now live on the online auditor
    #: (:meth:`repro.obs.audit.QualityAuditor.warm_audit`); the
    #: ``warm_regret_mean`` / ``warm_sampled`` stats keys are
    #: unchanged.  Prefer the ``audit_*`` knobs for new code.
    warm_audit_frac: float = 0.0
    #: Online quality audit (PR 9): deterministically sample this
    #: fraction of served steps and re-run the paper's Fig.-1
    #: protocol live — score the served composition against
    #: ``audit_k`` seeded random orders of the same kernel set under
    #: the step's own currency (gated makespan on traced steps, round
    #: cost model on flat steps).  Results land in the
    #: ``audit_quality_percentile{arch,kind}`` histogram; a verdict
    #: under ``audit_floor`` bumps ``audit_below_floor``.  Off by
    #: default; ``check_regression.py --audit-overhead`` caps the
    #: cost of ``audit_frac=0.05`` at 1.15x the audit-off run.
    audit_frac: float = 0.0
    #: random launch-order baselines per audited step (the paper's
    #: design-space sample; K=50 is the acceptance protocol).
    audit_k: int = 50
    #: live SLO floor on the served order's percentile rank (the
    #: paper claims "well above the 90 percentile mark").
    audit_floor: float = 90.0
    #: base seed for the audit baselines (each audited step derives a
    #: distinct deterministic seed from it).
    audit_seed: int = 0
    #: Move-evaluation backend for the refinement passes: "host" is
    #: the sequential delta evaluator; "batched" scores the move
    #: neighborhood in vectorized ``(B, n)`` passes
    #: (:func:`repro.core.batched.refine_order_batched`) with exact
    #: re-verification before any acceptance — same budget accounting,
    #: same result currency, ~3x+ effective-move throughput at
    #: serving-scale n (see ``BENCH_scheduler_scaling.json``).
    refine_backend: str = "host"
    #: Candidate batch per vectorized pass when
    #: ``refine_backend="batched"``.
    refine_batch: int = 128
    #: How the respect_deps path composes across steps (PR 7):
    #: "batch" recomposes every step from scratch (optionally through
    #: the ScheduleCache); "incremental" keeps the ready-set greedy's
    #: round-frontier state live across steps
    #: (:class:`repro.serve.live.LiveComposition`) — joining requests'
    #: chains are placed by Algorithm 1's own scoring into the
    #: existing composition, leaving requests' stages are retired in
    #: place, and everything else refreshes without moving.  Counters
    #: in ``ScheduleCache.stats()``: ``incremental_joins``,
    #: ``incremental_leaves``, ``frontier_rebuilds``.  Tokens are
    #: bit-identical either way (execution is exact per request); only
    #: per-step compose cost and modelled round times differ.  No
    #: effect on the flat (``respect_deps=False``) path.
    composition: str = "batch"


def build_dag_triples(cfg: ModelConfig, reqs: list[Request], *,
                      n_params: float, kv_bytes_per_token: float,
                      max_stages: int | None = None):
    """Trace live requests into per-layer work items.

    Every request expands into its traced chain of layer-stage items
    (:func:`repro.graph.trace_arch`).  Only the *tail* item of a chain
    carries its executable kind ``"prefill"``/``"decode"`` — the
    engine executes a request's forward pass exactly, as one unit —
    while interior stages carry kind ``"frag"`` and exist for round
    composition and modelled time only.  Returns ``(triples,
    traced)``; module-level so benchmark drivers can compose traced
    steps without instantiating an engine
    (``benchmarks/serving.py``'s churn workload).
    """
    spec = []
    for r in reqs:
        if r.cache is None:
            spec.append(("prefill", int(len(r.prompt))))
        else:
            spec.append(("decode", r.pos))
    traced = trace_arch(cfg, spec, n_params=n_params,
                        kv_bytes_per_token=kv_bytes_per_token,
                        max_stages=max_stages)
    triples = []
    for i, it in enumerate(traced.items):
        owner = traced.owners[i]
        r = reqs[owner]
        if i == traced.tail_of[owner]:
            kind = "prefill" if r.cache is None else "decode"
        else:
            kind = "frag"
        triples.append((it, r, kind))
    return triples, traced


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 256,
                 n_params: float | None = None,
                 policy: SchedulerPolicy | None = None,
                 device=None, metrics: MetricsRegistry | None = None,
                 trace=None, recorder=None, schedule_cache=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.policy = policy or SchedulerPolicy()
        self.n_params = n_params or float(T.count_params(params))
        self.device = device or make_serving_device()
        self.weights_bytes = 2.0 * self.n_params  # bf16 weight stream
        self.queue: list[Request] = []
        self._decode_jit = jax.jit(
            lambda p, t, c, s: T.decode_step(p, cfg, t, c, s))
        self._round_times: list[float] = []
        #: the unified registry (PR 8): cache counters, composer
        #: guard/refine timers and the engine's own phase timers all
        #: land here; ``run()`` re-exports its snapshot.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        #: optional :class:`repro.obs.ScheduleTrace` — when set,
        #: ``step()`` records one span per executed round member on
        #: the engine's modelled-round timeline (round boundaries as
        #: instants).  Purely read-only over already-computed round
        #: times, so modelled times and generated tokens are
        #: bit-identical with and without it.
        self.trace = trace
        self._trace_t = 0.0
        #: optional :class:`repro.obs.FlightRecorder` (PR 9): the
        #: composer, live frontier and auditor emit schedule
        #: decisions, cache outcomes, rebuild reasons and audit
        #: verdicts as JSONL events.  Same null-path contract as
        #: ``trace``: tokens and modelled times are bit-identical
        #: with and without it.
        self.recorder = recorder
        #: PR 10: a pre-built :class:`ScheduleCache` may be injected so
        #: several engine replicas behind ``repro.serve.frontend`` share
        #: one pattern store (cache-aware routing then pays off across
        #: replicas).  An injected cache keeps its *own* metrics
        #: registry — its counters and the composer's guard/refine
        #: timers land there, not in this engine's registry.
        self.schedule_cache = (
            schedule_cache if schedule_cache is not None else
            ScheduleCache(kv_bucket=self.policy.kv_bucket,
                          metrics=self.metrics))
        self.composer = Composer(self.policy, self.device,
                                 self.weights_bytes,
                                 self.schedule_cache,
                                 recorder=recorder)
        self.live = (LiveComposition(self.composer)
                     if self.policy.composition == "incremental"
                     else None)
        #: per-request arrival→completion latency spans (PR 9); fed
        #: by ``submit()`` / ``step()``, exported as
        #: ``run()``-stats ``"latency"`` (p50/p95/p99 + goodput).
        self.latency = LatencyTracker(self.metrics)
        self._completed_rids: set[int] = set()

    # -- workload characterisation -------------------------------------
    def _kv_bytes_per_token(self) -> float:
        cfg = self.cfg
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.layer_kind(i) == "attn")
        if cfg.attn_type == "mla":
            per = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        else:
            per = 2 * cfg.n_kv_heads * cfg.head_dim
        return float(n_attn * per * 2)  # bf16

    def _work_items(self) -> list[tuple[TpuWorkItem, Request, str]]:
        items = []
        kvb = self._kv_bytes_per_token()
        for r in self.queue:
            if r.done:
                continue
            if r.cache is None:
                it = prefill_profile(f"prefill:{r.rid}",
                                     n_params=self.n_params,
                                     seq_len=int(len(r.prompt)),
                                     kv_bytes_per_token=kvb)
                items.append((it, r, "prefill"))
            else:
                it = decode_profile(f"decode:{r.rid}",
                                    n_params=self.n_params,
                                    kv_len=r.pos,
                                    kv_bytes_per_token=kvb)
                items.append((it, r, "decode"))
        return items

    def _work_items_dag(self):
        """Per-layer work items for the ``respect_deps`` path
        (see :func:`build_dag_triples`)."""
        reqs = [r for r in self.queue if not r.done]
        return build_dag_triples(
            self.cfg, reqs, n_params=self.n_params,
            kv_bytes_per_token=self._kv_bytes_per_token(),
            max_stages=self.policy.dag_max_stages)

    # -- composition (delegated; historical private surface) -----------
    def _compose(self, items) -> list[list]:
        return self.composer.compose(items)

    def _compose_dag(self, triples, traced) -> list[list]:
        return self.composer.compose_dag(triples, traced)

    def _dag_gated_time(self, rounds, traced) -> float:
        return self.composer.dag_gated_time(rounds, traced)

    def _dag_key_and_labels(self, triples, traced):
        return self.composer.dag_key_and_labels(triples, traced)

    def _dag_round_time(self, rd) -> float:
        return self.composer.dag_round_time(rd)

    # -- execution -------------------------------------------------------
    def submit(self, reqs: list[Request]) -> None:
        self.queue.extend(reqs)
        for r in reqs:
            self.latency.arrive(r.rid)

    def _exec_prefill(self, r: Request) -> None:
        toks = jnp.asarray(r.prompt, jnp.int32)[None, :]
        cache = T.init_cache(self.cfg, 1, self.max_len)
        # replay prompt through decode steps (correctness-first prefill)
        for s in range(toks.shape[1]):
            logits, cache = self._decode_jit(self.params, toks[:, s],
                                             cache, s)
        r.cache = cache
        r.pos = int(toks.shape[1])
        r.generated.append(int(jnp.argmax(logits[0])))

    def _exec_decode(self, r: Request) -> None:
        tok = jnp.asarray([r.generated[-1]], jnp.int32)
        logits, r.cache = self._decode_jit(self.params, tok, r.cache, r.pos)
        r.pos += 1
        r.generated.append(int(jnp.argmax(logits[0])))
        if (len(r.generated) >= r.max_new_tokens or
                r.pos >= self.max_len - 1):
            r.done = True

    def step(self) -> int:
        """One scheduling iteration: compose rounds from the current
        queue and execute them.  Returns the number of rounds run.

        On the ``respect_deps`` path a round may contain interior
        chain stages (kind ``"frag"``): they contribute to the round's
        modelled time but trigger no execution — the request's exact
        forward pass runs once, at its chain's tail item.  With
        ``composition="incremental"`` the traced step composes through
        the live frontier instead of the batch pipeline.

        Observability (PR 8-9): the whole composition pipeline is
        timed under the ``phase_compose`` histogram and the execution
        loop under ``phase_execute`` (the composer's own
        ``phase_guard`` / ``phase_refine`` are sub-intervals of
        compose); sampled steps run the online quality audit under
        ``phase_audit`` (outside compose, so audit cost never skews
        the compose-time series); with :attr:`trace` set, each
        executed round is recorded on the modelled-round timeline;
        the step's measured phase wall times are attributed to the
        requests it served (:class:`repro.obs.LatencyTracker`)."""
        self.metrics.counter("engine_steps").inc()
        phase0 = {ph: self.metrics.histogram(f"phase_{ph}").total
                  for ph in ("compose", "guard", "refine", "execute")}
        traced = None
        with self.metrics.timer("phase_compose"):
            if self.policy.respect_deps:
                triples, traced = self._work_items_dag()
                if not triples:
                    return 0
                if self.live is not None:
                    rounds = self.live.compose_dag(triples, traced)
                else:
                    rounds = self._compose_dag(triples, traced)
                time_of = self._dag_round_time
            else:
                items = self._work_items()
                if not items:
                    return 0
                rounds = self._compose(items)
                time_of = lambda rd: round_time(  # noqa: E731
                    [t[0] for t in rd], self.device, self.weights_bytes)
        # Online quality audit (PR 9): read-only over the composed
        # rounds, on deterministically sampled steps only.
        aud = self.composer.auditor
        if aud.sample_step():
            with self.metrics.timer("phase_audit"):
                if traced is not None:
                    aud.audit_dag(rounds, traced, arch=self.cfg.name,
                                  kind=self.policy.kind)
                else:
                    aud.audit_flat(rounds,
                                   weights_bytes=self.weights_bytes,
                                   arch=self.cfg.name,
                                   kind=self.policy.kind)
        n = 0
        with self.metrics.timer("phase_execute"):
            for rd in rounds:
                rt = time_of(rd)
                self._round_times.append(rt)
                if self.trace is not None:
                    t0 = self._trace_t
                    for it, r, kind in rd:
                        self.trace.span(0, it.name, t0, t0 + rt,
                                        cat=kind)
                    self.trace.instant(
                        f"round {len(self._round_times) - 1}",
                        t0 + rt, unit=0, cat="round")
                    self.trace.add_busy(0, rt)
                self._trace_t += rt
                for it, r, kind in rd:
                    if kind == "prefill":
                        self._exec_prefill(r)
                    elif kind == "decode":
                        self._exec_decode(r)
                n += 1
        # Latency accounting: split this step's measured phase wall
        # times across the requests it served ("compose" net of its
        # guard/refine sub-intervals, so the four shares partition the
        # step), then close spans for requests that just finished.
        delta = {ph: self.metrics.histogram(f"phase_{ph}").total - t0
                 for ph, t0 in phase0.items()}
        delta["compose"] = max(
            0.0, delta["compose"] - delta["guard"] - delta["refine"])
        served = {r.rid: r for rd in rounds for _, r, _ in rd}
        self.latency.attribute(served.keys(), delta)
        for rid, r in served.items():
            if r.done and rid not in self._completed_rids:
                self._completed_rids.add(rid)
                self.latency.complete(rid, tokens=len(r.generated))
        return n

    def run(self, max_iters: int = 10_000,
            arrivals: list[tuple[int, list[Request]]] | None = None) -> dict:
        """Run to completion; returns stats incl. modelled round times.

        ``arrivals``: optional [(iteration, requests)] injections — a
        continuous-arrival workload where prefill and decode work
        genuinely coexist in the queue.

        The returned stats carry (PR 9) a ``"latency"`` block —
        per-request arrival→completion p50/p95/p99, queue quantiles,
        mean per-phase attribution and goodput over the run's wall
        time (:meth:`repro.obs.LatencyTracker.stats`)."""
        import time as _time

        t_wall0 = _time.perf_counter()
        arrivals = list(arrivals or [])
        n_rounds = 0
        iters = 0
        while iters < max_iters:
            for when, reqs in list(arrivals):
                if when <= iters:
                    self.submit(reqs)
                    arrivals.remove((when, reqs))
            ran = self.step()
            if ran == 0 and not arrivals:
                break
            n_rounds += ran
            iters += 1
        total_tokens = sum(len(r.generated) for r in self.queue)
        return {
            "rounds": n_rounds,
            "total_new_tokens": total_tokens,
            "modelled_time_s": float(sum(self._round_times)),
            "modelled_tokens_per_s": total_tokens /
            max(sum(self._round_times), 1e-12),
            "schedule_cache": self.schedule_cache.stats(),
            "metrics": self.metrics.snapshot(),
            "phases": phase_breakdown(self.metrics),
            "latency": self.latency.stats(
                _time.perf_counter() - t_wall0),
            "outputs": {r.rid: list(r.generated) for r in self.queue},
        }
