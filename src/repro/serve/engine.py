"""Serving engine with symbiotic round scheduling (the paper's
technique as a first-class serving feature).

Every unit of pending work is characterised as a roofline work item:

* a **prefill chunk** (compute-bound: ~2·N FLOPs/token at intensity
  ~seq_len),
* a **decode step** (memory-bound: streams weights + KV/state at
  intensity ~batch),

and the *unmodified Algorithm 1* composes execution rounds that mix
compute-bound with memory-bound work near the hardware balance point
``R_B`` — the 2015 reordering insight independently rediscovering
chunked-prefill scheduling.

The engine actually executes (greedy decoding, CPU-sized models) in the
scheduled order, and reports per-round roofline times from the event
simulator so the ordering gain is measurable (see
``benchmarks/serving.py``).
"""

from __future__ import annotations

from collections import Counter, OrderedDict, deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Schedule
from repro.core.fastscore import greedy_order_fast, warm_start_insert
from repro.core.refine import refine_order
from repro.core.tpu import (TpuWorkItem, decode_profile, fifo_rounds,
                            make_serving_device, prefill_profile,
                            round_time)
from repro.graph.constrained import greedy_order_dag, refine_order_dag
from repro.graph.delta import _FastGatedSim
from repro.graph.kernel_graph import trace_arch
from repro.graph.streams import fifo_rounds_dag
from repro.slice import KernelSlicer, greedy_order_slices, join_item
from repro.models import transformer as T
from repro.models.common import ModelConfig

__all__ = ["Request", "ServingEngine", "SchedulerPolicy", "ScheduleCache"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int = 16
    # runtime state
    generated: list[int] = field(default_factory=list)
    cache: object = None
    pos: int = 0
    done: bool = False


@dataclass
class SchedulerPolicy:
    kind: str = "symbiotic"               # fifo | symbiotic | refined
    refine_budget: int = 200
    #: local-search move set for kind="refined" (see repro.core.refine)
    neighborhood: str = "auto"
    #: Schedule the per-layer dependency graph instead of flat
    #: per-request items: each live request expands into its traced
    #: chain of layer-stage work items (repro.graph.trace_arch) and the
    #: ready-set greedy (repro.graph.greedy_order_dag) composes rounds
    #: that interleave *different* requests' stages while chains stay
    #: ordered.  The ScheduleCache participates with coarsened keys:
    #: instead of per-item layer-stage signatures (which re-key every
    #: step as kv-lens drift), the key is the multiset of per-request
    #: *chain* signatures (kind, kv bucket, stage count), so
    #: decode-heavy steady state gets warm hits on this path too
    #: (``dag_hits`` in ``ScheduleCache.stats()``).
    respect_deps: bool = False
    #: Kernelet-style slicing (repro.slice) on the respect_deps path:
    #: when set, a stage the ready-set greedy cannot pack with any
    #: frontier peer (a solo round) is cut per this
    #: :class:`repro.slice.SlicePolicy` into co-schedulable slices
    #: with exact accounting — slice profiles sum to the parent and
    #: the stage weight stream is still charged once per round.
    #: Default off.  Slicing only reshapes modelled rounds; chain
    #: tails still trigger exact execution (moved to the slice join),
    #: so generated tokens are bit-identical with or without it.
    slice_policy: object | None = None
    #: Optional stage coarsening for deep configs on the respect_deps
    #: path (see trace_arch(max_stages=...)); None = one item per
    #: layer stage.
    dag_max_stages: int | None = None
    #: objective for kind="refined": "rounds" re-rounds every candidate
    #: under the TPU round cost model (weight stream charged once per
    #: round); "event" / "round" refine the flat launch order under the
    #: corresponding core simulator, delta-evaluated via the
    #: checkpointing :class:`repro.core.refine.DeltaEvaluator` — the
    #: suffix re-simulation path that makes event-model refinement
    #: affordable on the serving hot path.  On the respect_deps path
    #: "gated" refines under the gated DAG makespan itself
    #: (:class:`repro.graph.delta.GatedDeltaEvaluator`) — the currency
    #: that actually scores dependency-aware schedules.
    refine_model: str = "rounds"
    #: Guard currency for the respect_deps/slice_policy path: "rounds"
    #: compares compositions against dep-aware arrival order under the
    #: TPU round cost model (each round charged its distinct stages'
    #: weight streams).  That currency structurally punishes slice
    #: rounds — every round touching a slice pays the full stage
    #: stream, so slicing wins that the gated dispatcher realizes
    #: (slices co-executing with decode work) are guarded away.
    #: "gated" compares gated-event makespans of the compositions'
    #: flat launch orders (:class:`repro.graph.DagEventSimulator` over
    #: the expanded slice/join edges) — the same currency
    #: ``benchmarks/slicing.py`` scores, letting serving accept
    #: compositions whose slice rounds genuinely co-execute.  The
    #: stale-replay drift re-validation stays in the round currency
    #: either way (it compares a replay against its own stored time,
    #: not against fifo).
    dag_guard: str = "rounds"
    #: ScheduleCache: reuse round compositions across steps whose
    #: work-item mix is equivalent (decode kv-lens bucketized).
    cache: bool = True
    kv_bucket: int = 256
    #: On a cache near-miss (exactly one request joined or left the
    #: mix since a cached step), adapt the cached composition instead
    #: of recomputing greedy + guard + refine from scratch.
    warm_start: bool = True
    #: Stale-replay re-validation: a replayed cached pattern whose
    #: modelled time drifts more than this fraction from the time
    #: recorded when the pattern was stored — or whose rounds no
    #: longer fit device capacity on actual demands — is not replayed
    #: optimistically; the engine re-validates and recomposes cold
    #: (counted as ``replay_revalidations`` in
    #: ``ScheduleCache.stats()``).  <= 0 disables (legacy optimistic
    #: replay).
    replay_drift_tol: float = 0.05
    #: Warm-start quality tracking: audit this fraction of warm hits
    #: by also recomputing the cold greedy composition and recording
    #: the modelled regret (warm time vs cold time, round cost model)
    #: in ``ScheduleCache.stats()``.  Deterministic sampling (every
    #: ``1/frac``-th warm hit).  Off by default: each audited hit
    #: pays the full cold greedy the warm start exists to skip, so
    #: only measurement runs (``benchmarks/serving.py``) opt in.
    warm_audit_frac: float = 0.0
    #: Move-evaluation backend for the refinement passes: "host" is
    #: the sequential delta evaluator; "batched" scores the move
    #: neighborhood in vectorized ``(B, n)`` passes
    #: (:func:`repro.core.batched.refine_order_batched`) with exact
    #: re-verification before any acceptance — same budget accounting,
    #: same result currency, ~3x+ effective-move throughput at
    #: serving-scale n (see ``BENCH_scheduler_scaling.json``).
    refine_backend: str = "host"
    #: Candidate batch per vectorized pass when
    #: ``refine_backend="batched"``.
    refine_batch: int = 128


#: Work-item signature: what makes two items schedule-equivalent.
#: Prefill chunks are keyed by exact token count (compiled geometry);
#: decode steps by their kv-len bucket — within a bucket the demand
#: vectors are close enough that the greedy + guard + refine pipeline
#: composes the same round structure.
Signature = tuple[str, int]


class ScheduleCache:
    """Memoised round compositions keyed on the multiset of work-item
    signatures.

    Steady-state decode-heavy serving repeats near-identical
    compositions every ``step()``: the same live requests, each one
    kv-token longer.  Quantizing decode kv-lens into buckets makes
    consecutive steps hash to the same key, so the engine replays the
    cached round *pattern* (a partition of signatures) instead of
    re-running greedy + guard + refine.  Patterns are applied by
    matching signatures, never by request identity, so any same-mix
    step can reuse them; generated tokens are unaffected because
    execution is exact per request regardless of round membership.
    """

    def __init__(self, kv_bucket: int = 256, max_entries: int = 256):
        self.kv_bucket = kv_bucket
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        #: near-miss adaptations that seeded a composition (see
        #: :meth:`near_miss`); every warm hit is also counted a miss,
        #: since :meth:`lookup` failed first.
        self.warm_hits = 0
        #: hits served on the respect_deps path (coarsened per-request
        #: chain-signature keys); a subset of ``hits``.
        self.dag_hits = 0
        #: replays rejected by the stale-replay re-validation (modelled
        #: drift above ``SchedulerPolicy.replay_drift_tol`` or a
        #: capacity violation on actual demands) and recomposed cold.
        self.replay_revalidations = 0
        #: warm-start quality audit (ROADMAP item): on a sampled
        #: fraction of warm hits the engine also recomputes the cold
        #: greedy composition and records the modelled regret
        #: ``t_warm / t_cold - 1`` (round cost model; negative means
        #: the adapted composition modelled *better* than cold).
        self.warm_sampled = 0
        self.warm_regret_total = 0.0
        self._store: OrderedDict[tuple, tuple[tuple[Signature, ...], ...]] \
            = OrderedDict()
        #: modelled time of the composition each pattern was stored
        #: from (same key space as ``_store``); the baseline the
        #: stale-replay drift check compares against.
        self._times: dict[tuple, float | None] = {}

    def signature(self, kind: str, length: int) -> Signature:
        if kind == "decode":
            return ("d", length // self.kv_bucket)
        return ("p", length)

    @staticmethod
    def key_of(sigs: list[Signature]) -> tuple:
        return tuple(sorted(sigs))

    def lookup(self, key: tuple):
        pat = self._store.get(key)
        if pat is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return pat

    def store(self, key: tuple,
              pattern: tuple[tuple[Signature, ...], ...],
              t_model: float | None = None) -> None:
        self._store[key] = pattern
        self._times[key] = t_model
        # Assigning to an existing key does NOT reorder an OrderedDict:
        # without this, a refreshed entry keeps its stale position and
        # is evicted as if it were never re-stored.
        self._store.move_to_end(key)
        if len(self._store) > self.max_entries:
            old, _ = self._store.popitem(last=False)
            self._times.pop(old, None)

    def time_of(self, key: tuple) -> float | None:
        """Modelled time recorded when ``key``'s pattern was stored
        (None for patterns stored without one)."""
        return self._times.get(key)

    def near_miss(self, key: tuple):
        """Cached entry whose signature multiset differs from ``key``
        by exactly one occurrence — one request joined or one left the
        mix since the cached step.

        ``key`` must have the engine's shape ``(kind, sigs)`` with
        ``sigs`` the sorted signature tuple from :meth:`key_of`.
        Returns ``(pattern, added, removed)`` — ``added`` the
        signatures present now but not in the cached mix (the joined
        request), ``removed`` the cached-only ones (the departed
        request) — or ``None``.  Most recently used entries are
        preferred.  Does not bump hit counters: callers count
        ``warm_hits`` only when the adaptation is actually used.
        """
        kind, sigs = key
        want = Counter(sigs)
        n = len(sigs)
        for k2 in reversed(self._store):
            if k2[0] != kind or k2 == key or abs(len(k2[1]) - n) != 1:
                continue
            have = Counter(k2[1])
            added = list((want - have).elements())
            removed = list((have - want).elements())
            if len(added) + len(removed) == 1:
                return self._store[k2], added, removed
        return None

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def record_warm_regret(self, regret: float) -> None:
        self.warm_sampled += 1
        self.warm_regret_total += regret

    @property
    def warm_regret_mean(self) -> float:
        return (self.warm_regret_total / self.warm_sampled
                if self.warm_sampled else 0.0)

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "warm_hits": self.warm_hits,
                "dag_hits": self.dag_hits,
                "replay_revalidations": self.replay_revalidations,
                "warm_sampled": self.warm_sampled,
                "warm_regret_mean": self.warm_regret_mean,
                "hit_rate": self.hit_rate, "entries": len(self._store)}


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 256,
                 n_params: float | None = None,
                 policy: SchedulerPolicy | None = None,
                 device=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.policy = policy or SchedulerPolicy()
        self.n_params = n_params or float(T.count_params(params))
        self.device = device or make_serving_device()
        self.weights_bytes = 2.0 * self.n_params  # bf16 weight stream
        self.queue: list[Request] = []
        self._decode_jit = jax.jit(
            lambda p, t, c, s: T.decode_step(p, cfg, t, c, s))
        self._round_times: list[float] = []
        self.schedule_cache = ScheduleCache(
            kv_bucket=self.policy.kv_bucket)

    # -- workload characterisation -------------------------------------
    def _kv_bytes_per_token(self) -> float:
        cfg = self.cfg
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.layer_kind(i) == "attn")
        if cfg.attn_type == "mla":
            per = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        else:
            per = 2 * cfg.n_kv_heads * cfg.head_dim
        return float(n_attn * per * 2)  # bf16

    def _work_items(self) -> list[tuple[TpuWorkItem, Request, str]]:
        items = []
        kvb = self._kv_bytes_per_token()
        for r in self.queue:
            if r.done:
                continue
            if r.cache is None:
                it = prefill_profile(f"prefill:{r.rid}",
                                     n_params=self.n_params,
                                     seq_len=int(len(r.prompt)),
                                     kv_bytes_per_token=kvb)
                items.append((it, r, "prefill"))
            else:
                it = decode_profile(f"decode:{r.rid}",
                                    n_params=self.n_params,
                                    kv_len=r.pos,
                                    kv_bytes_per_token=kvb)
                items.append((it, r, "decode"))
        return items

    def _work_items_dag(self):
        """Per-layer work items for the ``respect_deps`` path.

        Every live request expands into its traced chain of layer-stage
        items (:func:`repro.graph.trace_arch` over this engine's model
        config and cost model).  Only the *tail* item of a chain
        triggers real execution — kind ``"prefill"``/``"decode"`` —
        because the engine executes a request's forward pass exactly,
        as one unit; interior stages carry kind ``"frag"`` and exist
        for round composition and modelled time only.  Returns
        ``(triples, traced)``.
        """
        reqs = [r for r in self.queue if not r.done]
        spec = []
        for r in reqs:
            if r.cache is None:
                spec.append(("prefill", int(len(r.prompt))))
            else:
                spec.append(("decode", r.pos))
        traced = trace_arch(self.cfg, spec, n_params=self.n_params,
                            kv_bytes_per_token=self._kv_bytes_per_token(),
                            max_stages=self.policy.dag_max_stages)
        triples = []
        for i, it in enumerate(traced.items):
            owner = traced.owners[i]
            r = reqs[owner]
            if i == traced.tail_of[owner]:
                kind = "prefill" if r.cache is None else "decode"
            else:
                kind = "frag"
            triples.append((it, r, kind))
        return triples, traced

    @staticmethod
    def _dag_stage_key(name: str) -> str:
        """``r3:d:L0:attn`` -> ``L0:attn``: the layer stage, dropping
        the owning request — co-scheduled copies of one stage share
        its weight stream.  Slice metadata after ``#``
        (``r3:d:L0:attn#s1of4``, ``...#join``) is stripped too: slices
        of one stage share the *parent's* stream, so a round charges
        it once per distinct parent stage, never per slice."""
        return name.split(":", 2)[2].split("#", 1)[0]

    def _dag_round_time(self, rd) -> float:
        """Round time on the respect_deps path: the weight stream
        charged is the sum over the round's *distinct* layer stages of
        that stage's own parameter share (``TpuWorkItem.weight_bytes``,
        set by trace_arch; max across copies, so a prefill stage that
        touches the full expert bank dominates a routed decode copy).
        Charging the engine-wide ``weights_bytes`` here would bill the
        whole model once per stage round — many times per step."""
        shares: dict[str, float] = {}
        for it, _, _ in rd:
            key = self._dag_stage_key(it.name)
            shares[key] = max(shares.get(key, 0.0), it.weight_bytes)
        return round_time([t[0] for t in rd], self.device,
                          sum(shares.values()))

    def _compose_dag(self, triples, traced) -> list[list]:
        """Round composition over the per-layer dependency graph.

        The ready-set greedy (:func:`repro.graph.greedy_order_dag`)
        composes rounds that mix stages of *different* requests while
        every chain stays ordered across rounds; ``kind="refined"``
        additionally runs the precedence-respecting local search on
        the flat order.  With ``policy.slice_policy`` set the greedy
        is the slice-aware one
        (:func:`repro.slice.greedy_order_slices`): stages it cannot
        pack are cut into co-schedulable slices, with the chain tail's
        exact execution moved to the slice join.  The cost-model guard
        compares against the dependency-aware arrival-order packing
        (:func:`repro.graph.fifo_rounds_dag`) — plain ``fifo_rounds``
        could co-schedule a stage with its own predecessor — in the
        currency ``policy.dag_guard`` selects: the round cost model,
        or the gated-event makespan (which is what lets slice rounds
        win, see :meth:`_dag_gated_time`).

        The ScheduleCache participates with coarsened per-request
        *chain* signatures (kind, kv bucket, stage count) so that
        steady-state decode mixes replay cached DAG patterns
        (``dag_hits``); replayed patterns pass the same stale-replay
        re-validation as the flat path.
        """
        profs = traced.graph.kernels
        eids = traced.graph.edges_by_id()
        by_name = {p.name: trip for p, trip in zip(profs, triples)}
        dem = lambda k: k.demands  # noqa: E731 — profiles, not items

        def modelled(rounds):
            return sum(self._dag_round_time(rd) for rd in rounds)

        def guard_time(rounds):
            # Guard currency (policy.dag_guard): the round cost model,
            # or the gated-event makespan of the composition's flat
            # launch order — the latter sees slice rounds co-execute
            # instead of billing each one the full stage stream.
            if self.policy.dag_guard == "gated":
                return self._dag_gated_time(rounds, traced)
            return modelled(rounds)

        fifo = [[by_name[p.name] for p in rd]
                for rd in fifo_rounds_dag(profs, self.device, eids,
                                          demands_of=dem)]
        if self.policy.kind == "fifo":
            return fifo
        key = labels = None
        if self.policy.cache:
            key, labels = self._dag_key_and_labels(triples, traced)
            pattern = self.schedule_cache.lookup(key)
            if pattern is not None:
                replay = self._dag_apply_pattern(pattern, triples,
                                                 labels)
                if replay is not None and self._replay_ok(
                        key, replay, self._dag_round_time):
                    # Counted a hit only when the replay is actually
                    # served; rejected/failed replays recompose cold.
                    self.schedule_cache.dag_hits += 1
                    # The replay honours the same fifo guard as a cold
                    # composition, so the "never modelled-worse than
                    # dep-aware arrival order" invariant survives
                    # cache hits.
                    if guard_time(fifo) < guard_time(replay):
                        return fifo
                    return replay
        sp = self.policy.slice_policy
        if sp is None:
            sched = greedy_order_dag(profs, self.device,
                                     edges=traced.graph.edges)
            names, sl_eids = by_name, eids
        else:
            slicer = KernelSlicer(sp, self.device)
            extra: dict[str, tuple] = {}

            def mk_slices(prof, k):
                it, r, kind = by_name[prof.name]
                parts = slicer.slice_item(it, k)
                for part in parts:
                    extra[part.name] = (part, r, "frag")
                ji = join_item(it)
                # The chain tail's exact execution moves to the join:
                # it still runs exactly once, after every slice.
                extra[ji.name] = (ji, r, kind)
                return [part.profile() for part in parts]

            def mk_join(prof):
                return extra[prof.name.split("#", 1)[0] + "#join"][0] \
                    .profile()

            sl = greedy_order_slices(profs, self.device,
                                     edges=traced.graph.edges,
                                     policy=sp, make_slices=mk_slices,
                                     make_join=mk_join)
            sched = sl.schedule
            names = dict(by_name)
            names.update(extra)
            sl_eids = sl.edges_by_id()
        if self.policy.kind == "refined":
            model = (self.policy.refine_model
                     if self.policy.refine_model in ("round", "event",
                                                     "gated")
                     else "round")
            order, _, _ = refine_order_dag(
                sched.order, self.device, edge_ids=sl_eids, model=model,
                budget=self.policy.refine_budget,
                neighborhood=self.policy.neighborhood,
                batch_size=(self.policy.refine_batch
                            if self.policy.refine_backend == "batched"
                            else None))
            prof_rounds = fifo_rounds_dag(order, self.device, sl_eids,
                                          demands_of=dem)
        else:
            prof_rounds = [rd.kernels for rd in sched.rounds]
        composed = [[names[p.name] for p in rd] for rd in prof_rounds]
        # Same guard as the flat path: never accept a composition the
        # guard currency says is worse than (dep-aware) arrival order.
        result = fifo if guard_time(fifo) < guard_time(composed) \
            else composed
        if key is not None:
            self._dag_store(key, result, labels)
        return result

    def _dag_gated_time(self, rounds, traced) -> float:
        """Gated-event makespan of a composition's flat launch order
        (``policy.dag_guard == "gated"``).

        Rebuilds the dependency structure from item names so replayed
        compositions — whose slices were re-cut from cached patterns —
        are scored too: parent edges come from the traced graph, a
        sliced parent's in-edges fan out to its slices, its out-edges
        hang off the ``#join`` marker, and slices close the diamond on
        the join.  A flat order that is not topological (a corrupted
        replay) scores ``inf`` and is rejected by the guard."""
        profs, names = [], {}
        for rd in rounds:
            for trip in rd:
                p = trip[0].profile()
                profs.append(p)
                names[p.name] = p
        slices: dict[str, list] = {}
        for p in profs:
            parent, sep, sub = p.name.partition("#")
            if sep and sub.startswith("s"):
                slices.setdefault(parent, []).append(p)
        ks = traced.graph.kernels
        pairs: set[tuple[int, int]] = set()
        for u, v in traced.graph.edges:
            a, b = ks[u].name, ks[v].name
            srcs = ([names.get(a + "#join")] if a in slices
                    else [names.get(a)])
            dsts = slices[b] if b in slices else [names.get(b)]
            for s in srcs:
                for d in dsts:
                    if s is not None and d is not None:
                        pairs.add((id(s), id(d)))
        for parent, parts in slices.items():
            j = names.get(parent + "#join")
            if j is not None:
                for s in parts:
                    pairs.add((id(s), id(j)))
        try:
            # The flat-tuple twin of DagEventSimulator (bit-identical,
            # tests/test_gated_delta.py) — the guard runs twice per
            # compose step, so oracle speed matters here.
            return _FastGatedSim(self.device, pairs).simulate(profs)[0]
        except ValueError:
            return float("inf")

    # -- DAG-path ScheduleCache (coarsened chain signatures) -----------
    def _dag_key_and_labels(self, triples, traced):
        """Cache key + per-item labels for the respect_deps path.

        Fine-grained layer-stage signatures re-key every step (kv-lens
        drift through every attention stage), so the key coarsens to
        the multiset of per-request *chain* signatures: (kind-bucketed
        length via :meth:`ScheduleCache.signature`, chain stage
        count).  Items are labelled ``(chain_sig, rank, chain_pos)``
        — requests with equal signatures are interchangeable, ranked
        by arrival order — which is what lets a cached round pattern
        replay onto a signature-equivalent step.
        """
        cache = self.schedule_cache
        owners = traced.owners
        n_req = len(traced.tail_of)
        chain_len = [0] * n_req
        for o in owners:
            chain_len[o] += 1
        chain_sig = []
        for rid in range(n_req):
            it, r, kind = triples[traced.tail_of[rid]]
            length = r.pos if kind == "decode" else it.tokens
            chain_sig.append((cache.signature(kind, length),
                              chain_len[rid]))
        seen = Counter()
        rank = []
        for s in chain_sig:
            rank.append(seen[s])
            seen[s] += 1
        labels = {}
        pos_ctr = [0] * n_req
        for i, (it, _, _) in enumerate(triples):
            rid = owners[i]
            labels[it.name] = (chain_sig[rid], rank[rid], pos_ctr[rid])
            pos_ctr[rid] += 1
        key = ("dag", self.policy.kind,
               ScheduleCache.key_of(chain_sig))
        return key, labels

    def _dag_store(self, key, result, labels) -> None:
        """Store a DAG composition as a label pattern.  Sliced items
        record their slice tag alongside the parent stage's label so a
        replay can re-cut a signature-equivalent step identically."""
        def label_of(name):
            parent, _, sub = name.partition("#")
            return labels[parent] + (sub,)
        try:
            pattern = tuple(tuple(label_of(t[0].name) for t in rd)
                            for rd in result)
        except KeyError:           # defensive: unlabelled item
            return
        t_model = sum(self._dag_round_time(rd) for rd in result)
        self.schedule_cache.store(key, pattern, t_model)

    def _dag_apply_pattern(self, pattern, triples, labels):
        """Replay a cached DAG pattern onto the current step.

        Whole-stage labels map straight onto the current traced items;
        labels carrying slice tags re-cut the current stage with the
        cached slice count (exact accounting on *current* demands —
        the replayed modelled time is honest, which is what the drift
        re-validation inspects).  Any mismatch — a label the current
        step lacks, a slice count the stage can no longer support —
        returns None and the engine recomposes cold."""
        by_label = {}
        for trip in triples:
            by_label[labels[trip[0].name]] = trip
        # slice counts demanded per parent label
        need: dict[tuple, int] = {}
        for rd in pattern:
            for lab in rd:
                *parent, sub = lab
                if sub.startswith("s"):
                    try:
                        k = int(sub.split("of", 1)[1])
                    except (IndexError, ValueError):
                        return None
                    need[tuple(parent)] = k
                elif sub not in ("", "join"):
                    return None
        sp = self.policy.slice_policy
        expanded: dict[tuple, tuple] = {}
        if need:
            if sp is None:
                return None
            slicer = KernelSlicer(sp, self.device)
            for parent, k in need.items():
                trip = by_label.get(parent)
                if trip is None:
                    return None
                it, r, kind = trip
                parts = slicer.slice_item(it, k)
                if len(parts) != k:
                    return None  # stage can no longer support the cut
                for j, part in enumerate(parts):
                    expanded[parent + (f"s{j}of{k}",)] = (part, r, "frag")
                expanded[parent + ("join",)] = (join_item(it), r, kind)
        out = []
        used = set()
        for rd in pattern:
            row = []
            for lab in rd:
                if lab in used:
                    return None
                used.add(lab)
                *parent, sub = lab
                trip = (expanded.get(lab) if sub
                        else by_label.get(tuple(parent)))
                if trip is None:
                    return None
                row.append(trip)
            out.append(row)
        # every current item must be covered exactly once
        want = {labels[t[0].name] + ("",) for t in triples}
        got = {(lab if lab[-1] == "" else tuple(lab[:-1]) + ("",))
               for lab in used}
        if got != want:
            return None
        return out

    def _round_fits(self, rd) -> bool:
        """Capacity re-check of one replayed round on actual demands
        (solo rounds are always legal — oversized stages run alone)."""
        if len(rd) <= 1:
            return True
        used = {d: 0.0 for d in self.device.caps}
        for it, _, _ in rd:
            for d, v in it.profile().demands.items():
                if d in used:  # items may demand untracked dims
                    used[d] += v
        return all(used[d] <= self.device.cap(d) * (1 + 1e-9)
                   for d in used)

    def _replay_ok(self, key, rounds, time_of) -> bool:
        """Stale-replay re-validation (ROADMAP item): a replayed
        pattern whose modelled time drifts beyond
        ``policy.replay_drift_tol`` from the stored composition's — or
        that violates capacity on actual demands — is rejected and the
        step recomposes cold."""
        tol = self.policy.replay_drift_tol
        if tol is None or tol <= 0:
            return True            # legacy optimistic replay
        cache = self.schedule_cache
        t0 = cache.time_of(key)
        t_now = sum(time_of(rd) for rd in rounds)
        drifted = (t0 is not None and t0 > 0 and
                   abs(t_now / t0 - 1.0) > tol)
        if drifted or not all(self._round_fits(rd) for rd in rounds):
            cache.replay_revalidations += 1
            return False
        return True

    def _compose(self, items) -> list[list]:
        """Group pending work items into execution rounds per policy.

        Returns a list of rounds; each round is a list of
        (TpuWorkItem, Request, kind) triples."""
        by_name = {it.name: trip for trip in items for it in (trip[0],)}
        if self.policy.kind == "fifo":
            rounds = fifo_rounds([t[0] for t in items], self.device)
            return [[by_name[it.name] for it in rd] for rd in rounds]
        sigs = [self._signature(trip) for trip in items]
        key = None
        stale = False
        if self.policy.cache:
            key = (self.policy.kind, ScheduleCache.key_of(sigs))
            pattern = self.schedule_cache.lookup(key)
            if pattern is not None:
                replay = self._apply_pattern(pattern, items, sigs)
                if self._replay_ok(key, replay, self._flat_round_time):
                    return replay
                # Stale replay: recompose cold (the fresh composition
                # re-stores under the same key).  Warm-start adaptation
                # is skipped too — a one-signature-away pattern shares
                # the rejected pattern's staleness and performs no
                # capacity/drift re-validation of its own.
                stale = True
            if self.policy.warm_start and not stale:
                warm = self.schedule_cache.near_miss(key)
                if warm is not None:
                    result = self._warm_adapt(warm, items, sigs)
                    if result is not None:
                        return self._cache_store(key, result, items, sigs)
        profs = [t[0].profile() for t in items]
        sched: Schedule = greedy_order_fast(profs, self.device)
        if self.policy.kind == "refined":
            if self.policy.refine_model in ("event", "round"):
                # flat-order refinement under the core simulator,
                # delta-evaluated (suffix re-simulation from cached
                # admission checkpoints), then re-rounded by capacity
                order, _, _ = refine_order(
                    sched.order, self.device,
                    model=self.policy.refine_model,
                    budget=self.policy.refine_budget,
                    neighborhood=self.policy.neighborhood,
                    batch_size=(self.policy.refine_batch
                                if self.policy.refine_backend == "batched"
                                else None))
            else:
                # local search over the flat order, re-rounded by
                # greedy capacity packing under the round cost model
                def tfn(order_profs):
                    its = [by_name[p.name][0] for p in order_profs]
                    rds = fifo_rounds(its, self.device)
                    return sum(round_time(r, self.device,
                                          self.weights_bytes)
                               for r in rds)

                order, _, _ = refine_order(
                    sched.order, self.device, time_fn=tfn,
                    budget=self.policy.refine_budget,
                    neighborhood=self.policy.neighborhood)
            its = [by_name[p.name][0] for p in order]
            rounds = fifo_rounds(its, self.device)
            result = [[by_name[it.name] for it in rd] for rd in rounds]
            return self._cache_store(key, result, items, sigs)
        composed = [[by_name[p.name] for p in rd.kernels]
                    for rd in sched.rounds]
        # Cost-model guard: Algorithm 1 is profile-greedy; never accept
        # a composition the round cost model says is worse than arrival
        # order (the scheduler's own timing model is always available).
        t_alg = sum(round_time([t[0] for t in rd], self.device,
                               self.weights_bytes) for rd in composed)
        fifo = fifo_rounds([t[0] for t in items], self.device)
        t_fifo = sum(round_time(r, self.device, self.weights_bytes)
                     for r in fifo)
        if t_fifo < t_alg:
            result = [[by_name[it.name] for it in rd] for rd in fifo]
        else:
            result = composed
        return self._cache_store(key, result, items, sigs)

    def _signature(self, trip) -> tuple[str, int]:
        it, r, kind = trip
        length = r.pos if kind == "decode" else it.tokens
        return self.schedule_cache.signature(kind, length)

    def _flat_round_time(self, rd) -> float:
        return round_time([t[0] for t in rd], self.device,
                          self.weights_bytes)

    def _cache_store(self, key, result, items, sigs):
        if key is not None:
            name_sig = {trip[0].name: s for trip, s in zip(items, sigs)}
            pattern = tuple(tuple(name_sig[t[0].name] for t in rd)
                            for rd in result)
            t_model = sum(self._flat_round_time(rd) for rd in result)
            self.schedule_cache.store(key, pattern, t_model)
        return result

    def _apply_pattern(self, pattern, items, sigs):
        """Replay a cached round pattern onto the current (signature-
        equivalent) work items."""
        groups: dict[tuple[str, int], deque] = {}
        for trip, s in zip(items, sigs):
            groups.setdefault(s, deque()).append(trip)
        return [[groups[s].popleft() for s in rd] for rd in pattern]

    def _warm_adapt(self, warm, items, sigs):
        """Seed this step's composition from a near-miss cached one.

        One request left: drop its signature's occurrence from the
        cached pattern and replay.  One request joined: replay the
        pattern on the matching items, then place the newcomer into
        the round Algorithm 1's own scoring picks
        (:func:`repro.core.fastscore.warm_start_insert`).  The result
        still passes the fifo cost-model guard; returns None when the
        adaptation cannot be applied.
        """
        pattern, added, removed = warm
        pat = [list(rd) for rd in pattern]
        if removed:
            s = removed[0]
            for rd in pat:
                if s in rd:
                    rd.remove(s)
                    break
            pat = [rd for rd in pat if rd]
        groups: dict[tuple[str, int], deque] = {}
        for trip, s in zip(items, sigs):
            groups.setdefault(s, deque()).append(trip)
        if added:
            extra = groups[added[0]].popleft()
        try:
            result = [[groups[s].popleft() for s in rd] for rd in pat]
        except (KeyError, IndexError):
            return None  # stale pattern shape: fall back to recompute
        if added:
            ri = warm_start_insert(
                [[t[0].profile() for t in rd] for rd in result],
                extra[0].profile(), self.device)
            if ri >= 0:
                result[ri].append(extra)
            else:
                result.append([extra])
        # Same guard as the cold path: never accept a composition the
        # round cost model says is worse than arrival order.
        t_warm = sum(round_time([t[0] for t in rd], self.device,
                                self.weights_bytes) for rd in result)
        fifo = fifo_rounds([t[0] for t in items], self.device)
        t_fifo = sum(round_time(r, self.device, self.weights_bytes)
                     for r in fifo)
        if t_fifo < t_warm:
            by_name = {t[0].name: t for t in items}
            result = [[by_name[it.name] for it in rd] for rd in fifo]
        else:
            cache = self.schedule_cache
            cache.warm_hits += 1
            # Warm-start quality audit (deterministic sampling: the
            # warm-hit counter crossing an integer multiple of 1/frac
            # triggers a cold recompute; no RNG, so runs reproduce).
            frac = self.policy.warm_audit_frac
            if frac > 0 and (int(cache.warm_hits * frac) >
                             int((cache.warm_hits - 1) * frac)):
                sched = greedy_order_fast([t[0].profile() for t in items],
                                          self.device)
                nm = {t[0].name: t[0] for t in items}
                t_cold = min(t_fifo, sum(
                    round_time([nm[p.name] for p in rd.kernels],
                               self.device, self.weights_bytes)
                    for rd in sched.rounds))
                cache.record_warm_regret(t_warm / max(t_cold, 1e-30) - 1.0)
        return result

    # -- execution -------------------------------------------------------
    def submit(self, reqs: list[Request]) -> None:
        self.queue.extend(reqs)

    def _exec_prefill(self, r: Request) -> None:
        toks = jnp.asarray(r.prompt, jnp.int32)[None, :]
        cache = T.init_cache(self.cfg, 1, self.max_len)
        # replay prompt through decode steps (correctness-first prefill)
        for s in range(toks.shape[1]):
            logits, cache = self._decode_jit(self.params, toks[:, s],
                                             cache, s)
        r.cache = cache
        r.pos = int(toks.shape[1])
        r.generated.append(int(jnp.argmax(logits[0])))

    def _exec_decode(self, r: Request) -> None:
        tok = jnp.asarray([r.generated[-1]], jnp.int32)
        logits, r.cache = self._decode_jit(self.params, tok, r.cache, r.pos)
        r.pos += 1
        r.generated.append(int(jnp.argmax(logits[0])))
        if (len(r.generated) >= r.max_new_tokens or
                r.pos >= self.max_len - 1):
            r.done = True

    def step(self) -> int:
        """One scheduling iteration: compose rounds from the current
        queue and execute them.  Returns the number of rounds run.

        On the ``respect_deps`` path a round may contain interior
        chain stages (kind ``"frag"``): they contribute to the round's
        modelled time but trigger no execution — the request's exact
        forward pass runs once, at its chain's tail item."""
        if self.policy.respect_deps:
            triples, traced = self._work_items_dag()
            if not triples:
                return 0
            rounds = self._compose_dag(triples, traced)
            time_of = self._dag_round_time
        else:
            items = self._work_items()
            if not items:
                return 0
            rounds = self._compose(items)
            time_of = lambda rd: round_time(  # noqa: E731
                [t[0] for t in rd], self.device, self.weights_bytes)
        n = 0
        for rd in rounds:
            self._round_times.append(time_of(rd))
            for it, r, kind in rd:
                if kind == "prefill":
                    self._exec_prefill(r)
                elif kind == "decode":
                    self._exec_decode(r)
            n += 1
        return n

    def run(self, max_iters: int = 10_000,
            arrivals: list[tuple[int, list[Request]]] | None = None) -> dict:
        """Run to completion; returns stats incl. modelled round times.

        ``arrivals``: optional [(iteration, requests)] injections — a
        continuous-arrival workload where prefill and decode work
        genuinely coexist in the queue."""
        arrivals = list(arrivals or [])
        n_rounds = 0
        iters = 0
        while iters < max_iters:
            for when, reqs in list(arrivals):
                if when <= iters:
                    self.submit(reqs)
                    arrivals.remove((when, reqs))
            ran = self.step()
            if ran == 0 and not arrivals:
                break
            n_rounds += ran
            iters += 1
        total_tokens = sum(len(r.generated) for r in self.queue)
        return {
            "rounds": n_rounds,
            "total_new_tokens": total_tokens,
            "modelled_time_s": float(sum(self._round_times)),
            "modelled_tokens_per_s": total_tokens /
            max(sum(self._round_times), 1e-12),
            "schedule_cache": self.schedule_cache.stats(),
            "outputs": {r.rid: list(r.generated) for r in self.queue},
        }
