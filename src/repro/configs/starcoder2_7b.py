"""Config for ``starcoder2-7b`` (assigned architecture).

Exact published hyper-parameters; see ``repro.configs.archs`` for the
source notes and the reduced smoke variant.
"""

from .archs import get_config

def full():
    return get_config("starcoder2-7b", "full")

def smoke():
    return get_config("starcoder2-7b", "smoke")

config = full
