"""Config for ``xlstm-125m`` (assigned architecture).

Exact published hyper-parameters; see ``repro.configs.archs`` for the
source notes and the reduced smoke variant.
"""

from .archs import get_config

def full():
    return get_config("xlstm-125m", "full")

def smoke():
    return get_config("xlstm-125m", "smoke")

config = full
