"""Config for ``hubert-xlarge`` (assigned architecture).

Exact published hyper-parameters; see ``repro.configs.archs`` for the
source notes and the reduced smoke variant.
"""

from .archs import get_config

def full():
    return get_config("hubert-xlarge", "full")

def smoke():
    return get_config("hubert-xlarge", "smoke")

config = full
