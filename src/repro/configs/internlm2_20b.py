"""Config for ``internlm2-20b`` (assigned architecture).

Exact published hyper-parameters; see ``repro.configs.archs`` for the
source notes and the reduced smoke variant.
"""

from .archs import get_config

def full():
    return get_config("internlm2-20b", "full")

def smoke():
    return get_config("internlm2-20b", "smoke")

config = full
