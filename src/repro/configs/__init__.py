"""Architecture registry: 10 assigned archs + shapes + skip plan."""

from .archs import ARCHS, arch_names, get_config
from .shapes import SHAPES, ShapeSpec, shape_plan

__all__ = ["ARCHS", "arch_names", "get_config", "SHAPES", "ShapeSpec",
           "shape_plan"]
