"""Assigned input shapes and per-architecture applicability.

Every LM-family architecture is paired with four shapes:

* ``train_4k``     seq 4,096   global batch 256   (training step)
* ``prefill_32k``  seq 32,768  global batch 32    (inference prefill)
* ``decode_32k``   seq 32,768  global batch 128   (one decode token, KV=32k)
* ``long_500k``    seq 524,288 global batch 1     (long-context decode)

Skip rules (recorded, not silently dropped):
* encoder-only archs have no decode step -> decode shapes skipped,
* ``long_500k`` requires a sub-quadratic/bounded-KV path -> runs for
  SSM/hybrid archs and SWA archs, skipped for pure full-attention archs.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.models.common import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "shape_plan"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def _is_recurrent_or_hybrid(cfg: ModelConfig) -> bool:
    return any(k != "attn" for k in cfg.block_pattern)


def shape_plan(cfg: ModelConfig) -> dict[str, str | None]:
    """shape name -> None (run) or a skip reason string."""
    plan: dict[str, str | None] = {}
    for name, spec in SHAPES.items():
        reason = None
        if spec.kind == "decode" and not cfg.causal:
            reason = "encoder-only: no autoregressive decode step"
        elif name == "long_500k":
            if not cfg.causal:
                reason = "encoder-only: no autoregressive decode step"
            elif _is_recurrent_or_hybrid(cfg):
                reason = None  # SSM/hybrid: constant/bounded state
            elif cfg.sliding_window is not None:
                reason = None  # SWA bounds the KV cache
            else:
                reason = ("pure full-attention architecture: no "
                          "sub-quadratic path at 524k context")
        elif spec.kind == "prefill" and not cfg.causal:
            # Encoder archs still run prefill-shaped forward (a 32k
            # utterance batch) — it is just a forward pass.
            reason = None
        plan[name] = reason
    return plan
