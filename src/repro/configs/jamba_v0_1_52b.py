"""Config for ``jamba-v0.1-52b`` (assigned architecture).

Exact published hyper-parameters; see ``repro.configs.archs`` for the
source notes and the reduced smoke variant.
"""

from .archs import get_config

def full():
    return get_config("jamba-v0.1-52b", "full")

def smoke():
    return get_config("jamba-v0.1-52b", "smoke")

config = full
