"""Config for ``deepseek-v2-236b`` (assigned architecture).

Exact published hyper-parameters; see ``repro.configs.archs`` for the
source notes and the reduced smoke variant.
"""

from .archs import get_config

def full():
    return get_config("deepseek-v2-236b", "full")

def smoke():
    return get_config("deepseek-v2-236b", "smoke")

config = full
