"""Config for ``qwen1.5-0.5b`` (assigned architecture).

Exact published hyper-parameters; see ``repro.configs.archs`` for the
source notes and the reduced smoke variant.
"""

from .archs import get_config

def full():
    return get_config("qwen1.5-0.5b", "full")

def smoke():
    return get_config("qwen1.5-0.5b", "smoke")

config = full
