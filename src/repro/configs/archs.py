"""The ten assigned architectures, exact full configs + reduced smoke
variants of the same family shape.

Sources are the public configs cited in the assignment brief; smoke
variants preserve the family structure (block pattern, attention type,
MoE topology, GQA grouping) at toy width so one forward/train step runs
on CPU in seconds.
"""

from __future__ import annotations

from repro.models.common import ModelConfig

__all__ = ["ARCHS", "get_config", "arch_names"]

_JAMBA_PATTERN = ("mamba", "mamba", "mamba", "mamba",
                  "attn", "mamba", "mamba", "mamba")


def _pixtral_12b() -> ModelConfig:
    # Pixtral ViT frontend is a stub (input embeddings); backbone is the
    # Mistral-Nemo 12B decoder.
    return ModelConfig(
        name="pixtral-12b", n_layers=40, d_model=5120, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=14336, vocab=131072,
        rope_theta=1e6, input_mode="embeddings")


def _pixtral_12b_smoke() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b-smoke", n_layers=4, d_model=128, n_heads=8,
        n_kv_heads=2, head_dim=16, d_ff=352, vocab=512,
        rope_theta=1e6, input_mode="embeddings")


def _xlstm_125m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", n_layers=12, d_model=768, n_heads=4,
        n_kv_heads=4, head_dim=192, d_ff=0, vocab=50304,
        block_pattern=("slstm", "mlstm"), xlstm_proj_factor=2.0,
        use_rope=False)


def _xlstm_125m_smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=0, vocab=512,
        block_pattern=("slstm", "mlstm"), xlstm_proj_factor=2.0,
        use_rope=False)


def _mixtral_8x7b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=0, vocab=32000,
        n_experts=8, top_k=2, moe_d_ff=14336, sliding_window=4096)


def _mixtral_8x7b_smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke", n_layers=4, d_model=128, n_heads=8,
        n_kv_heads=2, head_dim=16, d_ff=0, vocab=512,
        n_experts=4, top_k=2, moe_d_ff=96, sliding_window=64)


def _deepseek_v2_236b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
        n_kv_heads=128, head_dim=128, d_ff=12288, vocab=102400,
        attn_type="mla", kv_lora_rank=512, q_lora_rank=1536,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        n_experts=160, top_k=6, n_shared_experts=2, moe_d_ff=1536,
        first_dense_layers=1)


def _deepseek_v2_236b_smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke", n_layers=3, d_model=128, n_heads=8,
        n_kv_heads=8, head_dim=16, d_ff=256, vocab=512,
        attn_type="mla", kv_lora_rank=64, q_lora_rank=48,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=64,
        first_dense_layers=1)


def _qwen15_05b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, head_dim=64, d_ff=2816, vocab=151936,
        qkv_bias=True, tie_embeddings=True)


def _qwen15_05b_smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b-smoke", n_layers=4, d_model=96, n_heads=6,
        n_kv_heads=6, head_dim=16, d_ff=256, vocab=512,
        qkv_bias=True, tie_embeddings=True)


def _starcoder2_7b() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36,
        n_kv_heads=4, head_dim=128, d_ff=18432, vocab=49152,
        qkv_bias=True, act="gelu", norm="layernorm")


def _starcoder2_7b_smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke", n_layers=4, d_model=144, n_heads=9,
        n_kv_heads=3, head_dim=16, d_ff=384, vocab=512,
        qkv_bias=True, act="gelu", norm="layernorm")


def _mistral_nemo_12b() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", n_layers=40, d_model=5120, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=14336, vocab=131072,
        rope_theta=1e6)


def _mistral_nemo_12b_smoke() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-smoke", n_layers=4, d_model=128, n_heads=8,
        n_kv_heads=2, head_dim=16, d_ff=352, vocab=512, rope_theta=1e6)


def _internlm2_20b() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", n_layers=48, d_model=6144, n_heads=48,
        n_kv_heads=8, head_dim=128, d_ff=16384, vocab=92544)


def _internlm2_20b_smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-smoke", n_layers=4, d_model=96, n_heads=6,
        n_kv_heads=1, head_dim=16, d_ff=256, vocab=512)


def _jamba_v01_52b() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=14336, vocab=65536,
        n_experts=16, top_k=2, moe_d_ff=14336, moe_layer_period=2,
        block_pattern=_JAMBA_PATTERN, use_rope=False,
        mamba_d_state=16, mamba_d_conv=4, mamba_expand=2)


def _jamba_v01_52b_smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        n_experts=4, top_k=2, moe_d_ff=96, moe_layer_period=2,
        block_pattern=_JAMBA_PATTERN, use_rope=False,
        mamba_d_state=8, mamba_d_conv=4, mamba_expand=2)


def _hubert_xlarge() -> ModelConfig:
    # Encoder-only; the CNN waveform frontend is a stub (precomputed
    # frame embeddings arrive as inputs).
    return ModelConfig(
        name="hubert-xlarge", n_layers=48, d_model=1280, n_heads=16,
        n_kv_heads=16, head_dim=80, d_ff=5120, vocab=504,
        causal=False, act="gelu", norm="layernorm",
        input_mode="embeddings", use_rope=False)


def _hubert_xlarge_smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", n_layers=4, d_model=80, n_heads=4,
        n_kv_heads=4, head_dim=20, d_ff=192, vocab=64,
        causal=False, act="gelu", norm="layernorm",
        input_mode="embeddings", use_rope=False)


ARCHS: dict[str, dict] = {
    "pixtral-12b": {"full": _pixtral_12b, "smoke": _pixtral_12b_smoke},
    "xlstm-125m": {"full": _xlstm_125m, "smoke": _xlstm_125m_smoke},
    "mixtral-8x7b": {"full": _mixtral_8x7b, "smoke": _mixtral_8x7b_smoke},
    "deepseek-v2-236b": {"full": _deepseek_v2_236b,
                         "smoke": _deepseek_v2_236b_smoke},
    "qwen1.5-0.5b": {"full": _qwen15_05b, "smoke": _qwen15_05b_smoke},
    "starcoder2-7b": {"full": _starcoder2_7b, "smoke": _starcoder2_7b_smoke},
    "mistral-nemo-12b": {"full": _mistral_nemo_12b,
                         "smoke": _mistral_nemo_12b_smoke},
    "internlm2-20b": {"full": _internlm2_20b, "smoke": _internlm2_20b_smoke},
    "jamba-v0.1-52b": {"full": _jamba_v01_52b, "smoke": _jamba_v01_52b_smoke},
    "hubert-xlarge": {"full": _hubert_xlarge, "smoke": _hubert_xlarge_smoke},
}


def get_config(name: str, variant: str = "full") -> ModelConfig:
    return ARCHS[name][variant]()


def arch_names() -> list[str]:
    return list(ARCHS)
