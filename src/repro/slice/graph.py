"""Slice-aware precedence: expand sliced nodes inside a kernel DAG.

Expanding node ``t`` into slices ``s_1..s_k`` plus a join ``j`` rewires
the graph so precedence semantics are preserved while the slices gain
schedulable freedom:

* every in-edge ``(u, t)`` becomes ``(u, s_i)`` for all i — slices
  inherit the parent's predecessors (none may start early),
* every out-edge ``(t, v)`` becomes ``(j, v)`` — the parent's
  successors hang off the synthetic join, waiting for the whole stage,
* edges ``(s_i, j)`` close the diamond,
* slices of one kernel carry **no** edges among themselves: they are
  mutually independent, so the ready-set greedy may pack them into
  different rounds with different peers and
  :func:`repro.graph.streams.assign_streams` may fan them out across
  launch queues.

Expansion preserves acyclicity (each node is replaced by a local
diamond) and composes: the output of one :func:`expand_nodes` call can
be expanded again, which is how the lazy scheduler
(:func:`repro.slice.constrained.greedy_order_slices`) slices in
passes.  ``parent_of`` threads the original node identity through
arbitrarily many passes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Mapping, Sequence

from repro.core.resources import KernelProfile

__all__ = ["SliceExpansion", "expand_nodes"]


@dataclass
class SliceExpansion:
    """One expansion pass: the rewired node list and edge set, plus
    the bookkeeping that maps new indices back to the input's.

    ``new_of[i]`` lists the new indices replacing input node ``i``
    (``[i']`` for untouched nodes, the slice indices for expanded
    ones); ``join_of[i]`` is the join's new index for expanded nodes;
    ``parent_of[j]`` is the input index every new node ``j`` descends
    from (slices and joins map to their parent).
    """

    kernels: list[KernelProfile]
    edges: set
    new_of: list[list[int]]
    join_of: dict[int, int] = field(default_factory=dict)
    parent_of: list[int] = field(default_factory=list)

    @property
    def n(self) -> int:
        return len(self.kernels)


def expand_nodes(kernels: Sequence[KernelProfile],
                 edges: Iterable[tuple[int, int]],
                 expansions: Mapping[int, tuple[Sequence[KernelProfile],
                                                KernelProfile]]
                 ) -> SliceExpansion:
    """Replace each node in ``expansions`` with its slices + join.

    ``expansions`` maps a node index to ``(slice_profiles, join)``.
    Slices are placed at the parent's position in the node list and
    the join directly after them, so a topological input ordering
    (every edge ``u < v``) stays topological after expansion — the
    invariant serving and the fifo baselines rely on.
    """
    out: list[KernelProfile] = []
    parent_of: list[int] = []
    new_of: list[list[int]] = []
    join_of: dict[int, int] = {}
    for i, k in enumerate(kernels):
        if i in expansions:
            slices, join = expansions[i]
            if len(slices) < 1:
                raise ValueError(f"node {i}: need >= 1 slice")
            idxs = []
            for s in slices:
                idxs.append(len(out))
                out.append(s)
                parent_of.append(i)
            join_of[i] = len(out)
            out.append(join)
            parent_of.append(i)
            new_of.append(idxs)
        else:
            new_of.append([len(out)])
            out.append(k)
            parent_of.append(i)
    new_edges: set = set()
    for u, v in set(edges):
        srcs = [join_of[u]] if u in expansions else new_of[u]
        for a in srcs:
            for b in new_of[v]:
                new_edges.add((a, b))
    for i in expansions:
        for s in new_of[i]:
            new_edges.add((s, join_of[i]))
    return SliceExpansion(kernels=out, edges=new_edges, new_of=new_of,
                          join_of=join_of, parent_of=parent_of)
