"""Kernelet-style kernel slicing: cut oversized stages into
co-schedulable pieces.

The paper's reordering wins come from packing kernels whose resource
profiles are complementary; a stage whose profile saturates the device
(a prefill full-bank attention, a dense MoE up-projection) can never
share a round, so reordering alone leaves it serialized.  Kernelet
(Zhong & He) solves exactly this by slicing a large kernel's grid into
sub-grids that *can* co-execute with other kernels.  This module is
the slicing primitive the slice-aware scheduler
(:mod:`repro.slice.constrained`) applies lazily — a stage is only cut
when the greedy's score vector shows it cannot pack with any frontier
peer:

* :class:`SlicePolicy` — how aggressively to cut: ``occupancy``
  (slice only stages that cannot fit a unit at all, to pieces under an
  occupancy threshold), ``round_fill`` (slice anything above a target
  round-fill fraction down to it), or ``fixed`` (cut triggered stages
  into a fixed number of pieces).  Granularity is a *scheduling
  decision* computed per stage from its profile (the ACS motivation:
  irregular, input-dependent graphs want per-stage choices, not a
  static config).
* :class:`KernelSlicer` — applies a policy to one
  :class:`~repro.core.resources.KernelProfile` or
  :class:`~repro.core.tpu.TpuWorkItem` with **exact accounting**:
  slice profiles sum back to the parent (work, traffic, demand mass
  and tokens are partitioned; block-parallel kernels partition the
  grid), while ``weight_bytes`` is *copied* to every slice — the
  parameter stream is a property of the stage, shared by its slices,
  and the serving round accounting
  (:meth:`repro.serve.engine.ServingEngine._dag_round_time`) charges
  it once per distinct parent stage per round, never per slice.
* :func:`join_profile` / :func:`join_item` — the synthetic
  zero-work join node the graph expansion hangs the parent's
  out-edges off (:func:`repro.slice.graph.expand_nodes`), so slices of
  one kernel stay mutually independent and downstream consumers wait
  for *all* of them.

Naming: a slice of ``r0:p:L3:moe`` is ``r0:p:L3:moe#s1of4``; its join
is ``r0:p:L3:moe#join``.  Everything after ``#`` is slice metadata —
:func:`parent_name` strips it, which is how per-stage weight
accounting keys slices back to their stage.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Sequence

from repro.core.resources import DeviceModel, KernelProfile
from repro.core.tpu import TpuWorkItem

__all__ = ["SlicePolicy", "KernelSlicer", "join_profile", "join_item",
           "parent_name", "is_slice", "is_join", "merge_slice_profiles",
           "slice_indices"]


def parent_name(name: str) -> str:
    """Strip slice metadata: ``r0:p:L3:moe#s1of4`` -> ``r0:p:L3:moe``."""
    return name.split("#", 1)[0]


def is_slice(name: str) -> bool:
    return "#s" in name


def is_join(name: str) -> bool:
    return name.endswith("#join")


@dataclass(frozen=True)
class SlicePolicy:
    """When to slice a stage and into how many pieces.

    ``mode``:

    * ``"occupancy"`` (default) — slice only stages that cannot fit an
      execution unit at all (solo footprint above ``trigger_frac`` of
      some capacity, default 1.0), into pieces each at most
      ``occupancy_threshold`` of the binding capacity:
      ``k = ceil(max_frac / occupancy_threshold)``.
    * ``"round_fill"`` — slice any stage whose footprint exceeds
      ``target_fill`` of a capacity down to pieces of at most that
      fill: ``k = ceil(max_frac / target_fill)``.  More aggressive:
      also cuts stages that fit but monopolise a round.
    * ``"fixed"`` — cut every triggered stage (footprint above
      ``trigger_frac``) into exactly ``fixed_k`` pieces.

    ``max_slices`` bounds k for any single stage; slicing functions
    additionally clamp k to the stage's own granularity (grid size for
    block-parallel kernels, token count for serving items) — a
    1-token decode step is never cut.  Slices are terminal: a slice or
    join is never re-sliced.
    """

    mode: str = "occupancy"
    occupancy_threshold: float = 0.75
    target_fill: float = 0.5
    fixed_k: int = 2
    trigger_frac: float = 1.0
    max_slices: int = 16

    def __post_init__(self):
        if self.mode not in ("occupancy", "round_fill", "fixed"):
            raise ValueError(f"unknown slice mode {self.mode!r}")
        if not (0.0 < self.occupancy_threshold <= 1.0):
            raise ValueError("occupancy_threshold must be in (0, 1]")
        if not (0.0 < self.target_fill <= 1.0):
            raise ValueError("target_fill must be in (0, 1]")
        if self.fixed_k < 1 or self.max_slices < 1:
            raise ValueError("fixed_k and max_slices must be >= 1")


def _balanced_split(total: int, k: int) -> list[int]:
    """``total`` into ``k`` positive integers differing by at most 1,
    largest parts first (deterministic)."""
    base, rem = divmod(total, k)
    return [base + (1 if i < rem else 0) for i in range(k)]


@dataclass
class KernelSlicer:
    """Applies a :class:`SlicePolicy` to kernels against one device."""

    policy: SlicePolicy
    device: DeviceModel

    # -- policy: how many pieces -----------------------------------------
    def footprint_frac(self, prof: KernelProfile) -> float:
        """Solo footprint of ``prof`` as a fraction of the tightest
        per-unit capacity (including the resident-block cap) — > 1.0
        means the stage cannot fit an execution unit at all."""
        dev = self.device
        d = prof.per_unit_demand(dev)
        frac = prof.blocks_per_unit(dev) / max(dev.max_resident, 1)
        for dim in dev.caps:
            cap = dev.cap(dim)
            if cap > 0:
                frac = max(frac, d.get(dim, 0.0) / cap)
        return frac

    def slice_count(self, prof: KernelProfile) -> int:
        """Slices the policy wants for ``prof``; 1 means don't slice.
        Slice granularity is a per-stage scheduling decision read off
        the profile, not a static config."""
        if "#" in prof.name:      # slices and joins are terminal
            return 1
        pol = self.policy
        frac = self.footprint_frac(prof)
        if pol.mode == "round_fill":
            if frac <= pol.target_fill:
                return 1
            k = -(-frac // pol.target_fill)           # ceil
        elif pol.mode == "occupancy":
            if frac <= pol.trigger_frac:
                return 1
            k = -(-frac // pol.occupancy_threshold)   # ceil
        else:                                         # fixed
            if frac <= pol.trigger_frac:
                return 1
            k = pol.fixed_k
        k = int(min(k, pol.max_slices))
        k = min(k, self._granularity(prof))
        return max(k, 1)

    def _granularity(self, prof: KernelProfile) -> int:
        """Finest legal cut: block-parallel kernels cut along the
        grid; single-block (serving) profiles cut along the
        parallel-slack dimension (token slots)."""
        if prof.n_blocks > 1:
            return int(prof.n_blocks)
        sd = self.device.sat_dim
        if sd and sd in prof.demands:
            return max(int(prof.demands[sd]), 1)
        return 1

    # -- mechanics: exact accounting -------------------------------------
    def slice_profile(self, prof: KernelProfile,
                      k: int | None = None) -> list[KernelProfile]:
        """Cut ``prof`` into ``k`` slice profiles whose resource totals
        sum back to the parent exactly.

        Block-parallel kernels (``n_blocks > 1``) partition the grid —
        per-block demands, work and intensity are unchanged, block
        counts sum to the parent's (Kernelet's sub-grid slicing).
        Single-block profiles partition *mass*: demands and per-block
        work scale by the slice's share, intensity is preserved.
        """
        k = self.slice_count(prof) if k is None else int(k)
        k = min(k, self._granularity(prof))
        if k <= 1:
            return [prof]
        if prof.n_blocks > 1:
            return [
                replace(prof, name=f"{prof.name}#s{i}of{k}", n_blocks=nb)
                for i, nb in enumerate(_balanced_split(int(prof.n_blocks), k))
            ]
        total = self._granularity(prof)
        shares = [p / total for p in _balanced_split(total, k)]
        return [
            KernelProfile(
                name=f"{prof.name}#s{i}of{k}",
                n_blocks=prof.n_blocks,
                demands={d: v * w for d, v in prof.demands.items()},
                inst_per_block=prof.inst_per_block * w,
                r=prof.r,
                agg_blocks_per_unit=prof.agg_blocks_per_unit,
            )
            for i, w in enumerate(shares)
        ]

    def slice_item(self, item: TpuWorkItem,
                   k: int | None = None) -> list[TpuWorkItem]:
        """Cut a serving work item along its token dimension into
        ``k`` slices with exact accounting: FLOPs, marginal HBM
        traffic, on-chip residency and tokens are partitioned
        proportionally (tokens as balanced integers) and sum back to
        the parent; arithmetic intensity is inherited; the shared
        parameter stream (``weight_bytes``) is *copied*, not split —
        it belongs to the stage and is charged once per round that
        touches any slice of it."""
        k = self.slice_count(item.profile()) if k is None else int(k)
        k = min(k, max(int(item.tokens), 1))
        if k <= 1:
            return [item]
        toks = _balanced_split(int(item.tokens), k)
        out = []
        for i, t in enumerate(toks):
            w = t / item.tokens
            out.append(TpuWorkItem(
                name=f"{item.name}#s{i}of{k}",
                flops=item.flops * w,
                hbm_bytes=item.hbm_bytes * w,
                vmem_bytes=item.vmem_bytes * w,
                tokens=t,
                intensity_hint=item.intensity,
                weight_bytes=item.weight_bytes,
            ))
        return out


def slice_indices(name: str) -> tuple[list[int], int]:
    """Parse slice metadata: ``r0:moe#s1of4 -> ([1], 4)``;
    merged slices carry every constituent index:
    ``r0:moe#s1+3of4 -> ([1, 3], 4)``."""
    if "#s" not in name:
        raise ValueError(f"{name!r} is not a slice name")
    meta = name.rsplit("#s", 1)[1]
    idx_part, k_part = meta.rsplit("of", 1)
    return sorted(int(p) for p in idx_part.split("+")), int(k_part)


def merge_slice_profiles(slices: Sequence[KernelProfile],
                         block_parallel: bool | None = None
                         ) -> KernelProfile:
    """Inverse of :meth:`KernelSlicer.slice_profile` for sibling
    slices: one profile whose resource totals are the exact sum of the
    inputs' (the same conservation law slicing obeys, run backwards).

    Block-parallel siblings merge by summing grid blocks (per-block
    demands, work and intensity unchanged); mass-sliced siblings
    (single-block serving profiles) merge by summing demands and
    per-block work, preserving intensity.  ``block_parallel=None``
    infers the mode: any multi-block slice, or identical per-block
    demand/work vectors across all siblings, means grid slicing (mass
    shares are balanced integers, so equal mass shares — the one
    ambiguous corner — merge block-shaped; totals are conserved under
    either reading).

    Merging *every* sibling (indices cover ``0..k-1``) restores the
    parent name; a partial merge keeps slice metadata, e.g.
    ``moe#s1of4 + moe#s3of4 -> moe#s1+3of4``, so
    :func:`is_slice` / :func:`parent_name` keep working and a later
    pass can finish the merge.
    """
    if not slices:
        raise ValueError("need >= 1 slice to merge")
    if len(slices) == 1:
        return slices[0]
    parent = parent_name(slices[0].name)
    idxs: list[int] = []
    k_tot = None
    for s in slices:
        if parent_name(s.name) != parent:
            raise ValueError(f"not siblings: {s.name!r} vs {parent!r}")
        ix, k = slice_indices(s.name)
        if k_tot is None:
            k_tot = k
        elif k != k_tot:
            raise ValueError(f"slice counts disagree on {s.name!r}")
        idxs.extend(ix)
    if len(set(idxs)) != len(idxs):
        raise ValueError("duplicate slice indices")
    idxs.sort()
    full = idxs == list(range(k_tot))
    name = (parent if full else
            f"{parent}#s{'+'.join(str(i) for i in idxs)}of{k_tot}")
    first = slices[0]
    if block_parallel is None:
        same = all(
            s.demands == first.demands and
            s.inst_per_block == first.inst_per_block and
            s.r == first.r for s in slices[1:])
        block_parallel = any(s.n_blocks > 1 for s in slices) or same
    if block_parallel:
        return replace(first, name=name,
                       n_blocks=sum(int(s.n_blocks) for s in slices))
    dims = {d for s in slices for d in s.demands}
    return KernelProfile(
        name=name,
        n_blocks=first.n_blocks,
        demands={d: sum(s.demands.get(d, 0.0) for s in slices)
                 for d in dims},
        inst_per_block=sum(s.inst_per_block for s in slices),
        r=first.r,
        agg_blocks_per_unit=first.agg_blocks_per_unit,
    )


def join_profile(parent: KernelProfile) -> KernelProfile:
    """The synthetic join node for ``parent``'s slices: zero work,
    zero demands, one block — a pure synchronisation marker.  The
    graph expansion hangs the parent's out-edges off it so successors
    wait for *every* slice; the gated simulator
    (:class:`repro.graph.streams.DagEventSimulator`) retires zero-work
    kernels instantly once their predecessors drain, so a join never
    occupies a unit or adds modelled time."""
    return KernelProfile(
        name=f"{parent_name(parent.name)}#join",
        n_blocks=1,
        demands={d: 0.0 for d in parent.demands},
        inst_per_block=0.0,
        r=1.0,
    )


def join_item(parent: TpuWorkItem) -> TpuWorkItem:
    """Serving-item twin of :func:`join_profile` (zero cost, zero
    tokens, unit intensity so ``mem_per_block`` stays defined)."""
    return TpuWorkItem(
        name=f"{parent_name(parent.name)}#join",
        flops=0.0, hbm_bytes=0.0, vmem_bytes=0.0, tokens=0,
        intensity_hint=1.0, weight_bytes=0.0,
    )
