"""Coalesce sibling slices the scheduler put back together.

Slicing (:mod:`repro.slice.slicer`) cuts a stage so its pieces *can*
co-execute with other kernels.  When a composed schedule then lands
several siblings in the **same round anyway**, the cut bought nothing
for those pieces — they run side by side exactly as one bigger slice
would — while the extra nodes and diamond edges keep taxing everything
downstream: legality filtering, gated suffix re-simulation, and
especially the 200-order random-topological percentile sweeps the
benchmarks run (whose cost grows with node count, not work).

:func:`coalesce_rounds` is the inverse pass: siblings sharing a round
merge back into one node (:func:`~repro.slice.slicer.merge_slice_profiles`
— the same exact-accounting conservation law slicing obeys, run
backwards), and a stage whose *every* slice merged back collapses
fully: the restored parent node takes the join's out-edges and the
zero-work join disappears.  Precedence is preserved by construction —
siblings share their in-edges (the parent's predecessors), their only
successor is the join, and a merged node sits exactly where its first
member sat in the round structure.
"""

from __future__ import annotations

from dataclasses import replace

from repro.core.scheduler import Round, Schedule

from .constrained import SlicedSchedule
from .slicer import is_join, is_slice, merge_slice_profiles, parent_name

__all__ = ["coalesce_rounds"]


def coalesce_rounds(result: SlicedSchedule) -> SlicedSchedule:
    """Merge same-round sibling slices of ``result`` back into single
    nodes; fully re-merged stages drop their join.  Returns a new
    :class:`~repro.slice.constrained.SlicedSchedule` over the shrunken
    graph (``result`` is untouched).  Resource totals are conserved
    exactly; the round structure is preserved (merged members are
    replaced in place, emptied rounds dropped)."""
    ks = result.kernels
    idx_of = {id(k): i for i, k in enumerate(ks)}

    # -- 1. merge groups: same-parent slices sharing a round ----------
    groups: list[list[int]] = []
    grouped: dict[int, int] = {}          # old idx -> group id
    for rd in result.rounds:
        per_parent: dict[str, list[int]] = {}
        for k in rd.kernels:
            if is_slice(k.name) and not is_join(k.name):
                per_parent.setdefault(parent_name(k.name),
                                      []).append(idx_of[id(k)])
        for sibs in per_parent.values():
            if len(sibs) > 1:
                gid = len(groups)
                groups.append(sorted(sibs))
                for i in sibs:
                    grouped[i] = gid
    if not groups:
        return result

    # -- 2. which stages collapse fully (single surviving slice)? ----
    slices_of: dict[str, list[int]] = {}
    join_of: dict[str, int] = {}
    for i, k in enumerate(ks):
        if is_join(k.name):
            join_of[parent_name(k.name)] = i
        elif is_slice(k.name):
            slices_of.setdefault(parent_name(k.name), []).append(i)
    survivors: dict[str, int] = {
        p: len({grouped.get(i, -1 - i) for i in sibs})
        for p, sibs in slices_of.items()}
    collapsed = {p for p, n_left in survivors.items()
                 if n_left == 1 and p in join_of}

    # -- 3. rebuild the node list (merged node at first member) ------
    new_ks: list = []
    new_parent_of: list[int] = []
    newidx: dict[int, int] = {}
    emitted: set[int] = set()
    dropped_joins: dict[int, str] = {
        join_of[p]: p for p in collapsed}
    for i, k in enumerate(ks):
        if i in dropped_joins:
            continue
        gid = grouped.get(i)
        if gid is None:
            newidx[i] = len(new_ks)
            new_ks.append(k)
            new_parent_of.append(result.parent_of[i])
            continue
        if gid in emitted:
            newidx[i] = newidx[groups[gid][0]]
            continue
        emitted.add(gid)
        members = groups[gid]
        merged = merge_slice_profiles([ks[m] for m in members])
        p = parent_name(k.name)
        if p in collapsed and is_slice(merged.name):
            # every sibling merged into this node: restore the parent
            # name so the graph carries no slice metadata for it
            # (merge_slice_profiles already does this when the indices
            # cover 0..k-1; this is the belt for exotic expansions).
            merged = replace(merged, name=p)
        newidx[i] = len(new_ks)
        new_ks.append(merged)
        new_parent_of.append(result.parent_of[i])
    # joins of collapsed stages route their edges through the restored
    # node.
    for j, p in dropped_joins.items():
        newidx[j] = newidx[slices_of[p][0]]

    new_edges = {(newidx[u], newidx[v]) for u, v in result.edges
                 if newidx[u] != newidx[v]}

    # -- 4. rebuild rounds: members replaced in place, dedup, no
    # dropped joins ---------------------------------------------------
    new_rounds: list[Round] = []
    for rd in result.rounds:
        nrd = Round()
        seen: set[int] = set()
        for k in rd.kernels:
            i = idx_of[id(k)]
            if i in dropped_joins:
                continue
            ni = newidx[i]
            if ni in seen:
                continue
            seen.add(ni)
            nrd.kernels.append(new_ks[ni])
        if nrd.kernels:
            new_rounds.append(nrd)

    new_sliced = {}
    for p, n in result.sliced.items():
        if p in collapsed:
            continue
        new_sliced[p] = survivors.get(p, n)
    return SlicedSchedule(schedule=Schedule(new_rounds), kernels=new_ks,
                          edges=new_edges, sliced=new_sliced,
                          parent_of=new_parent_of,
                          passes=result.passes)
