"""Kernelet-style kernel slicing (PR 4's new subsystem).

The reordering scheduler (:mod:`repro.core.fastscore`) and its DAG
generalisation (:mod:`repro.graph`) pack kernels whose resource
profiles are complementary; a stage that saturates the device on its
own can never share a round, so reordering alone leaves it serialized.
This package cuts such stages into co-schedulable pieces:

* :mod:`repro.slice.slicer` — :class:`SlicePolicy` (occupancy /
  round-fill / fixed-k, granularity chosen per stage from its
  profile) + :class:`KernelSlicer` (exact accounting: slice profiles
  sum back to the parent, the stage's weight stream is shared by its
  slices and charged once per round),
* :mod:`repro.slice.graph` — :func:`expand_nodes` (slices inherit the
  parent's in-edges, successors hang off a synthetic join node,
  sibling slices stay mutually independent),
* :mod:`repro.slice.constrained` — :func:`greedy_order_slices` (lazy
  expansion: a stage is cut only when the ready-set greedy lands it in
  a solo round) + :func:`refine_order_slices` (legal local search over
  the expanded order).

Gated makespans of sliced schedules come from the unchanged
:class:`repro.graph.streams.DagEventSimulator`, which admits slices
under the ready-set gate and retires the zero-work join markers
instantly; at slice factor 1 every path here degenerates bit-for-bit
to the unsliced :mod:`repro.graph` pipeline.  Serving opts in through
``SchedulerPolicy.slice_policy`` (default off).
"""

from .coalesce import coalesce_rounds
from .constrained import (SlicedSchedule, greedy_order_slices,
                          refine_order_slices)
from .graph import SliceExpansion, expand_nodes
from .slicer import (KernelSlicer, SlicePolicy, is_join, is_slice,
                     join_item, join_profile, merge_slice_profiles,
                     parent_name, slice_indices)

__all__ = [
    "SlicePolicy", "KernelSlicer", "join_profile", "join_item",
    "parent_name", "is_slice", "is_join", "merge_slice_profiles",
    "slice_indices", "coalesce_rounds",
    "SliceExpansion", "expand_nodes",
    "SlicedSchedule", "greedy_order_slices", "refine_order_slices",
]
