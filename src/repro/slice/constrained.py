"""Lazy slice-aware ready-set greedy + legal local search.

:func:`greedy_order_slices` extends
:func:`repro.graph.constrained.greedy_order_dag` with **lazy slice
expansion**: the graph is scheduled as-is, and a stage is only cut
when the greedy itself proves it cannot pack — it lands in a solo
round, i.e. its score vector showed no frontier peer it fits with (or
the frontier had no peers at all).  Triggered stages are expanded
through :func:`repro.slice.graph.expand_nodes` (slices inherit the
parent's in-edges, successors hang off the synthetic join) and the
ready-set greedy re-runs over the rewired graph; passes repeat until
no solo round wants slicing.  Slices and joins are terminal — a pass
can only expand original stages — so the loop terminates after at
most one pass per sliceable stage (two passes in practice).

With a policy that triggers nothing (or ``policy=None``) the result
is exactly one ``greedy_order_dag`` pass: same rounds, same
intra-round order, same tie-breaking — the slice-factor-1 identity
pinned by ``tests/test_slice.py``.

:func:`refine_order_slices` is
:func:`repro.graph.constrained.refine_order_dag` run over the expanded
order: legality extends to the slice/join edges automatically (a slice
can never move before its parent's predecessors, a successor never
before the join) because the move filter reads the expanded edge set.
With ``model="gated"`` it optimizes the sliced schedule's own scoring
currency — the gated DAG makespan
(:class:`repro.graph.streams.DagEventSimulator`, which retires the
zero-work joins instantly) — directly via gated suffix re-simulation
(:class:`repro.graph.delta.GatedDeltaEvaluator`), so the returned time
needs no greedy fallback on the gated scoreboard.
"""

from __future__ import annotations

from typing import Callable, Iterable, Sequence

from repro.core.resources import DeviceModel, KernelProfile
from repro.core.scheduler import Schedule
from repro.graph.constrained import greedy_order_dag, refine_order_dag
from repro.graph.kernel_graph import KernelGraph

from .graph import expand_nodes
from .slicer import KernelSlicer, SlicePolicy, join_profile

__all__ = ["SlicedSchedule", "frontier_solo_expander",
           "greedy_order_slices", "refine_order_slices"]


def frontier_solo_expander(slicer: KernelSlicer,
                           make_slices: Callable | None = None,
                           make_join: Callable | None = None):
    """``on_solo`` hook for
    :meth:`repro.graph.constrained.GreedyFrontier.insert_chain`:
    slice-aware live joins (PR 7).

    When a joining chain's stage fits no round of the live
    composition, the frontier asks this hook before opening a solo
    round — the live counterpart of the lazy expansion trigger in
    :func:`greedy_order_slices` (there, a stage is cut when the batch
    greedy lands it in a solo round; here, when the live placement
    scan finds no fitting peer round).  The policy decision is the
    slicer's (:meth:`~repro.slice.slicer.KernelSlicer.slice_count`);
    ``make_slices(prof, k)`` / ``make_join(prof)`` override the
    expansion mechanics exactly as in :func:`greedy_order_slices` —
    the serving engine passes closures that also cut the backing
    work items so the composed rounds stay executable.  Returns
    ``(slices, join)`` or ``None`` (stage stays whole)."""
    if make_slices is None:
        make_slices = slicer.slice_profile
    if make_join is None:
        make_join = join_profile

    def on_solo(prof: KernelProfile):
        if "#" in prof.name:
            return None          # slices and joins are terminal
        k = slicer.slice_count(prof)
        if k <= 1:
            return None
        return list(make_slices(prof, k)), make_join(prof)

    return on_solo


class SlicedSchedule:
    """Result of the slice-aware greedy: the round schedule plus the
    expanded workload it is a schedule *of*.

    ``kernels``/``edges`` describe the expanded DAG (slices + joins);
    ``sliced`` maps each cut stage's name to its slice count;
    ``parent_of[j]`` maps expanded node ``j`` to its index in the
    caller's original kernel list.
    """

    def __init__(self, schedule: Schedule, kernels: list[KernelProfile],
                 edges: set, sliced: dict[str, int],
                 parent_of: list[int], passes: int):
        self.schedule = schedule
        self.kernels = kernels
        self.edges = edges
        self.sliced = sliced
        self.parent_of = parent_of
        self.passes = passes

    @property
    def order(self) -> list[KernelProfile]:
        return self.schedule.order

    @property
    def rounds(self):
        return self.schedule.rounds

    def graph(self) -> KernelGraph:
        return KernelGraph(self.kernels, self.edges)

    def edges_by_id(self) -> set:
        ks = self.kernels
        return {(id(ks[u]), id(ks[v])) for u, v in self.edges}


def greedy_order_slices(
    kernels: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    edges: Iterable[tuple[int, int]] = (),
    policy: SlicePolicy | None = None,
    make_slices: Callable[[KernelProfile, int],
                          Sequence[KernelProfile]] | None = None,
    make_join: Callable[[KernelProfile], KernelProfile] | None = None,
    max_passes: int = 8,
    frontier=None,
) -> SlicedSchedule:
    """Ready-set Algorithm 1 with lazy Kernelet-style slicing.

    ``policy=None`` disables slicing entirely (one plain
    ``greedy_order_dag`` pass).  ``make_slices(prof, k)`` /
    ``make_join(prof)`` override the expansion mechanics — the serving
    engine supplies closures that also cut the backing
    :class:`~repro.core.tpu.TpuWorkItem` so rounds stay executable;
    the *decision* (which stage, how many pieces) always comes from
    the policy via :class:`~repro.slice.slicer.KernelSlicer`.

    ``frontier`` threads a
    :class:`repro.graph.constrained.GreedyFrontier` sink through to
    the greedy passes; because each pass resets it, on return it holds
    the *final* pass's composition — the one this function's schedule
    reports — ready for live extension.
    """
    ks: list[KernelProfile] = list(kernels)
    es: set = {(u, v) for u, v in edges}
    parent_of = list(range(len(ks)))
    sliced: dict[str, int] = {}
    slicer = KernelSlicer(policy, device) if policy is not None else None
    if make_slices is None and slicer is not None:
        make_slices = slicer.slice_profile
    if make_join is None:
        make_join = join_profile
    passes = 0
    while True:
        sched = greedy_order_dag(ks, device, edges=es,
                                 frontier=frontier)
        if slicer is None or passes >= max_passes:
            break
        pos = {id(k): i for i, k in enumerate(ks)}
        trig: dict[int, int] = {}
        for rd in sched.rounds:
            if len(rd.kernels) != 1:
                continue
            k = rd.kernels[0]
            n_cut = slicer.slice_count(k)
            if n_cut > 1:
                trig[pos[id(k)]] = n_cut
        if not trig:
            break
        expansions = {i: (list(make_slices(ks[i], n)), make_join(ks[i]))
                      for i, n in trig.items()}
        for i, n in trig.items():
            sliced[ks[i].name] = len(expansions[i][0])
        exp = expand_nodes(ks, es, expansions)
        ks, es = exp.kernels, exp.edges
        parent_of = [parent_of[p] for p in exp.parent_of]
        passes += 1
    return SlicedSchedule(schedule=sched, kernels=ks, edges=es,
                          sliced=sliced, parent_of=parent_of,
                          passes=passes)


def refine_order_slices(
    result: SlicedSchedule,
    device: DeviceModel,
    *,
    budget: int = 2000,
    model: str = "event",
    neighborhood: str = "adjacent",
    batch_size: int | None = None,
    rescore: bool | None = None,
    metrics=None,
) -> tuple[list[KernelProfile], float, int]:
    """Precedence-respecting local search over a sliced schedule's
    flat order.  Slice/join edges participate in the legality filter
    like any other precedence edge, so every candidate keeps slices
    after their parent's predecessors and the join (hence all
    successors) after every slice.  ``model="gated"`` optimizes the
    gated DAG makespan directly (delta-evaluated suffix re-simulation,
    see :func:`repro.graph.constrained.refine_order_dag`); ``"round"``
    and ``"event"`` remain the cheap precedence-blind proxies.
    ``batch_size`` selects the batched move evaluator
    (:func:`repro.core.batched.refine_order_batched`) as in
    :func:`~repro.graph.constrained.refine_order_dag`; ``metrics``
    forwards there too (``refine_evals`` / ``refine_cost`` /
    ``refine_score_s``)."""
    return refine_order_dag(result.order, device,
                            edge_ids=result.edges_by_id(),
                            budget=budget, model=model,
                            neighborhood=neighborhood,
                            batch_size=batch_size, rescore=rescore,
                            metrics=metrics)
