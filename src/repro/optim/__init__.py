"""Optimizer substrate."""

from .adamw import (AdamWConfig, adamw_init, adamw_update,
                    clip_by_global_norm, compress_int8, cosine_schedule,
                    decompress_int8, global_norm)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "clip_by_global_norm", "compress_int8", "cosine_schedule",
           "decompress_int8", "global_norm"]
