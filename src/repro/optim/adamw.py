"""AdamW + schedules + gradient utilities (pure JAX, optax-free).

State is a pytree mirroring params; the update is fully jit/pjit
compatible and inherits parameter shardings (m/v get the same specs as
their parameters).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm", "compress_int8",
           "decompress_int8"]

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    #: dtype for m/v moments ("bfloat16" halves optimizer HBM at 100B+
    #: scale, the standard production trade).
    state_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: PyTree, state_dtype=jnp.float32) -> PyTree:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float
                        ) -> tuple[PyTree, jnp.ndarray]:
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), g


def _decay_mask(path) -> bool:
    """No weight decay for norms, biases, gates and 1-D params."""
    s = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    return not any(t in s for t in ("norm", "scale", "/b", "bias", "a_log",
                                    "d_skip"))


def adamw_update(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                 state: PyTree) -> tuple[PyTree, PyTree, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    sdt = jnp.dtype(cfg.state_dtype)
    new_m = jax.tree.map(
        lambda m, g: (b1 * m.astype(jnp.float32) +
                      (1 - b1) * g.astype(jnp.float32)).astype(sdt),
        state["m"], grads)
    new_v = jax.tree.map(
        lambda v, g: (b2 * v.astype(jnp.float32) +
                      (1 - b2) * jnp.square(g.astype(jnp.float32))
                      ).astype(sdt),
        state["v"], grads)

    def upd(path, p, m, v):
        m, v = m.astype(jnp.float32), v.astype(jnp.float32)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if _decay_mask(path):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map_with_path(upd, params, new_m, new_v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics


# ---------------------------------------------------------------------------
# Gradient compression (int8 with per-tensor scale + error feedback)
# ---------------------------------------------------------------------------

def compress_int8(tree: PyTree) -> PyTree:
    """-> {leaf: (int8 values, f32 scale)}; used for cross-pod gradient
    exchange and accumulation-buffer compression (error feedback is the
    caller's responsibility via the returned residual)."""

    def enc(x):
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}

    return jax.tree.map(enc, tree)


def decompress_int8(tree: PyTree) -> PyTree:
    def dec(leaf):
        return leaf["q"].astype(jnp.float32) * leaf["scale"]

    return jax.tree.map(dec, tree,
                        is_leaf=lambda t: isinstance(t, dict) and "q" in t)
