"""Online quality auditing: the paper's Fig.-1 percentile claim as a
live serving SLO.

The headline result of the source paper is a *percentile*: a reordered
launch sequence lands "well above the 90 percentile mark" of the
design space of all (legal) launch orders.  Offline that audit lives
in ``benchmarks/dag.py``; this module re-runs the same protocol
*inside* the serving loop so a regression below the paper's claim is a
counter, not a rerun of a benchmark:

* :class:`QualityAuditor` deterministically samples an ``audit_frac``
  fraction of served steps (the same integer-crossing rule as the PR 3
  warm-start audit, so runs reproduce without an RNG in the hot path),
* scores the *served* composition against ``audit_k`` seeded random
  orders of the same kernel set, under the step's own currency:

  - traced (``respect_deps``) steps score the gated-event makespan of
    the flat launch order — exactly what ``benchmarks/dag.py``
    measures — via one :class:`repro.graph.delta.GatedDeltaEvaluator`
    ``rebase`` on the served order; every random topological baseline
    then resumes from the checkpoint at its first divergence and pays
    only a suffix fraction of a full simulation (saved fractions
    accumulate in the ``audit_sims_saved`` counter);
  - flat steps score the round cost model over capacity-packed rounds
    of each shuffled order (the flat path's serving currency).

* records the served order's :func:`repro.core.percentile_rank` into
  the ``audit_quality_percentile{arch,kind}`` histogram and bumps
  ``audit_below_floor`` whenever it lands under ``audit_floor``
  (default 90.0 — the paper's claim as a live SLO).

The auditor also owns the warm-start regret audit that PR 3 inlined
into the composer: ``SchedulerPolicy.warm_audit_frac`` is now a
deprecated alias routed through :meth:`QualityAuditor.warm_audit`, so
the ``warm_regret_mean`` / ``warm_sampled`` stats keys keep working
unchanged.

Auditing is strictly read-only over already-composed rounds: it never
mutates the composition, the cache, or request state, so served
tokens are bit-identical with auditing on or off (property-tested in
``tests/test_audit.py``).
"""

from __future__ import annotations

import random

from repro.core.fastscore import greedy_order_fast
from repro.core.scheduler import percentile_rank
from repro.core.tpu import fifo_rounds, round_time
from repro.graph.delta import GatedDeltaEvaluator

__all__ = ["QualityAuditor"]


class QualityAuditor:
    """Budget-capped, seeded Fig.-1 sampler for served compositions.

    One instance per :class:`repro.serve.composer.Composer`; shares
    the policy object (so runtime knob flips are seen immediately) and
    writes to the engine-shared :class:`repro.obs.MetricsRegistry`.
    ``recorder`` (a :class:`repro.obs.FlightRecorder`) optionally gets
    one ``"audit"`` event per verdict.
    """

    def __init__(self, policy, device, metrics, recorder=None):
        self.policy = policy
        self.device = device
        self.metrics = metrics
        self.recorder = recorder
        #: steps offered to :meth:`sample_step` (audited or not) —
        #: the denominator of the deterministic sampling rule and the
        #: per-step component of the baseline seed.
        self._steps_seen = 0
        # Pre-register the unlabelled audit series so snapshots are
        # schema-stable whether or not any step was ever audited
        # (the per-arch/kind percentile histograms appear on first
        # verdict — their labels aren't known up front).
        for name in ("audit_steps", "audit_baselines",
                     "audit_below_floor", "audit_sims_saved"):
            metrics.counter(name)

    # -- deterministic sampling ----------------------------------------
    @staticmethod
    def crossed(seen: int, frac: float) -> bool:
        """The PR 3 integer-crossing rule: sample iff the running
        count just crossed a multiple of ``1/frac``.  No RNG, so a
        given workload audits the same steps every run (pinned by
        ``tests/test_schedule_cache.py``)."""
        return frac > 0 and int(seen * frac) > int((seen - 1) * frac)

    def sample_step(self) -> bool:
        """True iff the step being served should be audited
        (deterministic ``audit_frac`` sampling)."""
        frac = getattr(self.policy, "audit_frac", 0.0)
        if frac <= 0:
            return False
        self._steps_seen += 1
        return self.crossed(self._steps_seen, frac)

    def _seed(self) -> int:
        """Per-audited-step baseline seed: deterministic, distinct
        across steps so consecutive audits don't re-score the same
        random orders."""
        return (getattr(self.policy, "audit_seed", 0) * 1_000_003
                + self._steps_seen)

    # -- verdict recording ---------------------------------------------
    def _record(self, pct: float, t_served: float, k: int,
                saved: float, *, arch: str, kind: str,
                currency: str) -> dict:
        floor = getattr(self.policy, "audit_floor", 90.0)
        below = pct < floor
        m = self.metrics
        m.histogram("audit_quality_percentile",
                    arch=arch, kind=kind).observe(pct)
        m.counter("audit_steps").inc()
        m.counter("audit_baselines").inc(k)
        if saved:
            m.counter("audit_sims_saved").inc(saved)
        if below:
            m.counter("audit_below_floor").inc()
        verdict = {"percentile": pct, "t_served": t_served, "k": k,
                   "below_floor": below, "floor": floor,
                   "currency": currency, "arch": arch,
                   "policy_kind": kind, "sims_saved": saved}
        if self.recorder is not None:
            self.recorder.event("audit", **verdict)
        return verdict

    def _skip(self, reason: str) -> None:
        self.metrics.counter("audit_skipped", reason=reason).inc()

    # -- traced (respect_deps) steps: gated currency --------------------
    def audit_dag(self, rounds, traced, *, arch: str,
                  kind: str) -> dict | None:
        """Score a served traced composition against ``audit_k``
        random topological orders of its kernel graph under the
        gated-event makespan (the offline Fig.-1 protocol,
        ``benchmarks/dag.py``).

        One ``rebase`` on the served flat order caches per-position
        checkpoints; each baseline is delta-evaluated from its first
        divergence, so K baselines cost far less than K full
        simulations.  Sliced compositions are skipped (their kernel
        set differs from the traced graph's; counted under
        ``audit_skipped{reason=sliced}``)."""
        graph = traced.graph
        by_name = {p.name: p for p in graph.kernels}
        served = []
        for rd in rounds:
            for it, _, _ in rd:
                p = by_name.get(it.name)
                if p is None:
                    self._skip("sliced")
                    return None
                served.append(p)
        if (len(served) != graph.n
                or len({id(p) for p in served}) != graph.n):
            self._skip("partial")
            return None
        ev = GatedDeltaEvaluator(self.device, graph.edges_by_id())
        try:
            t_served = ev.rebase(served)
        except ValueError:
            self._skip("illegal")
            return None
        k = int(getattr(self.policy, "audit_k", 50))
        baselines = graph.random_topological_orders(k,
                                                    seed=self._seed())
        times = []
        saved = 0.0
        for cand in baselines:
            first = len(cand)
            for i, (a, b) in enumerate(zip(served, cand)):
                if a is not b:
                    first = i
                    break
            if first == len(cand):
                times.append(t_served)
                saved += 1.0
                continue
            t, frac = ev.evaluate_costed(cand, first)
            saved += max(0.0, 1.0 - frac)
            times.append(t)
        pct = percentile_rank(t_served, times)
        return self._record(pct, t_served, len(times), saved,
                            arch=arch, kind=kind, currency="gated")

    # -- flat steps: round currency -------------------------------------
    def audit_flat(self, rounds, *, weights_bytes: float, arch: str,
                   kind: str) -> dict | None:
        """Score a served flat composition against ``audit_k`` seeded
        shuffles of its work items, each capacity-packed by
        ``fifo_rounds`` and timed under the round cost model — the
        flat path's own serving currency (every launch order is legal:
        flat items carry no precedence edges)."""
        items = [trip[0] for rd in rounds for trip in rd]
        if not items:
            self._skip("empty")
            return None
        t_served = sum(round_time([t[0] for t in rd], self.device,
                                  weights_bytes) for rd in rounds)
        k = int(getattr(self.policy, "audit_k", 50))
        rng = random.Random(self._seed())
        times = []
        for _ in range(k):
            perm = list(items)
            rng.shuffle(perm)
            times.append(sum(round_time(rd, self.device, weights_bytes)
                             for rd in fifo_rounds(perm, self.device)))
        pct = percentile_rank(t_served, times)
        return self._record(pct, t_served, len(times), 0.0,
                            arch=arch, kind=kind, currency="round")

    # -- warm-start regret audit (the PR 3 path, absorbed) --------------
    def warm_audit(self, cache, items, t_warm: float, t_fifo: float,
                   weights_bytes: float) -> None:
        """The deprecated ``SchedulerPolicy.warm_audit_frac`` alias:
        on the sampled fraction of warm hits (same crossing rule,
        keyed on ``cache.warm_hits``), recompute the cold greedy
        composition and record the modelled regret through
        :meth:`repro.serve.cache.ScheduleCache.record_warm_regret`, so
        the historical ``warm_regret_mean`` / ``warm_sampled`` stats
        keys keep reporting unchanged."""
        frac = getattr(self.policy, "warm_audit_frac", 0.0)
        if frac <= 0 or not self.crossed(cache.warm_hits, frac):
            return
        sched = greedy_order_fast([t[0].profile() for t in items],
                                  self.device)
        nm = {t[0].name: t[0] for t in items}
        t_cold = min(t_fifo, sum(
            round_time([nm[p.name] for p in rd.kernels],
                       self.device, weights_bytes)
            for rd in sched.rounds))
        regret = t_warm / max(t_cold, 1e-30) - 1.0
        cache.record_warm_regret(regret)
        if self.recorder is not None:
            self.recorder.event("warm_audit", regret=regret,
                                t_warm=t_warm, t_cold=t_cold)
