"""Schedule tracing: per-kernel admission/completion timelines.

The paper's claim is a *timeline* claim — reordered launches fill
units that FIFO order leaves idle — but until PR 8 the simulators
could only report scalar makespans.  :class:`ScheduleTrace` is the
recorder the simulators feed when a caller passes ``trace=``:

* a **span** per kernel residency on a device unit — admitted at
  ``t0``, fully drained at ``t1``, carrying the block count;
* an **instant** per structural event — round boundaries from the
  round-based model, zero-work join retirements from the DAG models;
* a per-unit **busy-time** accumulator maintained independently of
  the spans (the dispatcher loop adds each ``dt`` it advances a unit
  through), which is what the conservation property in
  ``tests/test_obs.py`` checks span unions against.

The recorder is write-only during simulation — plain list appends and
float adds, no branching on content — and every instrumentation site
is guarded by ``if trace is not None``, so the null path costs one
pointer comparison (the bit-identity property: traced and untraced
runs produce the same floats because tracing only *reads* simulator
state).

Exports: :meth:`ScheduleTrace.to_chrome` renders Chrome-trace-event
JSON (one "process" per device unit, so Perfetto groups rows the way
the dispatcher does; load the file at https://ui.perfetto.dev), and
:meth:`ScheduleTrace.gantt` renders a terminal Gantt chart.
"""

from __future__ import annotations

import json

__all__ = ["ScheduleTrace"]


class ScheduleTrace:
    """Recorder for one (or several, via resume) simulator runs.

    ``label`` names the traced schedule in exports.  All times are in
    the simulators' modelled-time unit (seconds); Chrome export scales
    to microseconds, the trace-event wire unit.
    """

    def __init__(self, label: str = "schedule"):
        self.label = label
        #: (unit, name, t0, t1, blocks, category) complete spans
        self.spans: list[tuple[int, str, float, float, int, str]] = []
        #: (name, t, unit_or_None, category) instant events
        self.instants: list[tuple[str, float, int | None, str]] = []
        #: unit -> accumulated busy time (sum of dispatcher ``dt``
        #: advances while >= 1 cohort was resident)
        self.busy: dict[int, float] = {}
        self._t_max = 0.0

    # -- recording (called from inside simulator loops) ---------------

    def span(self, unit: int, name: str, t0: float, t1: float,
             blocks: int = 1, cat: str = "kernel") -> None:
        """Kernel ``name`` resident on ``unit`` from ``t0`` to ``t1``."""
        self.spans.append((unit, name, t0, t1, blocks, cat))
        if t1 > self._t_max:
            self._t_max = t1

    def instant(self, name: str, t: float, unit: int | None = None,
                cat: str = "event") -> None:
        """Zero-duration structural event (round boundary, join
        retirement).  ``unit=None`` scopes it to the whole device."""
        self.instants.append((name, t, unit, cat))
        if t > self._t_max:
            self._t_max = t

    def add_busy(self, unit: int, dt: float) -> None:
        self.busy[unit] = self.busy.get(unit, 0.0) + dt

    # -- derived views -------------------------------------------------

    @property
    def makespan(self) -> float:
        """Latest recorded event time."""
        return self._t_max

    def units(self) -> list[int]:
        us = {s[0] for s in self.spans} | set(self.busy)
        us.update(i[2] for i in self.instants if i[2] is not None)
        return sorted(us)

    def busy_of(self, unit: int) -> float:
        return self.busy.get(unit, 0.0)

    def span_union(self, unit: int) -> float:
        """Total time >= 1 span covers ``unit`` (interval union, so
        merged-cohort overlaps are not double-counted)."""
        ivs = sorted((t0, t1) for u, _, t0, t1, _, _ in self.spans
                     if u == unit)
        total, end = 0.0, float("-inf")
        for t0, t1 in ivs:
            if t0 > end:
                total += t1 - t0
                end = t1
            elif t1 > end:
                total += t1 - end
                end = t1
        return total

    def max_resident_blocks(self, unit: int) -> int:
        """Peak simultaneous resident blocks on ``unit`` over the
        trace (event sweep; span boundaries are half-open so a drain
        and a same-instant admit don't stack)."""
        events: list[tuple[float, int, int]] = []
        for u, _, t0, t1, blocks, _ in self.spans:
            if u != unit:
                continue
            events.append((t0, 1, blocks))   # admits after drains at t
            events.append((t1, 0, -blocks))
        events.sort()
        cur = peak = 0
        for _, _, d in events:
            cur += d
            if cur > peak:
                peak = cur
        return peak

    # -- exports -------------------------------------------------------

    def to_chrome(self) -> dict:
        """Chrome-trace-event JSON object (``traceEvents`` array).

        One trace-event *process* per device unit (``pid`` = unit
        index) so Perfetto renders a row group per unit, mirroring the
        dispatcher; spans are ``ph="X"`` complete events, structural
        instants ``ph="i"``.  Modelled seconds scale to the wire's
        microseconds.
        """
        ev: list[dict] = []
        units = self.units() or [0]
        for u in units:
            ev.append({"name": "process_name", "ph": "M", "pid": u,
                       "tid": 0,
                       "args": {"name": f"{self.label}: unit {u}"}})
        for u, name, t0, t1, blocks, cat in self.spans:
            ev.append({"name": name, "cat": cat, "ph": "X",
                       "ts": t0 * 1e6, "dur": (t1 - t0) * 1e6,
                       "pid": u, "tid": 0,
                       "args": {"blocks": blocks}})
        for name, t, u, cat in self.instants:
            ev.append({"name": name, "cat": cat, "ph": "i",
                       "ts": t * 1e6,
                       "pid": units[0] if u is None else u, "tid": 0,
                       "s": "g" if u is None else "t"})
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> None:
        """Write :meth:`to_chrome` JSON to ``path`` (open the file at
        https://ui.perfetto.dev or chrome://tracing)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def gantt(self, width: int = 72) -> str:
        """Plain-text Gantt chart: one row per unit, one symbol per
        kernel (legend below), ``*`` where distinct kernels overlap
        in a cell, ``.`` for idle."""
        span_end = self._t_max
        if not self.spans or span_end <= 0:
            return "(empty trace)"
        symbols = ("abcdefghijklmnopqrstuvwxyz"
                   "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789")
        sym: dict[str, str] = {}
        for _, name, _, _, _, _ in self.spans:
            if name not in sym:
                sym[name] = symbols[len(sym) % len(symbols)]
        scale = width / span_end
        lines = [f"{self.label}  (makespan {span_end:.4g}s, "
                 f"1 col = {span_end / width:.3g}s)"]
        for u in self.units():
            row = ["."] * width
            for su, name, t0, t1, _, _ in self.spans:
                if su != u:
                    continue
                i0 = min(width - 1, int(t0 * scale))
                i1 = min(width, max(i0 + 1, int(t1 * scale + 0.5)))
                ch = sym[name]
                for i in range(i0, i1):
                    row[i] = ch if row[i] in (".", ch) else "*"
            lines.append(f"unit {u:>2} |{''.join(row)}|")
        legend = ", ".join(f"{c}={n}" for n, c in
                           list(sym.items())[:24])
        lines.append(f"legend: {legend}"
                     + (" ..." if len(sym) > 24 else ""))
        for name, t, u, _ in self.instants[:16]:
            where = "device" if u is None else f"unit {u}"
            lines.append(f"  @{t:.4g}s [{where}] {name}")
        if len(self.instants) > 16:
            lines.append(f"  ... {len(self.instants) - 16} more events")
        return "\n".join(lines)
