"""Unified metrics registry for the scheduler/serving stack.

Before PR 8 every component kept its own ad-hoc counters — plain int
attributes on :class:`repro.serve.cache.ScheduleCache`, a float on the
gated guard, nothing at all on the refiners — and ``stats()`` dicts
with no shared shape.  ``MetricsRegistry`` is the one sink they all
write to now:

* **Counters** — monotone floats (``cache_hits``, ``refine_evals``,
  ``gated_sims_saved``); support labels, so the cache's flat and dag
  namespaces share one name (``cache_hits{namespace=flat}``).
* **Gauges** — last-write-wins values (``cache_entries``).
* **Histograms** — count/total/min/max summaries of observations, fed
  either directly (:meth:`Histogram.observe`) or through the
  wall-clock :meth:`MetricsRegistry.timer` context (the profiling
  hooks around the engine's compose/guard/refine/execute phases).

The registry is deliberately dependency-free and cheap: metric
objects are plain ``__slots__`` instances resolved once and mutated
in place, so hot paths hold a reference instead of re-looking-up by
name.  ``snapshot()`` renders the whole registry as a flat
``{name_with_labels: value}`` dict (histograms expand to
``name.count`` / ``name.total_s`` / ...), which is what
``ServingEngine.run()`` re-exports and ``benchmarks/serving.py``
prints.
"""

from __future__ import annotations

import random
import time
import zlib

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

#: Reservoir size for histogram quantiles.  256 samples bound the
#: p99 estimate's standard error to a few percentile points while
#: keeping ``observe()`` O(1) and the memory per series fixed.
RESERVOIR_SIZE = 256


def _fmt(name: str, labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone accumulator.  ``inc()`` only; never decremented."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-write-wins value (e.g. current cache entry count)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming count/total/min/max summary plus quantile reservoir.

    No buckets: the consumers here want means (seconds per phase per
    step), extrema, and tail quantiles, and a bucketed histogram would
    force a bucket layout choice on every caller.  Quantiles come from
    a fixed-size reservoir (Vitter's algorithm R) seeded from the
    series name, so two runs observing the same sequence produce
    bit-identical p50/p95/p99 — determinism the engine's
    bit-identity tests rely on.  ``observe()`` stays O(1).
    """

    __slots__ = ("name", "count", "total", "vmin", "vmax",
                 "_reservoir", "_rng")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._reservoir: list[float] = []
        # Seed from the labelled name: deterministic across runs and
        # processes (zlib.crc32, unlike hash(), is not salted).
        self._rng = random.Random(zlib.crc32(name.encode()))

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if len(self._reservoir) < RESERVOIR_SIZE:
            self._reservoir.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < RESERVOIR_SIZE:
                self._reservoir[j] = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Reservoir estimate of the ``q``-quantile (``0 <= q <= 1``).

        Exact while ``count <= RESERVOIR_SIZE``; an unbiased sample
        estimate beyond that.  Returns 0.0 for an empty histogram so
        snapshots stay schema-stable.
        """
        if not self._reservoir:
            return 0.0
        xs = sorted(self._reservoir)
        idx = min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))
        return xs[idx]


class _Timer:
    """``with registry.timer("phase_compose"):`` wall-clock context.

    Re-entrant-safe because each ``with`` statement gets its own
    instance via :meth:`MetricsRegistry.timer`."""

    __slots__ = ("hist", "_t0")

    def __init__(self, hist: Histogram):
        self.hist = hist
        self._t0 = 0.0

    def __enter__(self) -> "_Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.hist.observe(time.perf_counter() - self._t0)


class MetricsRegistry:
    """Get-or-create registry of named counters/gauges/histograms.

    Labels are keyword arguments; ``counter("cache_hits",
    namespace="flat")`` and ``counter("cache_hits", namespace="dag")``
    are distinct series under one logical name.  Metric kinds share a
    namespace: registering ``x`` as a counter and again as a gauge is
    a programming error and raises.
    """

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, cls, name: str, labels: dict) -> object:
        key = _fmt(name, tuple(sorted(labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = cls(key)
            self._metrics[key] = m
        elif type(m) is not cls:
            raise TypeError(
                f"metric {key!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def timer(self, name: str, **labels) -> _Timer:
        """Fresh wall-clock context feeding ``histogram(name)``."""
        return _Timer(self.histogram(name, **labels))

    def snapshot(self) -> dict:
        """Flat ``{labelled_name: value}`` view of every series.

        Counters and gauges render as their value; a histogram ``h``
        expands to ``h.count`` / ``h.total_s`` / ``h.mean_s`` /
        ``h.min_s`` / ``h.max_s`` plus reservoir-sampled quantiles
        ``h.p50_s`` / ``h.p95_s`` / ``h.p99_s`` (empty histograms
        report zeros so snapshots are schema-stable across runs).
        """
        out: dict[str, float | int] = {}
        for key, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                out[f"{key}.count"] = m.count
                out[f"{key}.total_s"] = m.total
                out[f"{key}.mean_s"] = m.mean
                out[f"{key}.min_s"] = m.vmin if m.count else 0.0
                out[f"{key}.max_s"] = m.vmax if m.count else 0.0
                out[f"{key}.p50_s"] = m.quantile(0.50)
                out[f"{key}.p95_s"] = m.quantile(0.95)
                out[f"{key}.p99_s"] = m.quantile(0.99)
            else:
                out[key] = m.value
        return out

    def reset(self, prefix: str | None = None) -> None:
        """Zero registered series in place (references held by hot
        paths stay valid).  ``prefix`` restricts the reset to series
        whose labelled name starts with it (``"cache_"`` lets
        :meth:`repro.serve.cache.ScheduleCache.reset` zero its own
        series without touching an engine-shared registry's phase
        timers)."""
        for key, m in self._metrics.items():
            if prefix is not None and not key.startswith(prefix):
                continue
            if isinstance(m, Histogram):
                m.count, m.total = 0, 0.0
                m.vmin, m.vmax = float("inf"), float("-inf")
                m._reservoir.clear()
                m._rng = random.Random(zlib.crc32(m.name.encode()))
            else:
                m.value = 0.0
