"""Export layer: Prometheus text exposition and a JSONL flight
recorder.

Everything the stack observes — cache counters, phase timers, audit
verdicts, latency spans — lives in a
:class:`repro.obs.MetricsRegistry` or happens as a discrete event.
This module gets both out of the process:

* :func:`prometheus_text` renders any registry in the Prometheus text
  exposition format (``# TYPE`` annotated; counters and gauges as
  single samples, histograms as summaries with ``quantile`` labels
  from the seeded reservoir plus ``_sum`` / ``_count``).
  :func:`parse_prometheus_text` is the matching reader — it exists so
  the round-trip is property-testable, and doubles as a minimal
  scrape parser for tests and tooling.

* :class:`FlightRecorder` is the JSONL event log: composers, the live
  frontier, the auditor, and the cache-replay paths emit discrete
  events (schedule decisions, cache outcomes, audit verdicts, rebuild
  reasons) via :meth:`FlightRecorder.event`.  Events carry a
  monotone ``seq`` instead of wall timestamps, so recorded runs are
  byte-identical across machines; :meth:`FlightRecorder.load` reads a
  dump back and :meth:`FlightRecorder.timeline` reconstructs a
  postmortem view (ordered, human-readable, with per-kind counts) —
  the mined-history substrate the ROADMAP's cross-step
  global-optimization direction will consume.

A ``None`` recorder is the null path everywhere (``if recorder is not
None`` at every emission site), mirroring the ``trace=None``
contract: recording must never change modelled times or served
tokens.
"""

from __future__ import annotations

import json
import re
from collections import Counter as _Counter

from .metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = ["prometheus_text", "parse_prometheus_text",
           "FlightRecorder"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
#: summary quantiles exported per histogram (matches the reservoir
#: quantiles surfaced in ``MetricsRegistry.snapshot()``)
_QUANTILES = (0.5, 0.95, 0.99)


def _split_labels(key: str) -> tuple[str, list[tuple[str, str]]]:
    """``"cache_hits{namespace=flat}"`` -> ``("cache_hits",
    [("namespace", "flat")])`` (the registry's labelled-name format,
    see :func:`repro.obs.metrics._fmt`)."""
    name, brace, rest = key.partition("{")
    if not brace:
        return key, []
    labels = []
    for part in rest.rstrip("}").split(","):
        k, _, v = part.partition("=")
        labels.append((k, v))
    return name, labels


def _sanitize(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _label_str(labels: list[tuple[str, str]]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_sanitize(k)}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def prometheus_text(metrics: MetricsRegistry, *,
                    prefix: str = "repro_") -> str:
    """Prometheus text exposition of every series in ``metrics``.

    Counters/gauges render as single samples; a histogram renders as
    a summary — ``quantile``-labelled samples from its seeded
    reservoir plus ``_sum`` and ``_count``.  Series sharing a base
    name (label variants) share one ``# TYPE`` header.  Names are
    sanitized to the Prometheus charset and prefixed (default
    ``repro_``) so a scrape of several processes stays collision-free.
    """
    by_name: dict[str, list[tuple[list[tuple[str, str]], object]]] = {}
    kinds: dict[str, str] = {}
    for key, m in sorted(metrics._metrics.items()):
        name, labels = _split_labels(key)
        pname = prefix + _sanitize(name)
        by_name.setdefault(pname, []).append((labels, m))
        kinds[pname] = ("counter" if isinstance(m, Counter)
                        else "gauge" if isinstance(m, Gauge)
                        else "summary")
    lines: list[str] = []
    for pname, series in by_name.items():
        lines.append(f"# TYPE {pname} {kinds[pname]}")
        for labels, m in series:
            if isinstance(m, Histogram):
                for q in _QUANTILES:
                    ql = labels + [("quantile", repr(q))]
                    lines.append(f"{pname}{_label_str(ql)} "
                                 f"{m.quantile(q):.17g}")
                lines.append(f"{pname}_sum{_label_str(labels)} "
                             f"{m.total:.17g}")
                lines.append(f"{pname}_count{_label_str(labels)} "
                             f"{m.count}")
            else:
                lines.append(f"{pname}{_label_str(labels)} "
                             f"{m.value:.17g}")
    return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus_text(text: str) -> dict[str, float]:
    """Minimal exposition reader: ``{sample_name_with_labels: value}``
    for every non-comment sample line.  The inverse of
    :func:`prometheus_text` up to float formatting — the round-trip
    property ``tests/test_obs.py`` pins."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        # labels may contain spaces in values; split on the last space
        name, _, value = line.rpartition(" ")
        out[name] = float(value)
    return out


class FlightRecorder:
    """Append-only JSONL event log for serving decisions.

    Emission sites pass a short ``kind`` plus JSON-safe fields:
    ``rec.event("rebuild", reason="capacity")``.  Events get a
    monotone ``seq``; no wall timestamps, so a recorded run is
    deterministic and diffable.  ``max_events`` bounds memory (the
    oldest events are dropped FIFO once exceeded; the drop count is
    kept so a truncated log says so).
    """

    def __init__(self, max_events: int = 100_000):
        self.max_events = max_events
        self.events: list[dict] = []
        self.dropped = 0
        self._seq = 0

    def event(self, kind: str, **fields) -> None:
        ev = {"seq": self._seq, "kind": kind}
        ev.update(fields)
        self._seq += 1
        self.events.append(ev)
        if len(self.events) > self.max_events:
            del self.events[0]
            self.dropped += 1

    def __len__(self) -> int:
        return len(self.events)

    # -- serialization --------------------------------------------------
    def to_jsonl(self) -> str:
        """One JSON object per line, key-sorted for diffability."""
        return "".join(json.dumps(ev, sort_keys=True) + "\n"
                       for ev in self.events)

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    @staticmethod
    def load(source: str) -> list[dict]:
        """Read a JSONL dump back into an event list.  ``source`` is a
        file path, or the JSONL text itself (anything containing a
        newline or starting with ``{`` is treated as text)."""
        if "\n" in source or source.lstrip().startswith("{"):
            text = source
        else:
            with open(source) as f:
                text = f.read()
        return [json.loads(line) for line in text.splitlines()
                if line.strip()]

    # -- postmortem -----------------------------------------------------
    @staticmethod
    def timeline(events: list[dict]) -> dict:
        """Reconstruct a postmortem view of a loaded event log.

        Returns ``{"n_events", "by_kind", "lines"}``: total count,
        per-kind counts, and one ordered human-readable line per
        event (``#seq kind: k=v ...``, fields key-sorted) — what a
        human reads first when a serving run went sideways, and the
        machine-readable substrate for mining schedule history."""
        events = sorted(events, key=lambda e: e.get("seq", 0))
        lines = []
        for ev in events:
            extra = " ".join(
                f"{k}={ev[k]}" for k in sorted(ev)
                if k not in ("seq", "kind"))
            lines.append(f"#{ev.get('seq', '?')} "
                         f"{ev.get('kind', '?')}"
                         + (f": {extra}" if extra else ""))
        return {"n_events": len(events),
                "by_kind": dict(_Counter(
                    e.get("kind", "?") for e in events)),
                "lines": lines}
