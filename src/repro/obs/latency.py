"""Per-request latency accounting and replay-drift monitoring.

The ROADMAP's async-serving north star needs p50/p99 latency and
goodput instrumentation to exist *before* the pipelined front end that
reports them can be built.  This module provides both halves:

* :class:`LatencyTracker` — per-request arrival→completion wall-clock
  spans inside :class:`repro.serve.engine.ServingEngine`, with
  queue/compose/guard/refine/execute attribution.  Queue time is
  arrival→first-scheduled; each engine step's measured phase wall
  times are split evenly across the requests served that step (the
  synchronous engine runs one step at a time, so an even split is the
  honest attribution — no request makes progress outside its step).
  Completions feed the ``request_latency_s`` / ``request_queue_s`` /
  ``request_phase_s{phase=...}`` histograms, whose seeded reservoirs
  give p50/p95/p99 in :meth:`stats` and in
  ``ServingEngine.stats()["latency"]``.

* :class:`DriftMonitor` — the EWMA modelled-vs-revalidated drift
  monitor per cache namespace.  The stale-replay check
  (:meth:`repro.serve.composer.Composer.replay_ok`) and the live
  frontier's ratio backstop already *reject* drifted replays; this
  monitor surfaces *how wrong* replayed compositions are — every
  re-validation feeds ``|t_now/t_stored - 1|`` into the
  ``replay_drift{namespace=...}`` histogram and the
  ``replay_drift_ewma{namespace=...}`` gauge, so a cache whose
  patterns are aging badly is visible before the reject counter
  climbs.

Both are pure observers: they read wall clocks and already-computed
modelled times, never the composition itself, so served tokens are
bit-identical with tracking on or off.
"""

from __future__ import annotations

import time

__all__ = ["LatencyTracker", "DriftMonitor"]

#: phase attribution keys, in pipeline order (queue is derived from
#: arrival→first-scheduled, the rest from engine phase wall deltas)
ATTRIB_PHASES = ("compose", "guard", "refine", "execute")


class _Span:
    """Open per-request span: arrival wall time, first-scheduled wall
    time, and accumulated per-phase attribution."""

    __slots__ = ("t_arrive", "t_first", "phases")

    def __init__(self, t_arrive: float):
        self.t_arrive = t_arrive
        self.t_first: float | None = None
        self.phases = {ph: 0.0 for ph in ATTRIB_PHASES}


class LatencyTracker:
    """Arrival→completion span tracker for the serving engine.

    ``clock`` is injectable for tests (defaults to
    ``time.perf_counter``).  All histograms land in the shared
    registry, so ``MetricsRegistry.snapshot()`` carries the latency
    series alongside the cache and phase series.
    """

    def __init__(self, metrics, clock=time.perf_counter):
        self.metrics = metrics
        self.clock = clock
        self._open: dict[int, _Span] = {}

    def arrive(self, rid: int, t: float | None = None) -> None:
        """A request entered the queue (``ServingEngine.submit``)."""
        if rid not in self._open:
            self._open[rid] = _Span(self.clock() if t is None else t)

    def attribute(self, rids, phase_s: dict,
                  t: float | None = None) -> None:
        """One engine step served ``rids``; split each measured phase
        wall time (``phase_s``, seconds per phase) evenly across
        them.  First-time-scheduled requests get their queue span
        closed at ``t``."""
        rids = [r for r in rids if r in self._open]
        if not rids:
            return
        now = self.clock() if t is None else t
        share = {ph: s / len(rids) for ph, s in phase_s.items() if s}
        for rid in rids:
            span = self._open[rid]
            if span.t_first is None:
                span.t_first = now
            for ph, s in share.items():
                if ph in span.phases:
                    span.phases[ph] += s

    def complete(self, rid: int, *, tokens: int = 0,
                 t: float | None = None) -> None:
        """Close a request's span and feed the latency histograms."""
        span = self._open.pop(rid, None)
        if span is None:
            return
        now = self.clock() if t is None else t
        m = self.metrics
        m.histogram("request_latency_s").observe(now - span.t_arrive)
        t_first = span.t_first if span.t_first is not None else now
        m.histogram("request_queue_s").observe(t_first - span.t_arrive)
        for ph, s in span.phases.items():
            m.histogram("request_phase_s", phase=ph).observe(s)
        m.counter("requests_completed").inc()
        m.counter("tokens_completed").inc(tokens)

    def stats(self, wall_s: float) -> dict:
        """The latency/goodput block of ``ServingEngine.stats()``:
        completion count, reservoir p50/p95/p99 (plus mean/max) of
        arrival→completion and queue spans, mean per-phase
        attribution, and goodput over ``wall_s`` (completed requests
        and tokens per wall second)."""
        m = self.metrics
        lat = m.histogram("request_latency_s")
        queue = m.histogram("request_queue_s")
        completed = m.counter("requests_completed").value
        tokens = m.counter("tokens_completed").value
        wall = max(wall_s, 1e-12)
        return {
            "completed": int(completed),
            "in_flight": len(self._open),
            "wall_s": wall_s,
            "p50_s": lat.quantile(0.50),
            "p95_s": lat.quantile(0.95),
            "p99_s": lat.quantile(0.99),
            "mean_s": lat.mean,
            "max_s": lat.vmax if lat.count else 0.0,
            "queue_p50_s": queue.quantile(0.50),
            "queue_p99_s": queue.quantile(0.99),
            "phase_mean_s": {
                ph: m.histogram("request_phase_s", phase=ph).mean
                for ph in ATTRIB_PHASES},
            "goodput_rps": completed / wall,
            "goodput_tokens_per_s": tokens / wall,
        }


class DriftMonitor:
    """EWMA of modelled-vs-revalidated drift per cache namespace.

    ``observe(namespace, rel_err)`` feeds the absolute relative error
    of a replayed (or incrementally maintained) composition's current
    modelled time against its stored baseline.  ``alpha`` is the EWMA
    smoothing weight of the newest observation.
    """

    def __init__(self, metrics, alpha: float = 0.2):
        self.metrics = metrics
        self.alpha = alpha
        self._ewma: dict[str, float] = {}

    def observe(self, namespace: str, rel_err: float) -> None:
        rel_err = abs(rel_err)
        prev = self._ewma.get(namespace)
        cur = (rel_err if prev is None
               else prev + self.alpha * (rel_err - prev))
        self._ewma[namespace] = cur
        m = self.metrics
        m.histogram("replay_drift", namespace=namespace) \
            .observe(rel_err)
        m.gauge("replay_drift_ewma", namespace=namespace).set(cur)

    def ewma(self, namespace: str) -> float:
        """Current EWMA drift for ``namespace`` (0.0 if never fed)."""
        return self._ewma.get(namespace, 0.0)
