"""repro.obs — observability for the scheduler/serving stack (PR 8-9).

Six layers:

* :mod:`repro.obs.trace`   — :class:`ScheduleTrace`, the per-kernel
  admission/completion recorder every simulator feeds via ``trace=``;
  exports Chrome-trace-event JSON (Perfetto) and terminal Gantt.
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` with counters /
  gauges / histograms (seeded reservoir p50/p95/p99); the single sink
  behind ``ScheduleCache.stats()``, the composer counters, and the
  refiners' budget accounting.
* :mod:`repro.obs.profile` — phase-timing conventions
  (:data:`PHASES`) and :func:`phase_breakdown` for the per-step
  compose/guard/refine/execute/audit wall-clock view.
* :mod:`repro.obs.audit`   — :class:`QualityAuditor`, the online
  Fig.-1 sampler: served compositions scored against K seeded random
  orders under the step's own currency, with the paper's 90th
  percentile as a live SLO floor.
* :mod:`repro.obs.latency` — :class:`LatencyTracker` (per-request
  arrival→completion spans with phase attribution, p50/p95/p99 and
  goodput) and :class:`DriftMonitor` (EWMA modelled-vs-revalidated
  replay drift per cache namespace).
* :mod:`repro.obs.export`  — :func:`prometheus_text` exposition for
  any registry and :class:`FlightRecorder`, the JSONL event log with
  a postmortem timeline loader.

Design contract: a ``None`` recorder is zero-cost (every hook is
``if trace is not None`` / ``if recorder is not None``) and an
attached recorder never changes modelled times or served tokens — it
only reads simulator state.  ``tests/test_obs.py`` and
``tests/test_audit.py`` property-test both.
"""

from .audit import QualityAuditor
from .export import FlightRecorder, parse_prometheus_text, prometheus_text
from .latency import DriftMonitor, LatencyTracker
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .profile import PHASES, phase_breakdown
from .trace import ScheduleTrace

__all__ = ["Counter", "DriftMonitor", "FlightRecorder", "Gauge",
           "Histogram", "LatencyTracker", "MetricsRegistry", "PHASES",
           "QualityAuditor", "ScheduleTrace", "parse_prometheus_text",
           "phase_breakdown", "prometheus_text"]
