"""repro.obs — observability for the scheduler/serving stack (PR 8).

Three layers:

* :mod:`repro.obs.trace`   — :class:`ScheduleTrace`, the per-kernel
  admission/completion recorder every simulator feeds via ``trace=``;
  exports Chrome-trace-event JSON (Perfetto) and terminal Gantt.
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` with counters /
  gauges / histograms; the single sink behind
  ``ScheduleCache.stats()``, the composer counters, and the refiners'
  budget accounting.
* :mod:`repro.obs.profile` — phase-timing conventions
  (:data:`PHASES`) and :func:`phase_breakdown` for the per-step
  compose/guard/refine/execute wall-clock view.

Design contract: a ``None`` recorder is zero-cost (every hook is
``if trace is not None``) and an attached recorder never changes
modelled times or served tokens — it only reads simulator state.
``tests/test_obs.py`` property-tests both.
"""

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .profile import PHASES, phase_breakdown
from .trace import ScheduleTrace

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "PHASES", "phase_breakdown", "ScheduleTrace"]
