"""Profiling hooks: phase names and breakdown views.

The engine and composer time their work with
:meth:`repro.obs.metrics.MetricsRegistry.timer` under the
``phase_<name>`` histogram names listed in :data:`PHASES`:

* ``phase_compose`` — everything between "step has a live mix" and
  "rounds are composed" (cache lookups, greedy, guard, refine, warm
  adaptation; recorded by ``ServingEngine.step``);
* ``phase_guard``   — gated/flat guard admission decisions inside the
  composer (a sub-interval of compose);
* ``phase_refine``  — refinement passes inside the composer (also a
  sub-interval of compose, so guard+refine <= compose);
* ``phase_execute`` — running the composed rounds (prefill/decode
  execution; recorded by ``ServingEngine.step``);
* ``phase_audit``   — online quality audits
  (:class:`repro.obs.audit.QualityAuditor`) on the sampled steps —
  kept outside ``phase_compose`` so audit cost never pollutes the
  compose-time series the churn benchmarks guard.

:func:`phase_breakdown` turns a registry into the per-step view
``benchmarks/serving.py`` prints.  Refiners report their own scoring
work under ``refine_evals`` / ``refine_score_s`` when handed a
``metrics=`` registry.
"""

from __future__ import annotations

from .metrics import Histogram, MetricsRegistry

__all__ = ["PHASES", "phase_breakdown"]

#: engine-step phases, in pipeline order; guard and refine are
#: sub-intervals of compose, audit runs on sampled steps only
PHASES = ("compose", "guard", "refine", "execute", "audit")


def phase_breakdown(metrics: MetricsRegistry) -> dict:
    """``{phase: {"calls", "total_s", "mean_s"}}`` for every phase in
    :data:`PHASES` (zeros for phases never entered, so the shape is
    stable across policies)."""
    out = {}
    for ph in PHASES:
        h = metrics.histogram(f"phase_{ph}")
        assert isinstance(h, Histogram)
        out[ph] = {"calls": h.count, "total_s": h.total,
                   "mean_s": h.mean}
    return out
