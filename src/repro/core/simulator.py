"""Execution-time models for a launch order on a multi-unit device.

``RoundSimulator``
    The paper's strict *execution round* abstraction, scalar per unit:
    kernels are admitted in launch order until one fails to fit, which
    closes the round.  A round's duration is its occupancy-adjusted
    roofline time and rounds run back to back.  This is the model the
    paper's narrative reasons with.

``EventSimulator``
    The reference timing model: an event-driven simulation of the
    GigaThread-style block dispatcher over ``n_units`` *individual*
    execution units.  Blocks are dispatched strictly in launch order
    (no lookahead — the false serialisation the paper exploits) to the
    next unit with available resources, round-robin.  Each unit
    progresses at its own occupancy-adjusted roofline rate
    ``lam = min(eff_c * compute_rate / sum_c, eff_m * mem_bw / sum_m)``
    over its resident mix, so

    * compute-bound and memory-bound blocks genuinely overlap,
    * under-occupied units run below peak (latency hiding needs
      parallel slack, and the memory system needs much more of it than
      the ALUs), and
    * heterogeneous block placement causes per-unit load imbalance and
      resource fragmentation — the order-dependent effects that create
      the multi-x spreads of the paper's Table 3.

Both models charge a block's compute and memory work concurrently
(within-block overlap), so a kernel alone runs at its roofline time.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Sequence

from .resources import DeviceModel, KernelProfile

__all__ = ["RoundSimulator", "RoundCheckpoint", "EventSimulator",
           "simulate"]

_EPS = 1e-12


@dataclass(frozen=True)
class RoundCheckpoint:
    """Admission state at one round boundary of a round-model run.

    ``pos`` is the order index of the head kernel when the round
    opened, ``blocks_left`` how many of its per-unit blocks were still
    undispatched (== its full count when the previous round did not
    split it), and ``time`` the cumulative time of all earlier rounds.
    A candidate order that only differs from the recorded one at
    positions >= p can resume from the latest checkpoint whose
    consumed prefix lies strictly before p (produced and consumed by
    :class:`repro.core.refine.DeltaRoundEvaluator`).
    """

    pos: int
    blocks_left: int
    time: float


@dataclass
class RoundSimulator:
    """Reference round model, kept deliberately simple: it is the
    oracle the optimized delta evaluator
    (:class:`repro.core.refine.DeltaRoundEvaluator`) is
    property-tested against for exact equality."""

    device: DeviceModel

    def simulate(self, order: Sequence[KernelProfile]) -> float:
        dev = self.device
        # FIFO of [kernel, blocks still to dispatch on this unit].
        pending: deque[list] = deque(
            [k, k.blocks_per_unit(dev)] for k in order)
        total = 0.0
        while pending:
            used = {d: 0.0 for d in dev.caps}
            blocks, inst, mem = 0, 0.0, 0.0
            while pending:
                k, nb = pending[0]
                d = k.demands
                fit = nb
                for dim in dev.caps:
                    if d[dim] > 0:
                        fit = min(fit, int((dev.cap(dim) - used[dim] + _EPS)
                                           // d[dim]))
                fit = max(min(fit, dev.max_resident - blocks), 0)
                if fit == 0:
                    if blocks == 0:
                        fit = 1  # oversized block: runs alone regardless
                    else:
                        break  # strict FIFO: head closes the round
                for dim in dev.caps:
                    used[dim] += d[dim] * fit
                blocks += fit
                inst += k.inst_per_block * fit
                mem += k.mem_per_block() * fit
                pending[0][1] -= fit
                if pending[0][1] == 0:
                    pending.popleft()
                if pending and pending[0][0] is k:
                    break  # partially admitted head: unit is full
            eff_c = max(dev.compute_efficiency(used), _EPS)
            eff_m = max(dev.memory_efficiency(used), _EPS)
            total += max(inst / (dev.compute_rate * eff_c),
                         mem / (dev.mem_bw * eff_m))
        return total


@dataclass
class _Cohort:
    """Blocks of one kernel admitted to one unit at the same instant."""

    kernel: KernelProfile
    n_blocks: int
    frac_left: float = 1.0


@dataclass
class _Unit:
    used: dict[str, float]
    n_resident: int = 0
    cohorts: list[_Cohort] = field(default_factory=list)
    lam: float = 0.0

    def recompute_rate(self, dev: DeviceModel) -> None:
        if not self.cohorts:
            self.lam = 0.0
            return
        sum_c = sum(c.kernel.inst_per_block * c.n_blocks for c in self.cohorts)
        sum_m = sum(c.kernel.mem_per_block() * c.n_blocks for c in self.cohorts)
        eff_c = max(dev.compute_efficiency(self.used), _EPS)
        eff_m = max(dev.memory_efficiency(self.used), _EPS)
        self.lam = min(dev.compute_rate * eff_c / max(sum_c, _EPS),
                       dev.mem_bw * eff_m / max(sum_m, _EPS))


@dataclass
class EventSimulator:
    device: DeviceModel

    def simulate(self, order: Sequence[KernelProfile]) -> float:
        dev = self.device
        units = [_Unit(used={d: 0.0 for d in dev.caps})
                 for _ in range(dev.n_units)]
        # Strict-FIFO dispatch queue of [kernel, blocks left to place].
        pending: deque[list] = deque([k, k.n_blocks] for k in order)
        rr = 0  # round-robin dispatch pointer

        def fits(u: _Unit, k: KernelProfile) -> bool:
            if u.n_resident + 1 > dev.max_resident:
                return False
            return all(u.used[dim] + k.demands[dim] <= dev.cap(dim) + _EPS
                       for dim in dev.caps)

        def try_admit() -> None:
            nonlocal rr
            touched: set[int] = set()
            while pending:
                k, _ = pending[0]
                placed = False
                for off in range(dev.n_units):
                    ui = (rr + off) % dev.n_units
                    u = units[ui]
                    if fits(u, k):
                        for dim in dev.caps:
                            u.used[dim] += k.demands[dim]
                        u.n_resident += 1
                        # Merge into a same-instant cohort if present.
                        for c in u.cohorts:
                            if c.kernel is k and c.frac_left == 1.0:
                                c.n_blocks += 1
                                break
                        else:
                            u.cohorts.append(_Cohort(k, 1))
                        touched.add(ui)
                        rr = (ui + 1) % dev.n_units
                        pending[0][1] -= 1
                        if pending[0][1] == 0:
                            pending.popleft()
                        placed = True
                        break
                if not placed:
                    break  # head blocks the queue (strict FIFO)
            for ui in touched:
                units[ui].recompute_rate(dev)

        try_admit()
        t = 0.0
        guard = 0
        while any(u.cohorts for u in units) or pending:
            guard += 1
            if guard > 1_000_000:
                raise RuntimeError("EventSimulator failed to converge")
            if not any(u.cohorts for u in units):
                # Head block larger than an empty unit: force it through
                # alone at whatever occupancy it achieves (degenerate).
                k, nb = pending.popleft()
                t += nb / dev.n_units * max(
                    k.inst_per_block / dev.compute_rate,
                    k.mem_per_block() / dev.mem_bw)
                try_admit()
                continue
            dt = min(c.frac_left / u.lam
                     for u in units if u.cohorts for c in u.cohorts)
            t += dt
            freed = False
            for u in units:
                if not u.cohorts:
                    continue
                done = []
                for c in u.cohorts:
                    c.frac_left -= u.lam * dt
                    if c.frac_left <= 1e-9:
                        done.append(c)
                if done:
                    freed = True
                    for c in done:
                        u.cohorts.remove(c)
                        for dim in dev.caps:
                            u.used[dim] -= c.kernel.demands[dim] * c.n_blocks
                        u.n_resident -= c.n_blocks
                    u.recompute_rate(dev)
            if freed:
                try_admit()
        return t


def simulate(order: Sequence[KernelProfile], device: DeviceModel,
             model: str = "event") -> float:
    """Convenience wrapper: execution time of ``order`` on ``device``."""
    if model == "event":
        return EventSimulator(device).simulate(order)
    if model == "round":
        return RoundSimulator(device).simulate(order)
    raise ValueError(f"unknown model {model!r}")
