"""Execution-time models for a launch order on a multi-unit device.

``RoundSimulator``
    The paper's strict *execution round* abstraction, scalar per unit:
    kernels are admitted in launch order until one fails to fit, which
    closes the round.  A round's duration is its occupancy-adjusted
    roofline time and rounds run back to back.  This is the model the
    paper's narrative reasons with.

``EventSimulator``
    The reference timing model: an event-driven simulation of the
    GigaThread-style block dispatcher over ``n_units`` *individual*
    execution units.  Blocks are dispatched strictly in launch order
    (no lookahead — the false serialisation the paper exploits) to the
    next unit with available resources, round-robin.  Each unit
    progresses at its own occupancy-adjusted roofline rate
    ``lam = min(eff_c * compute_rate / sum_c, eff_m * mem_bw / sum_m)``
    over its resident mix, so

    * compute-bound and memory-bound blocks genuinely overlap,
    * under-occupied units run below peak (latency hiding needs
      parallel slack, and the memory system needs much more of it than
      the ALUs), and
    * heterogeneous block placement causes per-unit load imbalance and
      resource fragmentation — the order-dependent effects that create
      the multi-x spreads of the paper's Table 3.

Both models charge a block's compute and memory work concurrently
(within-block overlap), so a kernel alone runs at its roofline time.

Both models are *checkpointable*: they can record their full dispatcher
state at admission boundaries (:class:`RoundCheckpoint` /
:class:`EventCheckpoint`) and resume a simulation from a recorded
checkpoint.  A candidate order that agrees with the recorded order on
every position before the checkpoint replays the identical float
accumulation from there on, which is what makes suffix re-simulation
(:class:`repro.core.refine.DeltaEvaluator`) exact.

Both models treat every kernel as free to co-schedule with every
other.  Orders that carry precedence edges are scored by the gated
extension of the event model —
:class:`repro.graph.streams.DagEventSimulator`, which holds a kernel
at the queue head until its predecessors drain, shares this module's
:class:`EventCheckpoint` format (the gate state is derived on resume)
and is delta-evaluated by :class:`repro.graph.delta.GatedDeltaEvaluator`.

Both models also have *batched* twins that evaluate whole ``(B, n)``
candidate batches at once from checkpoint-stitched suffixes —
:class:`repro.core.batched.BatchedRoundSim` (bit-exact against the
round model) and :class:`repro.core.batched.BatchedEventSim` (within
pure summation-order float noise of the event/gated models) — plus an
f32 single-order scan kernel of the event dispatcher,
:func:`repro.kernels.event_scan.event_scan_core`, dispatchable as
``jit(vmap)`` or a Pallas grid.  This module stays the semantic
definition: every batched/kernel path is property-tested against the
simulators here (``tests/test_batched.py``).
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Sequence

from .resources import DeviceModel, KernelProfile

__all__ = ["RoundSimulator", "RoundCheckpoint", "EventSimulator",
           "EventCheckpoint", "simulate"]

_EPS = 1e-12


@dataclass(frozen=True)
class RoundCheckpoint:
    """Admission state at one round boundary of a round-model run.

    ``pos`` is the order index of the head kernel when the round
    opened, ``blocks_left`` how many of its per-unit blocks were still
    undispatched (== its full count when the previous round did not
    split it), and ``time`` the cumulative time of all earlier rounds.
    A candidate order that only differs from the recorded one at
    positions >= p can resume from the latest checkpoint whose
    consumed prefix lies strictly before p (produced and consumed by
    :class:`repro.core.refine.DeltaEvaluator`).
    """

    pos: int
    blocks_left: int
    time: float


@dataclass
class RoundSimulator:
    """Reference round model, kept deliberately simple: it is the
    oracle the optimized delta evaluator
    (:class:`repro.core.refine.DeltaEvaluator`) is
    property-tested against for exact equality."""

    device: DeviceModel

    def simulate(self, order: Sequence[KernelProfile], *,
                 trace=None) -> float:
        """Execution time of ``order`` under the round model.

        ``trace`` (a :class:`repro.obs.ScheduleTrace`) records one
        span per kernel per round — the round model is scalar per
        unit, so all spans land on unit 0 — plus a round-boundary
        instant when each round closes.  Tracing only reads state:
        the returned float is bit-identical with and without it.
        """
        dev = self.device
        # FIFO of [kernel, blocks still to dispatch on this unit].
        pending: deque[list] = deque(
            [k, k.blocks_per_unit(dev)] for k in order)
        total = 0.0
        r_idx = 0
        while pending:
            used = {d: 0.0 for d in dev.caps}
            blocks, inst, mem = 0, 0.0, 0.0
            members: list = []
            while pending:
                k, nb = pending[0]
                d = k.demands
                fit = nb
                for dim in dev.caps:
                    if d[dim] > 0:
                        fit = min(fit, int((dev.cap(dim) - used[dim] + _EPS)
                                           // d[dim]))
                fit = max(min(fit, dev.max_resident - blocks), 0)
                if fit == 0:
                    if blocks == 0:
                        fit = 1  # oversized block: runs alone regardless
                    else:
                        break  # strict FIFO: head closes the round
                for dim in dev.caps:
                    used[dim] += d[dim] * fit
                blocks += fit
                inst += k.inst_per_block * fit
                mem += k.mem_per_block() * fit
                if trace is not None:
                    members.append((k.name, fit))
                pending[0][1] -= fit
                if pending[0][1] == 0:
                    pending.popleft()
                if pending and pending[0][0] is k:
                    break  # partially admitted head: unit is full
            eff_c = max(dev.compute_efficiency(used), _EPS)
            eff_m = max(dev.memory_efficiency(used), _EPS)
            r_start = total
            total += max(inst / (dev.compute_rate * eff_c),
                         mem / (dev.mem_bw * eff_m))
            if trace is not None:
                for name, nb in members:
                    trace.span(0, name, r_start, total, blocks=nb,
                               cat="round-member")
                trace.instant(f"round {r_idx}", total, unit=0,
                              cat="round")
                trace.add_busy(0, total - r_start)
            r_idx += 1
        return total


@dataclass
class _Cohort:
    """Blocks of one kernel admitted to one unit at the same instant.

    ``t_admit`` tags the admission instant: blocks only merge into a
    cohort admitted at the *same* simulation time.  (Merging on
    ``frac_left == 1.0`` alone — the pre-fix behaviour — let a block
    admitted at a later instant join an old cohort whose progress had
    underflowed to zero, violating the same-instant invariant and
    making checkpoint resume non-reproducible.)
    """

    kernel: KernelProfile
    n_blocks: int
    frac_left: float = 1.0
    t_admit: float = 0.0


@dataclass
class _Unit:
    used: dict[str, float]
    n_resident: int = 0
    cohorts: list[_Cohort] = field(default_factory=list)
    lam: float = 0.0

    def recompute_rate(self, dev: DeviceModel) -> None:
        if not self.cohorts:
            self.lam = 0.0
            return
        sum_c = sum(c.kernel.inst_per_block * c.n_blocks for c in self.cohorts)
        sum_m = sum(c.kernel.mem_per_block() * c.n_blocks for c in self.cohorts)
        eff_c = max(dev.compute_efficiency(self.used), _EPS)
        eff_m = max(dev.memory_efficiency(self.used), _EPS)
        self.lam = min(dev.compute_rate * eff_c / max(sum_c, _EPS),
                       dev.mem_bw * eff_m / max(sum_m, _EPS))


@dataclass(frozen=True)
class EventCheckpoint:
    """Full dispatcher state at the instant the event-model dispatcher
    first examines the kernel at order position ``pos``.

    At that instant no block of position ``pos`` has been placed
    (``blocks_left`` equals its full grid size), so the captured state
    — per-unit ``used`` vectors, resident-block counts, cohort
    fractions with their admission instants, the round-robin pointer
    and the cumulative time — depends only on kernels at positions
    ``< pos``.  A candidate order agreeing with the recorded one at
    every position ``< first_changed`` can therefore resume from the
    checkpoint at ``pos == first_changed`` (or any earlier one) and
    replay the identical float accumulation.

    ``units`` is a tuple with one entry per execution unit::

        (used, n_resident, cohorts)

    where ``used`` is a tuple of floats in ``device.caps`` order and
    ``cohorts`` is a tuple of ``(kernel, n_blocks, frac_left,
    t_admit)`` tuples.  Unit rates (``lam``) are derived state and are
    recomputed on resume.
    """

    pos: int
    blocks_left: int
    time: float
    rr: int
    units: tuple

    @staticmethod
    def capture(pos: int, blocks_left: int, time: float, rr: int,
                units: Sequence[_Unit], dims: Sequence[str]
                ) -> "EventCheckpoint":
        return EventCheckpoint(
            pos=pos, blocks_left=blocks_left, time=time, rr=rr,
            units=tuple(
                (tuple(u.used[d] for d in dims), u.n_resident,
                 tuple((c.kernel, c.n_blocks, c.frac_left, c.t_admit)
                       for c in u.cohorts))
                for u in units))


@dataclass
class EventSimulator:
    """Reference event-driven per-unit dispatcher model.

    This is the oracle implementation: deliberately dict-based and
    close to the prose description above.  The optimized twin
    (:class:`repro.core.refine._FastEventSim`) replays the identical
    arithmetic over pre-resolved tuples and is property-tested against
    this class for exact float equality, full runs and checkpoint
    resumes alike.
    """

    device: DeviceModel

    def simulate(self, order: Sequence[KernelProfile], *,
                 start_state: EventCheckpoint | None = None,
                 record: bool = False, trace=None):
        """Execution time of ``order``.

        ``start_state`` resumes from a previously recorded
        :class:`EventCheckpoint`; ``order`` must agree with the
        checkpoint's source order at every position before
        ``start_state.pos`` (positions from there on are re-dispatched
        with their full block counts, so the kernel *at*
        ``start_state.pos`` may differ).  With ``record=True`` returns
        ``(time, checkpoints)`` — one checkpoint per order position,
        captured the first time the dispatcher examines it; otherwise
        returns the time alone.

        ``trace`` (a :class:`repro.obs.ScheduleTrace`) records one
        span per drained cohort — kernel name, unit, admission
        instant to drain instant, block count — plus per-unit busy
        time for every ``dt`` the dispatcher advances.  Tracing only
        reads state (every hook is ``if trace is not None``), so
        modelled times are bit-identical with and without it.  On a
        ``start_state`` resume, cohorts restored from the checkpoint
        keep their original (pre-resume) admission instants while
        busy time accrues only from the resume point, so the
        span/busy conservation property only holds for fresh runs.
        """
        dev = self.device
        dims = tuple(dev.caps)
        if start_state is None:
            units = [_Unit(used={d: 0.0 for d in dims})
                     for _ in range(dev.n_units)]
            start_pos, rr, t = 0, 0, 0.0
        else:
            units = []
            for used, n_res, cohorts in start_state.units:
                u = _Unit(used=dict(zip(dims, used)), n_resident=n_res,
                          cohorts=[_Cohort(k, nb, fl, ta)
                                   for k, nb, fl, ta in cohorts])
                u.recompute_rate(dev)
                units.append(u)
            start_pos, rr, t = (start_state.pos, start_state.rr,
                                start_state.time)
        # Strict-FIFO dispatch queue of [kernel, blocks left, position].
        pending: deque[list] = deque(
            [order[p], order[p].n_blocks, p]
            for p in range(start_pos, len(order)))
        ckpts: list[EventCheckpoint] = []
        next_ckpt = start_pos  # first order position not yet examined

        def fits(u: _Unit, k: KernelProfile) -> bool:
            if u.n_resident + 1 > dev.max_resident:
                return False
            return all(u.used[dim] + k.demands[dim] <= dev.cap(dim) + _EPS
                       for dim in dev.caps)

        def try_admit() -> None:
            nonlocal rr, next_ckpt
            touched: set[int] = set()
            while pending:
                k, _, pos = pending[0]
                if record and pos == next_ckpt:
                    # First examination of position ``pos``: no block
                    # of it placed yet, state depends only on earlier
                    # positions — the admission boundary a suffix
                    # re-simulation can resume from.
                    ckpts.append(EventCheckpoint.capture(
                        pos, pending[0][1], t, rr, units, dims))
                    next_ckpt = pos + 1
                placed = False
                for off in range(dev.n_units):
                    ui = (rr + off) % dev.n_units
                    u = units[ui]
                    if fits(u, k):
                        for dim in dev.caps:
                            u.used[dim] += k.demands[dim]
                        u.n_resident += 1
                        # Merge only into a cohort admitted at this
                        # same instant (see _Cohort.t_admit).
                        for c in u.cohorts:
                            if c.kernel is k and c.t_admit == t:
                                c.n_blocks += 1
                                break
                        else:
                            u.cohorts.append(_Cohort(k, 1, t_admit=t))
                        touched.add(ui)
                        rr = (ui + 1) % dev.n_units
                        pending[0][1] -= 1
                        if pending[0][1] == 0:
                            pending.popleft()
                        placed = True
                        break
                if not placed:
                    break  # head blocks the queue (strict FIFO)
            for ui in touched:
                units[ui].recompute_rate(dev)

        try_admit()
        guard = 0
        while any(u.cohorts for u in units) or pending:
            guard += 1
            if guard > 1_000_000:
                raise RuntimeError("EventSimulator failed to converge")
            if not any(u.cohorts for u in units):
                # Head block larger than an empty unit: it runs alone,
                # one block per unit per pass, at the occupancy a
                # single resident block achieves — the same
                # "oversized block runs alone" rule (and the same
                # float accumulation) as RoundSimulator's forced
                # single-block rounds.
                k, nb, pos = pending.popleft()
                used1 = {dim: k.demands[dim] for dim in dev.caps}
                eff_c = max(dev.compute_efficiency(used1), _EPS)
                eff_m = max(dev.memory_efficiency(used1), _EPS)
                t1 = max(k.inst_per_block / (dev.compute_rate * eff_c),
                         k.mem_per_block() / (dev.mem_bw * eff_m))
                for p in range(math.ceil(nb / dev.n_units)):
                    t += t1
                    if trace is not None:
                        for ui in range(min(dev.n_units,
                                            nb - p * dev.n_units)):
                            trace.span(ui, k.name, t - t1, t,
                                       blocks=1, cat="solo")
                            trace.add_busy(ui, t1)
                try_admit()
                continue
            dt = min(c.frac_left / u.lam
                     for u in units if u.cohorts for c in u.cohorts)
            t += dt
            freed = False
            for ui, u in enumerate(units):
                if not u.cohorts:
                    continue
                if trace is not None:
                    trace.add_busy(ui, dt)
                done = []
                for c in u.cohorts:
                    c.frac_left -= u.lam * dt
                    if c.frac_left <= 1e-9:
                        done.append(c)
                if done:
                    freed = True
                    for c in done:
                        u.cohorts.remove(c)
                        for dim in dev.caps:
                            u.used[dim] -= c.kernel.demands[dim] * c.n_blocks
                        u.n_resident -= c.n_blocks
                        if trace is not None:
                            trace.span(ui, c.kernel.name, c.t_admit, t,
                                       blocks=c.n_blocks)
                    u.recompute_rate(dev)
            if freed:
                try_admit()
        if record:
            return t, ckpts
        return t


def simulate(order: Sequence[KernelProfile], device: DeviceModel,
             model: str = "event", trace=None) -> float:
    """Convenience wrapper: execution time of ``order`` on ``device``.
    ``trace`` forwards to the chosen simulator's recorder hook."""
    if model == "event":
        return EventSimulator(device).simulate(order, trace=trace)
    if model == "round":
        return RoundSimulator(device).simulate(order, trace=trace)
    raise ValueError(f"unknown model {model!r}")
