"""ScoreGen / ProfileCombine — lines 14-27 of Algorithm 1 in the paper.

The score between two kernels (or a virtual combined kernel and a
candidate) rewards

1. *balanced residual capacity*: for every resource dimension, the
   fraction of the per-unit capacity left over after co-residency adds
   to the score (clamped at 0), and
2. *opposing compute/memory character*: if one kernel sits on each side
   of the balanced ratio ``R_B``, the score additionally rewards a
   block-weighted combined ratio close to ``R_B``.

Pairs that cannot co-reside within one execution round score 0.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from .resources import DeviceModel, KernelProfile

__all__ = [
    "pair_score",
    "score_matrix",
    "score_vector",
    "profile_combine",
    "fits_together",
    "fits_alone",
    "combined_ratio",
]


def _per_unit(k: KernelProfile, device: DeviceModel) -> dict[str, float]:
    return k.per_unit_demand(device)


def fits_alone(k: KernelProfile, device: DeviceModel) -> bool:
    d = _per_unit(k, device)
    return all(d[dim] <= device.cap(dim) for dim in device.caps)


def fits_together(a: KernelProfile, b: KernelProfile,
                  device: DeviceModel) -> bool:
    da, db = _per_unit(a, device), _per_unit(b, device)
    if a.blocks_per_unit(device) + b.blocks_per_unit(device) > device.max_resident:
        return False
    return all(da[dim] + db[dim] <= device.cap(dim) for dim in device.caps)


def combined_ratio(a: KernelProfile, b: KernelProfile,
                   mode: str = "block_mean") -> float:
    """Combined inst/bytes ratio of a co-scheduled pair.

    "block_mean" — the paper's ProfileCombine (line 26): block-weighted
    average of R_i.  "harmonic" — total work / total bytes, the
    physically correct combined intensity (beyond-paper; required when
    R_i span orders of magnitude)."""
    if mode == "harmonic":
        # Guard r == 0 (pure-memory kernels report zero intensity): the
        # clamped denominator keeps the combined ratio finite and ~0,
        # i.e. the pair is treated as memory-bound, which is the
        # physically right limit.
        work = a.inst_per_block * a.n_blocks + b.inst_per_block * b.n_blocks
        byts = (a.inst_per_block * a.n_blocks / max(a.r, 1e-30) +
                b.inst_per_block * b.n_blocks / max(b.r, 1e-30))
        return work / max(byts, 1e-30)
    w = a.n_blocks + b.n_blocks
    return (a.n_blocks * a.r + b.n_blocks * b.r) / w


def pair_score(a: KernelProfile, b: KernelProfile,
               device: DeviceModel) -> float:
    """Score of co-scheduling ``a`` and ``b`` (Algorithm 1 lines 17-22)."""
    if not fits_together(a, b, device):
        return 0.0
    da, db = _per_unit(a, device), _per_unit(b, device)
    s = 0.0
    for dim in device.caps:
        cap = device.cap(dim)
        s += device.residual_weight * max((cap - da[dim] - db[dim]) / cap,
                                          0.0)
    rb = device.r_balanced
    if (a.r <= rb <= b.r) or (b.r <= rb <= a.r):
        rc = combined_ratio(a, b, device.combined_r)
        s += device.r_weight * max(1.0 - abs(rc - rb) / rb, 0.0)
    return s


def score_matrix(ks_m: Sequence[KernelProfile], ks_n: Sequence[KernelProfile],
                 device: DeviceModel) -> list[list[float]]:
    """ScoreGen(K_M, K_N): full pairwise score matrix."""
    return [[pair_score(a, b, device) for b in ks_n] for a in ks_m]


def score_vector(comb: KernelProfile, candidates: Sequence[KernelProfile],
                 device: DeviceModel) -> list[float]:
    """ScoreGen with a 1-D result: virtual combined kernel vs candidates."""
    return [pair_score(comb, c, device) for c in candidates]


def profile_combine(a: KernelProfile, b: KernelProfile,
                    device: DeviceModel) -> KernelProfile:
    """ProfileCombine (Algorithm 1 lines 25-27).

    Produces the virtual kernel representing the whole execution round:
    its per-unit footprint is the *sum* of its members' per-unit
    footprints (stored pre-aggregated so it is never re-multiplied by a
    block count).  Block counts add; the ratio combines block-weighted.
    """
    da, db = a.per_unit_demand(device), b.per_unit_demand(device)
    demands = {k: da[k] + db[k] for k in da}
    return KernelProfile(
        name=f"({a.name}+{b.name})",
        n_blocks=a.n_blocks + b.n_blocks,
        demands=demands,
        inst_per_block=a.inst_per_block + b.inst_per_block,
        r=combined_ratio(a, b, device.combined_r),
        agg_blocks_per_unit=a.blocks_per_unit(device) + b.blocks_per_unit(device),
    )
