"""TPU adaptation of the paper's reordering idea.

A TPU core has no notion of concurrent kernel co-residency: one XLA
program owns the chip.  The transferable insight of the paper is
*symbiotic round packing* — group independent work items into
sequential "rounds" so that every round (a) saturates the bounding
resource dimensions evenly, and (b) mixes compute-bound with
memory-bound work so the round's arithmetic intensity lands near the
hardware balance point ``R_B = peak_FLOPs / HBM_bw``.

On TPU the natural unit of independent work is a *serving micro-batch
entry* (a prefill chunk is compute-bound, a decode step is
memory-bound) or a *pipeline task* (a gradient all-reduce bucket is
interconnect-bound, a backward matmul is compute-bound).  This module
maps such tasks onto :class:`KernelProfile` so the unmodified
Algorithm 1 composes the rounds; the serving engine
(:mod:`repro.serve.scheduler`) and the overlap scheduler
(:mod:`repro.train.overlap`) build on it.

Resource dimensions for a serving round on a v5e core:

* ``hbm``   — bytes the round's working set streams from HBM (weights are
  counted once per round, KV reads per request),
* ``vmem``  — peak on-chip residency claimed by the round's kernels,
* ``slots`` — token budget per round (compiled batch geometry).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from .fastscore import greedy_order_fast
from .resources import TPU_V5E_UNIT, DeviceModel, KernelProfile
from .scheduler import Schedule, greedy_order

__all__ = [
    "TpuWorkItem",
    "prefill_profile",
    "decode_profile",
    "make_serving_device",
    "compose_rounds",
]


@dataclass(frozen=True)
class TpuWorkItem:
    """An independent unit of TPU work with a roofline cost model.

    ``hbm_bytes`` is the item's *marginal* HBM traffic; the shared
    weight stream is a per-round fixed cost (see :func:`round_time`).
    ``intensity_hint`` is the standalone arithmetic intensity used as
    the paper's ``R_i`` (it includes the weight stream the item would
    pay alone, which is what makes decode memory-bound)."""

    name: str
    flops: float
    hbm_bytes: float
    vmem_bytes: float
    tokens: int
    intensity_hint: float | None = None
    #: Weight bytes THIS item's computation streams (a layer stage's
    #: parameter share, bf16).  0.0 for whole-request items, whose
    #: shared weight stream the engine charges per round via
    #: ``round_time(..., weights_bytes)``; per-stage items from
    #: ``repro.graph.trace_arch`` carry their own share so a round's
    #: weight traffic can be summed over the *distinct* stages present
    #: (co-scheduled copies of one stage share its stream).
    weight_bytes: float = 0.0

    @property
    def intensity(self) -> float:
        if self.intensity_hint is not None:
            return self.intensity_hint
        return self.flops / max(self.hbm_bytes, 1.0)

    def profile(self) -> KernelProfile:
        return KernelProfile(
            name=self.name,
            n_blocks=1,
            demands={"vmem": self.vmem_bytes, "hbm": self.hbm_bytes,
                     "slots": float(self.tokens)},
            inst_per_block=self.flops,
            r=self.intensity,
        )


def prefill_profile(name: str, *, n_params: float, seq_len: int,
                    kv_bytes_per_token: float,
                    vmem_tile_bytes: float = 8 << 20) -> TpuWorkItem:
    """A prefill chunk: ~2*N*s FLOPs; *marginal* HBM traffic is the KV
    it writes plus its activation working set.  The weight stream is a
    per-round fixed cost (shared by every co-scheduled item) and is
    accounted by :func:`round_time`, not per item.

    Strongly compute-bound: intensity ~ 2*N / (weights/round) >> R_B.
    """
    flops = 2.0 * n_params * seq_len
    hbm = seq_len * kv_bytes_per_token * 2.0  # KV write + activation traffic
    r = 2.0 * n_params * seq_len / (2.0 * n_params + hbm)
    return TpuWorkItem(name, flops=flops, hbm_bytes=hbm,
                       vmem_bytes=vmem_tile_bytes, tokens=seq_len,
                       intensity_hint=r)


def decode_profile(name: str, *, n_params: float, kv_len: int,
                   kv_bytes_per_token: float,
                   vmem_tile_bytes: float = 4 << 20) -> TpuWorkItem:
    """One decode token: 2*N FLOPs; marginal HBM traffic is its KV-cache
    read.  Intensity (counting the shared weight stream it would incur
    alone) ~ 1: strongly memory-bound."""
    flops = 2.0 * n_params + 2.0 * kv_len * kv_bytes_per_token / 2.0
    hbm = kv_len * kv_bytes_per_token
    r = flops / (2.0 * n_params + hbm)
    return TpuWorkItem(name, flops=flops, hbm_bytes=hbm,
                       vmem_bytes=vmem_tile_bytes, tokens=1,
                       intensity_hint=r)


def round_time(items: Sequence["TpuWorkItem"], device: DeviceModel,
               weights_bytes: float) -> float:
    """Occupancy-adjusted roofline time of ONE execution round.

    The weight stream is charged once per round — the sharing that
    makes symbiotic prefill+decode rounds pay off.  Memory streams
    (weights, KV) are long contiguous DMA reads and saturate HBM at any
    batch size; occupancy (token rows) only gates the MXU."""
    if not items:
        return 0.0
    sum_c = sum(it.flops for it in items)
    sum_m = weights_bytes + sum(it.hbm_bytes for it in items)
    used = {device.sat_dim: float(sum(it.tokens for it in items))}
    eff_c = max(device.compute_efficiency(used), 1e-9)
    return max(sum_c / (device.compute_rate * eff_c),
               sum_m / device.mem_bw)


def schedule_time(rounds: Sequence[Sequence["TpuWorkItem"]],
                  device: DeviceModel, weights_bytes: float) -> float:
    return sum(round_time(r, device, weights_bytes) for r in rounds)


def fifo_rounds(items: Sequence["TpuWorkItem"],
                device: DeviceModel) -> list[list["TpuWorkItem"]]:
    """Arrival-order round packing (the baseline scheduler)."""
    rounds: list[list[TpuWorkItem]] = []
    cur: list[TpuWorkItem] = []
    used = {d: 0.0 for d in device.caps}
    for it in items:
        dem = it.profile().demands
        fits = all(used[k] + dem[k] <= device.cap(k) for k in used)
        if not fits and cur:
            rounds.append(cur)
            cur, used = [], {d: 0.0 for d in device.caps}
        cur.append(it)
        for k in used:
            used[k] += dem[k]
    if cur:
        rounds.append(cur)
    return rounds


def make_serving_device(*, hbm_round_budget: float = 8 << 30,
                        token_budget: int = 4096,
                        vmem_budget: float = 96 << 20,
                        n_units: int = 1) -> DeviceModel:
    """A v5e core viewed as one execution unit for round composition.

    ``n_units > 1`` models a multi-core serving slice (a v5e-N pod
    slice): every core carries its own budgets (``caps`` are per unit)
    and its own roofline rates; the event dispatcher round-robins work
    items across cores while dependent chains serialize through the
    ready-set gate (:class:`repro.graph.streams.DagEventSimulator`).
    This is the regime where the paper's placement effects exist at
    all — per-core load imbalance and under-occupancy make the gated
    makespan genuinely order-sensitive, which single-core round
    composition (aligned rounds, one unit) is blind to.
    """
    base = TPU_V5E_UNIT
    return DeviceModel(
        name=("tpu_v5e_round" if n_units == 1
              else f"tpu_v5e_round_x{n_units}"),
        n_units=n_units,
        caps={"vmem": vmem_budget, "hbm": hbm_round_budget,
              "slots": float(token_budget)},
        max_resident=token_budget,
        compute_rate=base.compute_rate,
        mem_bw=base.mem_bw,
        r_balanced=base.r_balanced,
        sat_dim=base.sat_dim,
        sat_compute=base.sat_compute,
        sat_memory=base.sat_memory,
        # TPU-tuned ScoreGen weights (see DeviceModel docstring).
        r_weight=4.0,
        residual_weight=0.5,
        combined_r="harmonic",
    )


def compose_rounds(items: Sequence[TpuWorkItem],
                   device: DeviceModel | None = None,
                   method: str = "fast") -> Schedule:
    """Run the paper's Algorithm 1 over TPU work items.

    Returns the round-structured schedule; the serving engine executes
    one round per ``serve_step`` dispatch.  ``method="fast"`` (default)
    uses the incremental vectorized scheduler
    (:mod:`repro.core.fastscore`), which produces identical rounds to
    ``method="reference"`` in ``O(n^2)`` instead of ``O(R * n^2)``
    Python-level ScoreGen reruns — the difference between microseconds
    and seconds per serving step at production queue depths.
    ``method="reference"`` runs the pure-Python test-only oracle and
    exists solely for equivalence checks; no production caller should
    select it.
    """
    device = device or make_serving_device()
    profiles = [it.profile() for it in items]
    if method == "reference":
        return greedy_order(profiles, device)
    return greedy_order_fast(profiles, device)
