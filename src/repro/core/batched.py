"""Batched candidate evaluation: score and refine many orders per
dispatch (ISSUE 6 tentpole).

The refinement loop (:mod:`repro.core.refine`) was the optimizer's own
bottleneck: `pair_score_matrix` is host float64 NumPy and the fast
simulators are pure-Python tuple loops, so every candidate suffix is
re-simulated one at a time.  Following the dispatch discipline of the
gstaichi exemplar (each device dispatch must carry enough work to hide
its launch cost) and the batched-over-sequential argument of Pati et
al. (arXiv 2409.02227), this module evaluates **B candidate orders per
dispatch**:

* :func:`pair_score_matrix_batched` — the ScoreGen pair matrix in
  float32 on the jnp backend (packed once per
  :class:`~repro.core.fastscore.ProfileTable`), with a NumPy float32
  fallback when jax is unavailable and a documented tolerance audit
  (:func:`audit_pair_scores`) against the float64 reference.  The
  greedy itself keeps consuming the float64 matrix — its tie-breaking
  is bit-exact by contract — so the f32 path is for batched evaluation
  and device-resident scoring only.
* :class:`BatchedRoundSim` / :class:`BatchedEventSim` — lockstep
  vectorized twins of :class:`repro.core.refine._FastRoundSim` /
  ``_FastEventSim`` (and, with precedence arrays, of
  :class:`repro.graph.delta._FastGatedSim`): all B candidates advance
  together through admission/completion steps on ``(B, U, C)`` state
  arrays, resuming from per-candidate checkpoint-stitched suffixes.
  The round engine replays the reference float64 accumulation
  operation-for-operation (exact); the event/gated engines vectorize
  the round-robin first-fit block admission as *cyclic dealing* (see
  :meth:`BatchedEventSim._deal`) whose allocation provably equals the
  reference's block-by-block placement — only the float accumulation
  *order* differs (``used += k * dem`` vs k sequential adds), bounded
  by :data:`EVENT_TIME_RTOL`.
* :func:`refine_order_batched` — the batched move evaluator behind
  ``refine_order(..., batch_size=)`` and its DAG/slice counterparts:
  the swap/reinsert neighborhood is generated as a ``(B, n)`` order
  batch, all B candidates are delta-evaluated in one vectorized pass,
  and the **best improving move per batch** is accepted instead of the
  first-improving one.  Budget accounting is unchanged (every
  candidate charged its suffix fraction in full-simulation
  equivalents).  Because the vectorized times are used only to *rank*
  moves, every acceptance is re-verified by the sequential
  :class:`~repro.core.refine.DeltaEvaluator` before it lands — the
  accepted trajectory stays in the exact simulator currency, which is
  what pins refined quality at no-worse-than-input and keeps the
  round-model result set bit-equal to sequential evaluation.

The compiled counterpart of the event engine — the admission/
completion scan as a Pallas kernel with an interpret-mode CPU path —
lives in :mod:`repro.kernels.event_scan`; this module is dependency
free (NumPy only) so tier-1 tests never require a device.
"""

from __future__ import annotations

from time import perf_counter
from typing import Callable, Sequence

import numpy as np

from .fastscore import ProfileTable
from .resources import DeviceModel, KernelProfile
from .simulator import EventCheckpoint, RoundCheckpoint

try:  # pragma: no cover - exercised only where jax is present
    import jax
    import jax.numpy as jnp
    HAS_JAX = True
except Exception:  # pragma: no cover
    jax = None
    jnp = None
    HAS_JAX = False

__all__ = ["HAS_JAX", "F32_SCORE_RTOL", "EVENT_TIME_RTOL",
           "pair_score_matrix_batched", "audit_pair_scores",
           "PackedKernels", "BatchedRoundSim", "BatchedEventSim",
           "refine_order_batched"]

#: Documented float32 tolerance of :func:`pair_score_matrix_batched`
#: against the float64 reference ``pair_score_matrix``: scores are
#: sums of O(D) ratio terms of well-scaled magnitudes, so the f32
#: relative error stays within a few ulps (audited by
#: :func:`audit_pair_scores`; property-tested in tests/test_batched.py).
F32_SCORE_RTOL = 1e-5

#: Documented tolerance of the vectorized event/gated engines against
#: the sequential fast simulators: the dealing step accumulates
#: ``used`` and cohort work sums with a different float association
#: than the reference's block-by-block loop, so modelled times agree
#: to this *relative* tolerance rather than bit-exactly (the round
#: engine, which replays the reference op order, is exact).
EVENT_TIME_RTOL = 1e-9


# --------------------------------------------------------------------
# float32 pair scoring (jnp with NumPy fallback)
# --------------------------------------------------------------------

def _pair_scores_f32(xp, caps, per_unit, bpu, n_blocks, inst, r, *,
                     max_resident, residual_weight, r_weight,
                     r_balanced, combined_r):
    """ScoreGen(K, K) on backend ``xp`` (numpy or jax.numpy), float32.

    Same term structure as :func:`repro.core.fastscore.pair_score_matrix`
    including the ``((cap - da) - db)`` residual association; only the
    dtype differs."""
    d = per_unit
    fits = (bpu[:, None] + bpu[None, :]) <= max_resident
    sum_d = d[:, None, :] + d[None, :, :]
    fits = fits & xp.all(sum_d <= caps, axis=-1)
    resid = xp.sum(
        residual_weight * xp.maximum(
            (caps - d[:, None, :] - d[None, :, :]) / caps,
            xp.float32(0.0)), axis=-1)
    rb = xp.float32(r_balanced)
    ri, rj = r[:, None], r[None, :]
    gate = ((ri <= rb) & (rb <= rj)) | ((rj <= rb) & (rb <= ri))
    tiny = xp.float32(1e-30)
    if combined_r == "harmonic":
        work = inst * n_blocks
        byts = work / xp.maximum(r, tiny)
        rc = (work[:, None] + work[None, :]) / \
            xp.maximum(byts[:, None] + byts[None, :], tiny)
    else:
        nbr = n_blocks * r
        rc = (nbr[:, None] + nbr[None, :]) / \
            (n_blocks[:, None] + n_blocks[None, :])
    rterm = xp.float32(r_weight) * xp.maximum(
        xp.float32(1.0) - xp.abs(rc - rb) / rb, xp.float32(0.0))
    score = resid + xp.where(gate, rterm, xp.float32(0.0))
    return xp.where(fits, score, xp.float32(0.0))


def _f32_pack(table: ProfileTable) -> dict:
    """float32 views of the table's arrays, packed once per table (the
    jnp path moves them to the device a single time)."""
    pack = getattr(table, "_f32_pack", None)
    if pack is None:
        pack = {
            "caps": np.asarray(table.caps, dtype=np.float32),
            "per_unit": np.asarray(table.per_unit, dtype=np.float32),
            "bpu": np.asarray(table.bpu, dtype=np.float32),
            "n_blocks": np.asarray(table.n_blocks, dtype=np.float32),
            "inst": np.asarray(table.inst, dtype=np.float32),
            "r": np.asarray(table.r, dtype=np.float32),
        }
        if HAS_JAX:
            pack = {k: jnp.asarray(v) for k, v in pack.items()}
        table._f32_pack = pack
    return pack


if HAS_JAX:
    _pair_scores_jit = jax.jit(
        lambda caps, per_unit, bpu, n_blocks, inst, r, **kw:
        _pair_scores_f32(jnp, caps, per_unit, bpu, n_blocks, inst, r,
                         **kw),
        static_argnames=("max_resident", "residual_weight", "r_weight",
                         "r_balanced", "combined_r"))


def pair_score_matrix_batched(table: ProfileTable,
                              backend: str = "auto") -> np.ndarray:
    """Full pairwise ScoreGen matrix in float32 on the jnp backend
    (``backend="jax"``; the default ``"auto"`` uses jax when present),
    equal to the float64 ``pair_score_matrix`` within
    :data:`F32_SCORE_RTOL`.  ``backend="numpy"`` is the host fallback
    — same arithmetic, same dtype, no jax required."""
    if backend not in ("auto", "jax", "numpy"):
        raise ValueError(f"unknown backend {backend!r}")
    use_jax = HAS_JAX if backend == "auto" else backend == "jax"
    if use_jax and not HAS_JAX:
        raise RuntimeError("backend='jax' requested but jax is "
                           "unavailable; use backend='numpy'")
    dev = table.device
    pack = _f32_pack(table)
    kw = dict(max_resident=float(dev.max_resident),
              residual_weight=float(dev.residual_weight),
              r_weight=float(dev.r_weight),
              r_balanced=float(dev.r_balanced),
              combined_r=dev.combined_r)
    if use_jax:
        out = _pair_scores_jit(pack["caps"], pack["per_unit"],
                               pack["bpu"], pack["n_blocks"],
                               pack["inst"], pack["r"], **kw)
        return np.asarray(out)
    host = {k: np.asarray(v) for k, v in pack.items()}
    return _pair_scores_f32(np, host["caps"], host["per_unit"],
                            host["bpu"], host["n_blocks"], host["inst"],
                            host["r"], **kw)


def audit_pair_scores(table: ProfileTable,
                      backend: str = "auto") -> dict:
    """Tolerance audit of the f32 score matrix against the float64
    reference: returns max absolute/relative error and whether both
    stay within :data:`F32_SCORE_RTOL` (relative to the score scale).
    The greedy never consumes the f32 matrix — near-tie argmax
    decisions must replay the reference bit-for-bit — so this audit is
    the documented contract of the batched scoring path."""
    from .fastscore import pair_score_matrix
    ref = pair_score_matrix(table)
    f32 = pair_score_matrix_batched(table, backend=backend)
    err = np.abs(f32.astype(np.float64) - ref)
    scale = max(float(np.max(np.abs(ref))), 1.0)
    max_abs = float(np.max(err)) if err.size else 0.0
    return {"max_abs_err": max_abs,
            "max_rel_err": max_abs / scale,
            "scale": scale,
            "rtol": F32_SCORE_RTOL,
            "within_tol": max_abs <= F32_SCORE_RTOL * scale}


# --------------------------------------------------------------------
# packed kernel universe (one pack per ProfileTable)
# --------------------------------------------------------------------

class PackedKernels:
    """Per-block kernel arrays for the batched simulators, packed once
    per :class:`ProfileTable` (cached on the table, so the greedy ->
    refine pipeline packs exactly once — the pack-count probe in
    tests/test_batched.py pins this).

    Per-kernel rows, float64: ``dem`` (K, D) per-*block* demands in
    ``device.caps`` order, ``nbk`` grid sizes, ``bpu`` resident blocks
    per unit (round model), ``inst_b``/``mem_b`` per-block work, and
    ``zero`` flags for zero-work synchronisation markers (slice
    joins).  ``id2idx`` maps kernel object identity to its row."""

    def __init__(self, table: ProfileTable):
        self.table = table
        dev = table.device
        dims = table.dims
        ks = table.kernels
        K, D = len(ks), len(dims)
        self.caps = np.asarray(table.caps, dtype=np.float64)
        self.dem = np.zeros((K, D), dtype=np.float64)
        self.nbk = np.zeros(K, dtype=np.int64)
        self.bpu = np.zeros(K, dtype=np.int64)
        self.inst_b = np.zeros(K, dtype=np.float64)
        self.mem_b = np.zeros(K, dtype=np.float64)
        self.zero = np.zeros(K, dtype=bool)
        for i, k in enumerate(ks):
            for j, dim in enumerate(dims):
                self.dem[i, j] = k.demands[dim]
            self.nbk[i] = int(k.n_blocks)
            self.bpu[i] = int(k.blocks_per_unit(dev))
            self.inst_b[i] = k.inst_per_block
            self.mem_b[i] = k.mem_per_block()
            self.zero[i] = (k.inst_per_block == 0.0 and
                            all(v == 0.0 for v in k.demands.values()))
        self.id2idx = {id(k): i for i, k in enumerate(ks)}
        self.sat_idx = (dims.index(dev.sat_dim)
                        if dev.sat_dim in dims else -1)
        self.device = dev

    @classmethod
    def for_table(cls, table: ProfileTable) -> "PackedKernels":
        packed = getattr(table, "_packed_kernels", None)
        if packed is None:
            packed = cls(table)
            table._packed_kernels = packed
        return packed

    def rows(self, order: Sequence[KernelProfile]) -> np.ndarray:
        return np.asarray([self.id2idx[id(k)] for k in order],
                          dtype=np.int64)


def _eff_arr(occ: np.ndarray, sat: float, sat_idx: int,
             eps: float) -> np.ndarray:
    if sat_idx < 0:
        return np.ones_like(occ)
    return np.maximum(np.minimum(1.0, occ / sat), eps)


# --------------------------------------------------------------------
# batched round model (exact float64 lockstep)
# --------------------------------------------------------------------

class BatchedRoundSim:
    """Lockstep vectorized :class:`repro.core.refine._FastRoundSim`:
    all B candidates advance one admission step per iteration on (B,)
    state arrays, replaying the reference's float accumulation in the
    reference's order — times are *exactly* equal to the sequential
    simulator (property-tested), because every candidate performs the
    identical scalar op sequence, merely alongside B - 1 others."""

    _EPS = 1e-12

    def __init__(self, packed: PackedKernels):
        self.packed = packed
        dev = packed.device
        self.device = dev
        self._satc = dev.sat_compute
        self._satm = dev.sat_memory
        self._crate = dev.compute_rate
        self._mbw = dev.mem_bw

    def times(self, orders: np.ndarray, start_pos: np.ndarray,
              head_blocks: np.ndarray, t0: np.ndarray) -> np.ndarray:
        """Round-model times of ``orders`` (B, n), candidate b resumed
        at position ``start_pos[b]`` with ``head_blocks[b]`` blocks
        left on its head kernel and ``t0[b]`` elapsed time — the
        :class:`~repro.core.simulator.RoundCheckpoint` resume state."""
        pk = self.packed
        dev = self.device
        eps = self._EPS
        caps = pk.caps
        B, n = orders.shape
        D = caps.shape[0]
        max_res = dev.max_resident
        sat_idx = pk.sat_idx

        head = np.asarray(start_pos, dtype=np.int64).copy()
        t = np.asarray(t0, dtype=np.float64).copy()
        bleft = np.where(head < n, head_blocks, 0).astype(np.int64)
        used = np.zeros((B, D), dtype=np.float64)
        blocks = np.zeros(B, dtype=np.int64)
        inst = np.zeros(B, dtype=np.float64)
        mem = np.zeros(B, dtype=np.float64)
        open_rd = np.zeros(B, dtype=bool)   # current round has blocks
        done = head >= n
        bidx = np.arange(B)

        guard = 0
        while not done.all():
            guard += 1
            if guard > 1_000_000:
                raise RuntimeError("BatchedRoundSim failed to converge")
            act = ~done
            kid = orders[bidx, np.minimum(head, n - 1)]
            dem = pk.dem[kid]                                  # (B, D)
            # fit: min over demanded dims of floor((cap - used + eps)
            # / dem), clipped by the head's remaining blocks and the
            # resident-block budget — the reference's admission test.
            with np.errstate(divide="ignore", invalid="ignore"):
                per_dim = np.floor_divide(caps - used + eps,
                                          np.where(dem > 0, dem, 1.0))
            per_dim = np.where(dem > 0, per_dim, np.inf)
            fit = np.minimum(per_dim.min(axis=1), bleft.astype(np.float64))
            fit = np.maximum(np.minimum(fit, max_res - blocks), 0.0)
            fit = fit.astype(np.int64)
            oversized = act & (fit == 0) & (blocks == 0)
            fit = np.where(oversized, 1, fit)
            closing = act & (fit == 0)     # head closes the round
            placing = act & (fit > 0)

            used += np.where(placing[:, None], dem * fit[:, None], 0.0)
            blocks += np.where(placing, fit, 0)
            inst += np.where(placing, pk.inst_b[kid] * fit, 0.0)
            mem += np.where(placing, pk.mem_b[kid] * fit, 0.0)
            open_rd |= placing
            new_bleft = bleft - np.where(placing, fit, 0)
            # Partially admitted head: the unit is full, the round
            # closes (the reference's `pending[head][0] is k` break).
            closing |= placing & (new_bleft > 0)
            advanced = placing & (new_bleft == 0)
            head = head + np.where(advanced, 1, 0)
            at_end = act & (head >= n)
            closing |= at_end & open_rd
            done = done | (at_end & ~open_rd & ~closing)
            nxt = orders[bidx, np.minimum(head, n - 1)]
            # The round queue dispatches blocks-per-unit, not grid
            # blocks (the reference's pending entries carry bpu).
            bleft = np.where(advanced & (head < n), pk.bpu[nxt],
                             new_bleft)

            if closing.any():
                occ = used[:, sat_idx] if sat_idx >= 0 \
                    else np.zeros(B)
                eff_c = _eff_arr(occ, self._satc, sat_idx, eps)
                eff_m = _eff_arr(occ, self._satm, sat_idx, eps)
                rd_t = np.maximum(inst / (self._crate * eff_c),
                                  mem / (self._mbw * eff_m))
                t = np.where(closing, t + rd_t, t)
                used[closing] = 0.0
                blocks[closing] = 0
                inst[closing] = 0.0
                mem[closing] = 0.0
                open_rd[closing] = False
                done = done | (closing & (head >= n))
        return t

    def times_from_checkpoints(
            self, orders: np.ndarray,
            cps: Sequence[RoundCheckpoint | None]) -> np.ndarray:
        B, n = orders.shape
        start = np.zeros(B, dtype=np.int64)
        hb = np.zeros(B, dtype=np.int64)
        t0 = np.zeros(B, dtype=np.float64)
        for b, cp in enumerate(cps):
            if cp is None:
                hb[b] = self.packed.bpu[orders[b, 0]] if n else 0
            else:
                start[b] = cp.pos
                hb[b] = cp.blocks_left
                t0[b] = cp.time
        # The round queue dispatches blocks-per-unit, not grid blocks.
        fresh = np.asarray([cp is None for cp in cps])
        if fresh.any() and n:
            hb = np.where(fresh, self.packed.bpu[orders[:, 0]], hb)
        return self.times(orders, start, hb, t0)


# --------------------------------------------------------------------
# batched event / gated-event model (lockstep dealing)
# --------------------------------------------------------------------

class BatchedEventSim:
    """Lockstep vectorized event dispatcher: B candidates advance
    together through admission instants and completion events on
    ``(B, U, C)`` state arrays (C = ``min(max_resident, n)`` cohort
    slots per unit — each cohort holds >= 1 resident block of a
    kernel admitted exactly once, so slots never overflow).

    Admission vectorizes the reference's round-robin first-fit
    block-by-block loop as **cyclic dealing**: per admission instant
    each unit u can hold ``c_u = min(min_d floor((cap_d + eps -
    used_d) / dem_d), max_resident - n_res_u)`` more blocks of the
    head kernel, and placing m blocks one at a time in cyclic
    first-fit order from the round-robin pointer provably gives unit u
    exactly ``min(c_u, L)`` blocks plus one extra for the first
    ``m - sum_u min(c_u, L)`` units with ``c_u > L`` in cyclic order
    (L the deepest fully dealt level); the pointer ends one past the
    last placed block.  The allocation, admission decisions and event
    ordering therefore match the reference exactly; only the float
    *association* of ``used``/work-sum accumulation differs (one
    multiply per dealing vs per-block adds), bounded by
    :data:`EVENT_TIME_RTOL` (property-tested).

    With ``edge_ids`` (precedence as ``(id(u), id(v))`` pairs over the
    packed kernel universe) the same engine enforces the ready-set
    admission gate of :class:`repro.graph.delta._FastGatedSim`:
    per-kernel retired-block counts gate the head, zero-work join
    markers retire instantly, and an unready head at drain marks the
    candidate's time ``+inf`` (the sequential simulator raises — such
    candidates are filtered by the legality check before simulation).
    """

    _EPS = 1e-12

    def __init__(self, packed: PackedKernels,
                 edge_ids: set | None = None):
        self.packed = packed
        dev = packed.device
        self.device = dev
        self.gated = edge_ids is not None
        K = len(packed.nbk)
        if self.gated:
            preds: list[list[int]] = [[] for _ in range(K)]
            for u, v in edge_ids:
                preds[packed.id2idx[v]].append(packed.id2idx[u])
            P = max((len(p) for p in preds), default=0)
            self.preds_pad = np.full((K, max(P, 1)), -1, dtype=np.int64)
            for i, p in enumerate(preds):
                self.preds_pad[i, :len(p)] = sorted(p)

    def _rates(self, used, cin, cmb, cnb, occm):
        """Per-unit rates, sums recomputed fresh from the live cohort
        slots (matching the reference's recompute_rate).  ``cin`` /
        ``cmb`` are the per-slot inst/mem per-block caches (stale
        entries masked by ``cnb == 0``), so no kernel-table gather is
        needed per event."""
        pk = self.packed
        dev = self.device
        eps = self._EPS
        sum_c = (cin * cnb).sum(axis=2)
        sum_m = (cmb * cnb).sum(axis=2)
        if pk.sat_idx >= 0:
            occ = used[:, :, pk.sat_idx]
            eff_c = np.maximum(np.minimum(1.0, occ / dev.sat_compute),
                               eps)
            eff_m = np.maximum(np.minimum(1.0, occ / dev.sat_memory),
                               eps)
        else:
            eff_c = eff_m = np.ones(used.shape[:2])
        lam = np.minimum(dev.compute_rate * eff_c / np.maximum(sum_c, eps),
                         dev.mem_bw * eff_m / np.maximum(sum_m, eps))
        return np.where(occm.any(axis=2), lam, 0.0)

    def times(self, orders: np.ndarray,
              cps: Sequence[EventCheckpoint | None]) -> np.ndarray:
        """Event-model (or gated, when constructed with edges) times
        of ``orders`` (B, n); candidate b resumes from checkpoint
        ``cps[b]`` (None = fresh start).  Gate state for gated resumes
        is derived exactly as the sequential simulator derives it:
        positions before the checkpoint are fully retired minus the
        blocks still resident in its cohorts."""
        pk = self.packed
        dev = self.device
        eps = self._EPS
        caps = pk.caps
        B, n = orders.shape
        D = caps.shape[0]
        U = dev.n_units
        # Cohort slots: one dealing per (kernel, unit) at most, and a
        # kernel is admitted exactly once, so n slots always suffice —
        # serving devices advertise effectively-unbounded residency
        # (max_resident in the thousands), and sizing C to it would
        # blow the (B, U, C) state arrays up ~30x past what any
        # schedule can occupy.
        C = max(min(int(dev.max_resident), n), 1)
        max_res = dev.max_resident
        sat_idx = pk.sat_idx
        gated = self.gated
        bidx = np.arange(B)

        head = np.zeros(B, dtype=np.int64)
        rr = np.zeros(B, dtype=np.int64)
        t = np.zeros(B, dtype=np.float64)
        used = np.zeros((B, U, D), dtype=np.float64)
        nres = np.zeros((B, U), dtype=np.int64)
        ckn = np.full((B, U, C), -1, dtype=np.int64)
        cnb = np.zeros((B, U, C), dtype=np.int64)
        cfr = np.zeros((B, U, C), dtype=np.float64)
        # per-slot caches of the occupying kernel's per-block inst /
        # mem / demands, written once at placement so the event loop
        # never gathers from the kernel table (cnb == 0 masks stale
        # slots after retirement).
        cin = np.zeros((B, U, C), dtype=np.float64)
        cmb = np.zeros((B, U, C), dtype=np.float64)
        cdm = np.zeros((B, U, C, D), dtype=np.float64)
        failed = np.zeros(B, dtype=bool)
        if gated:
            retired = np.zeros((B, len(pk.nbk)), dtype=np.int64)
        for b, cp in enumerate(cps):
            if cp is None:
                continue
            head[b], rr[b], t[b] = cp.pos, cp.rr, cp.time
            if gated:
                for p in range(cp.pos):
                    retired[b, orders[b, p]] = pk.nbk[orders[b, p]]
            for ui, (u_used, u_nres, cohorts) in enumerate(cp.units):
                used[b, ui, :] = u_used
                nres[b, ui] = u_nres
                for si, (k, nb_c, fl, _ta) in enumerate(cohorts):
                    kidx = pk.id2idx[id(k)]
                    ckn[b, ui, si] = kidx
                    cnb[b, ui, si] = nb_c
                    cfr[b, ui, si] = fl
                    cin[b, ui, si] = pk.inst_b[kidx]
                    cmb[b, ui, si] = pk.mem_b[kidx]
                    cdm[b, ui, si, :] = pk.dem[kidx]
                    if gated:
                        retired[b, kidx] -= nb_c
        occm = ckn >= 0
        bleft = np.where(head < n,
                         pk.nbk[orders[bidx, np.minimum(head, n - 1)]],
                         0).astype(np.int64)
        done = (head >= n) & (nres.sum(axis=1) == 0)

        guard = 0
        while not done.all():
            guard += 1
            if guard > 1_000_000:
                raise RuntimeError("BatchedEventSim failed to converge")
            # -- admission: deal the head kernel while it places ------
            deal = ~done & (head < n)
            while deal.any():
                kid = orders[bidx, np.minimum(head, n - 1)]
                if gated:
                    pr = self.preds_pad[kid]                 # (B, P)
                    ready = np.all((pr < 0) |
                                   (retired[bidx[:, None],
                                            np.maximum(pr, 0)] >=
                                    pk.nbk[np.maximum(pr, 0)]), axis=1)
                    deal &= ready
                    zw = deal & pk.zero[kid]
                    if zw.any():
                        # Zero-work joins retire the instant their
                        # predecessors drain, occupying no unit.
                        retired[zw, kid[zw]] = pk.nbk[kid[zw]]
                        head = np.where(zw, head + 1, head)
                        nk = orders[bidx, np.minimum(head, n - 1)]
                        bleft = np.where(zw & (head < n), pk.nbk[nk],
                                         bleft)
                        deal &= head < n
                        continue
                if not deal.any():
                    break
                dem = pk.dem[kid]                            # (B, D)
                with np.errstate(divide="ignore", invalid="ignore"):
                    per_dim = np.floor((caps + eps - used) /
                                       np.where(dem[:, None, :] > 0,
                                                dem[:, None, :], 1.0))
                per_dim = np.where(dem[:, None, :] > 0, per_dim, np.inf)
                cap_u = np.minimum(per_dim.min(axis=2),
                                   (max_res - nres).astype(np.float64))
                cap_u = np.maximum(cap_u, 0.0)
                cap_u = np.where(deal[:, None], cap_u, 0.0)
                cap_u = cap_u.astype(np.int64)               # (B, U)
                m = np.minimum(bleft, cap_u.sum(axis=1))
                m = np.where(deal, m, 0)
                place, rr_deal = self._deal(cap_u, m, rr)    # (B, U)
                placing = m > 0
                if placing.any():
                    used += dem[:, None, :] * place[:, :, None]
                    nres += place
                    # one fresh cohort per (candidate, unit) dealing —
                    # candidate orders hold distinct kernel objects and
                    # admission instants strictly increase, so the
                    # reference's same-instant merge can never fire
                    # across dealings.
                    slot = np.argmin(occm, axis=2)           # first free
                    pb, pu = np.nonzero(place > 0)
                    ps = slot[pb, pu]
                    ckn[pb, pu, ps] = kid[pb]
                    cnb[pb, pu, ps] = place[pb, pu]
                    cfr[pb, pu, ps] = 1.0
                    cin[pb, pu, ps] = pk.inst_b[kid[pb]]
                    cmb[pb, pu, ps] = pk.mem_b[kid[pb]]
                    cdm[pb, pu, ps, :] = pk.dem[kid[pb]]
                    occm = ckn >= 0
                    # round-robin pointer: one past the last placed
                    # block (see _deal).
                    rr = np.where(placing, rr_deal, rr)
                    bleft = bleft - m
                adv = placing & (bleft == 0)
                head = head + np.where(adv, 1, 0)
                nk = orders[bidx, np.minimum(head, n - 1)]
                bleft = np.where(adv & (head < n), pk.nbk[nk], bleft)
                # blocked: head kernel still has blocks but nothing
                # placed (strict FIFO) — or the queue is drained.
                deal = deal & adv & (head < n)
            lam = self._rates(used, cin, cmb, cnb, occm)
            nres_tot = nres.sum(axis=1)
            done = done | ((head >= n) & (nres_tot == 0) & ~failed)

            # -- oversized heads run alone (drained units) -----------
            over = ~done & (nres_tot == 0) & (head < n)
            if gated and over.any():
                kid = orders[bidx, np.minimum(head, n - 1)]
                pr = self.preds_pad[kid]
                ready = np.all((pr < 0) |
                               (retired[bidx[:, None],
                                        np.maximum(pr, 0)] >=
                                pk.nbk[np.maximum(pr, 0)]), axis=1)
                bad = over & ~ready
                if bad.any():
                    # The sequential simulator raises ValueError here;
                    # batched candidates are pre-filtered for legality,
                    # so this only flags defensive +inf times.
                    failed |= bad
                    t = np.where(bad, np.inf, t)
                    done |= bad
                    over &= ready
            if over.any():
                kid = orders[bidx, np.minimum(head, n - 1)]
                dem = pk.dem[kid]
                occ = dem[:, sat_idx] if sat_idx >= 0 else np.zeros(B)
                eff_c = _eff_arr(occ, dev.sat_compute, sat_idx, eps)
                eff_m = _eff_arr(occ, dev.sat_memory, sat_idx, eps)
                t1 = np.maximum(pk.inst_b[kid] / (dev.compute_rate * eff_c),
                                pk.mem_b[kid] / (dev.mem_bw * eff_m))
                passes = np.ceil(bleft / U).astype(np.int64)
                t = np.where(over, t + passes * t1, t)
                if gated:
                    retired[over, kid[over]] = pk.nbk[kid[over]]
                head = head + np.where(over, 1, 0)
                nk = orders[bidx, np.minimum(head, n - 1)]
                bleft = np.where(over & (head < n), pk.nbk[nk], bleft)
                done = done | (over & (head >= n))

            # -- completion: advance to the next retirement ----------
            run = ~done & (nres_tot > 0)
            if run.any():
                with np.errstate(divide="ignore", invalid="ignore"):
                    ttf = np.where(occm, cfr / lam[:, :, None], np.inf)
                dt = ttf.min(axis=(1, 2))                    # (B,)
                dt = np.where(run, dt, 0.0)
                t = np.where(run, t + dt, t)
                dec = lam[:, :, None] * dt[:, None, None]
                cfr = np.where(occm & run[:, None, None], cfr - dec,
                               cfr)
                fin = occm & run[:, None, None] & (cfr <= 1e-9)
                if fin.any():
                    nb_f = np.where(fin, cnb, 0)
                    used -= (cdm * nb_f[:, :, :, None]).sum(axis=2)
                    nres -= nb_f.sum(axis=2)
                    if gated:
                        fb, fu, fs = np.nonzero(fin)
                        np.add.at(retired, (fb, ckn[fb, fu, fs]),
                                  cnb[fb, fu, fs])
                    ckn = np.where(fin, -1, ckn)
                    cnb = np.where(fin, 0, cnb)
                    occm = ckn >= 0
        return t

    @staticmethod
    def _deal(cap: np.ndarray, m: np.ndarray,
              rr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Allocation of ``m[b]`` blocks over units with capacities
        ``cap[b, :]`` by cyclic first-fit dealing from ``rr[b]`` —
        the closed form of the reference's block-by-block round-robin
        placement (see class docstring).  Returns ``(place, rr_new)``
        where ``rr_new`` points one past the unit that received the
        last block (meaningful only where m > 0; callers mask)."""
        B, U = cap.shape
        # deepest fully dealt level L: largest L with
        # sum_u min(cap_u, L) <= m (vectorized binary search).
        lo = np.zeros(B, dtype=np.int64)
        hi = cap.max(axis=1)
        while (lo < hi).any():
            mid = (lo + hi + 1) // 2
            f = np.minimum(cap, mid[:, None]).sum(axis=1)
            take = f <= m
            lo = np.where(take, mid, lo)
            hi = np.where(take, hi, mid - 1)
        L = lo
        base = np.minimum(cap, L[:, None])
        rem = m - base.sum(axis=1)
        # one extra block for the first `rem` units with cap > L in
        # cyclic order from rr.
        off = (np.arange(U)[None, :] + rr[:, None]) % U      # (B, U)
        cap_cyc = np.take_along_axis(cap, off, axis=1)
        elig = cap_cyc > L[:, None]
        rank = np.cumsum(elig, axis=1) - elig
        extra_cyc = elig & (rank < rem[:, None])
        extra = np.zeros_like(cap)
        np.put_along_axis(extra, off, extra_cyc.astype(np.int64),
                          axis=1)
        # rem > 0: the last block is the last extra; rem == 0: it is
        # the last unit dealt its L-th block (cap >= L) in cyclic order.
        offs = np.arange(U)[None, :]
        lvl = cap_cyc >= np.maximum(L, 1)[:, None]
        last_src = np.where((rem > 0)[:, None], extra_cyc, lvl)
        last_off = np.where(last_src, offs, -1).max(axis=1)
        last_off = np.maximum(last_off, 0)
        return base + extra, (rr + last_off + 1) % U


# --------------------------------------------------------------------
# batched move evaluator
# --------------------------------------------------------------------

def refine_order_batched(
    order: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    model: str = "event",
    budget: int = 2000,
    neighborhood: str = "full",
    batch_size: int = 128,
    table: ProfileTable | None = None,
    edge_ids: set | None = None,
    delta=None,
    legal: Callable[[Sequence[KernelProfile]], bool] | None = None,
    verify_k: int = 8,
    rescore: bool | None = None,
    metrics=None,
) -> tuple[list[KernelProfile], float, int]:
    """Batched counterpart of :func:`repro.core.refine.refine_order`:
    generates the move neighborhood as ``(B, n)`` candidate batches,
    delta-evaluates each batch in one vectorized pass from
    checkpoint-stitched suffixes, and accepts the **best improving
    move per batch** (exactly re-verified by the sequential
    :class:`~repro.core.refine.DeltaEvaluator` before it lands, so the
    trajectory stays in the exact simulator currency and is never
    worse than the input order).

    Budget accounting matches the sequential path: every candidate —
    including acceptance re-verifications — is charged its suffix
    fraction in full-simulation equivalents, with the same ``10 *
    budget`` evaluation cap.

    ``rescore`` selects the quality contract.  ``True`` (the default
    under ``model="gated"``) re-scores the chunk remainder against
    the new base after every acceptance, so the walk makes the same
    skip/accept decisions as the sequential first-improving sweep
    wherever the engine classifies improving/non-improving correctly
    — refined makespans then match the *sequential refiner's* (the
    traced-arch quality pin), at the cost of one extra engine pass
    per acceptance.  ``False`` (the default under
    ``model="round"``/``"event"``) keeps the single scoring pass per
    chunk — maximum effective-move throughput, quality pinned to
    never-worse-than-the-input-order only.

    ``model="gated"`` callers (:func:`repro.graph.refine_order_dag`)
    pass their own sequential ``delta``
    (:class:`repro.graph.delta.GatedDeltaEvaluator`) plus ``edge_ids``
    and a ``legal`` pre-filter; this module stays import-free of the
    graph layer.  ``table`` threads an already packed
    :class:`ProfileTable` through so greedy + refine packs exactly
    once."""
    from .refine import DeltaEvaluator, _apply, _moves

    t_wall = perf_counter()
    n = len(order)
    if neighborhood == "auto":
        neighborhood = "full" if n <= 128 else "adjacent"
    if table is None:
        table = ProfileTable.build(order, device)
    packed = PackedKernels.for_table(table)
    if delta is None:
        if model == "gated":
            raise ValueError("model='gated' requires the caller's "
                             "GatedDeltaEvaluator (see "
                             "repro.graph.refine_order_dag)")
        delta = DeltaEvaluator(device, model=model)
    if model == "round":
        engine: BatchedRoundSim | BatchedEventSim = \
            BatchedRoundSim(packed)
    elif model == "event":
        engine = BatchedEventSim(packed)
    elif model == "gated":
        engine = BatchedEventSim(packed, edge_ids=edge_ids or set())
    else:
        raise ValueError(f"unknown model {model!r}")

    if rescore is None:
        rescore = model == "gated"
    best = list(order)
    best_t = delta.rebase(best)
    cost = 1.0
    evals = 1
    eval_cap = 10 * budget
    batch_size = max(int(batch_size), 1)

    def _cp_for(first: int):
        """(checkpoint, frac) for a candidate first changed at
        ``first`` — the same resume state the sequential evaluator
        would pick."""
        if delta._per_position:
            if first < len(delta._ckpts):
                cp = delta._ckpts[first]
                return cp, (n - cp.pos) / max(n, 1)
            return None, 1.0
        bestcp = None
        for cp in delta._ckpts:
            if cp.pos < first:
                bestcp = cp
            else:
                break
        if bestcp is None:
            return None, 1.0
        return bestcp, (n - bestcp.pos) / max(n, 1)

    improved = True
    while improved and cost < budget and evals < eval_cap:
        improved = False
        moves = _moves(n, neighborhood)
        if neighborhood == "adjacent":
            bounds = delta.boundaries()
            if bounds is None:
                moves.sort(key=lambda m: -m[0])
            else:
                near = [False] * (n + 1)
                for b in bounds:
                    for p in (b - 1, b, b + 1):
                        if 0 <= p < n:
                            near[p] = True
                moves.sort(key=lambda m: (not (near[m[2]] or near[m[3]]),
                                          -m[0]))
        mi = 0
        while mi < len(moves) and cost < budget and evals < eval_cap:
            cands: list[list[KernelProfile]] = []
            chunk_moves: list[tuple[int, str, int, int]] = []
            cps: list = []
            while (mi < len(moves) and len(cands) < batch_size and
                   cost < budget and evals + len(cands) < eval_cap):
                first, kind, i, j = moves[mi]
                mi += 1
                cand = _apply(best, kind, i, j)
                if legal is not None and not legal(cand):
                    continue  # rejected before simulation: free
                cp, frac = _cp_for(first)
                cands.append(cand)
                chunk_moves.append((first, kind, i, j))
                cps.append(cp)
                cost += frac
            if not cands:
                continue
            rows = np.stack([packed.rows(c) for c in cands])
            if model == "round":
                ts = engine.times_from_checkpoints(rows, cps)
            else:
                ts = engine.times(rows, cps)
            evals += len(cands)
            # Predicted-improving candidates are re-verified in *move
            # order* — the order the sequential first-improving sweep
            # evaluates them — each re-applied (moves are
            # position-based) to the evolving best and exactly
            # re-simulated before acceptance.
            #
            # The chunk's predictions are against the chunk-start
            # base.  With ``rescore`` the chunk remainder is
            # *re-scored* against the new base after every acceptance
            # (each candidate stays charged exactly once — the stale
            # pass is wasted wall-clock, not wasted budget), so the
            # walk makes the same skip/accept decisions the
            # sequential sweep makes wherever the engine classifies
            # improving/non-improving correctly.  That is what pins
            # batched gated refinement to the sequential refiner's
            # makespans on the traced archs.  Without it the skip
            # test uses the frozen chunk-start time, which stays the
            # right admission test under the additive shift an
            # acceptance applies to non-interacting candidates —
            # maximum throughput, quality pinned to the input order.
            chunk_t = best_t
            tried = 0
            for ci in range(len(cands)):
                # Budget/eval-cap exhaustion does NOT gate this loop:
                # every candidate here already paid its suffix
                # fraction when the chunk was scored, and acceptance
                # verification is the sequential path's free rebase —
                # skipping it would silently discard the last chunk's
                # improvements (exactly the chunk most likely to hold
                # them, since the fill stops on the budget).
                if tried >= verify_k:
                    break
                if ts[ci] >= (best_t if rescore else chunk_t) - 1e-15:
                    continue
                first, kind, i, j = chunk_moves[ci]
                cand = _apply(best, kind, i, j)
                if legal is not None and not legal(cand):
                    continue
                # Not charged: each candidate already paid its suffix
                # fraction in the batch, and the sequential path's
                # budget prices candidate evaluations only — its
                # acceptance rebase is free, and this verification
                # doubles as exactly that rebase.  Only *misses*
                # (verified not-improving — mispredictions) count
                # against verify_k, so a chunk dense in real
                # improvements accepts them all, matching the
                # sequential sweep's acceptance density, while
                # mispredictions stay bounded and wall time stays
                # proportional to the budget.
                t_exact, _ = delta.evaluate_costed(cand, first)
                evals += 1
                if t_exact < best_t - 1e-15:
                    best, best_t, improved = cand, t_exact, True
                    delta.rebase_incremental(best, first)
                    if rescore and ci + 1 < len(cands):
                        rem_rows, rem_idx, rem_cps = [], [], []
                        for cj in range(ci + 1, len(cands)):
                            fj, kj, ij, jj = chunk_moves[cj]
                            cand_j = _apply(best, kj, ij, jj)
                            if legal is not None and not legal(cand_j):
                                ts[cj] = np.inf
                                continue
                            cp, _ = _cp_for(fj)
                            rem_rows.append(packed.rows(cand_j))
                            rem_idx.append(cj)
                            rem_cps.append(cp)
                        if rem_idx:
                            ts[rem_idx] = engine.times(
                                np.stack(rem_rows), rem_cps)
                else:
                    tried += 1
    if metrics is not None:
        metrics.counter("refine_evals").inc(evals)
        metrics.counter("refine_cost").inc(cost)
        metrics.histogram("refine_score_s").observe(
            perf_counter() - t_wall)
    return best, best_t, evals
