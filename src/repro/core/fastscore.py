"""Vectorized scoring core + incremental greedy scheduler.

The reference implementation (:mod:`repro.core.scheduler`) follows the
paper's pseudocode: every round re-runs ScoreGen over all remaining
pairs in pure Python, which is ``O(R * n^2)`` scored pairs and
unusable beyond a few dozen kernels.  This module is the production
hot path:

* :class:`ProfileTable` packs ``KernelProfile`` demand dicts into
  NumPy arrays **once** (per-unit demands in ``device.caps`` order,
  block counts, intensities),
* :func:`pair_score_matrix` computes the full pairwise score matrix
  with broadcasting in ``O(n^2 * D)``, and
* :func:`greedy_order_fast` runs Algorithm 1 *incrementally*: the
  pairwise matrix is computed a single time (pair scores between
  original kernels never change between rounds — only membership
  does), and during round construction only the 1xn score vector of
  the current round's combined profile against the remaining kernels
  is recomputed, ``O(n * D)`` per absorption.

The fast path reproduces the reference scheduler's output *exactly* —
same rounds, same intra-round order — including tie-breaking (first
strict maximum in remaining-order row-major scan).  This is enforced
by ``tests/test_fastscore.py`` on randomized profile sets; the
arithmetic is kept operation-for-operation identical to
:mod:`repro.core.scorer` so even near-ties resolve the same way.

This module schedules *independent* kernel batches.  When the kernels
carry precedence edges (traced per-layer model chains — see
``repro.graph.trace_arch``), call
:func:`repro.graph.greedy_order_dag`: it reuses this module's
``ProfileTable``/``pair_score_matrix`` machinery, restricts candidate
scans to the ready frontier, and degenerates to
:func:`greedy_order_fast` bit-for-bit on an empty edge set.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .resources import DeviceModel, KernelProfile
from .scheduler import Round, Schedule, _sort_key

__all__ = ["ProfileTable", "pair_score_matrix", "score_matrix_fast",
           "greedy_order_fast", "warm_start_insert"]


@dataclass
class ProfileTable:
    """Array-backed view of a kernel set against one device model."""

    device: DeviceModel
    kernels: list[KernelProfile]
    dims: tuple[str, ...]
    caps: np.ndarray       # (D,) per-unit capacity, caps order
    per_unit: np.ndarray   # (n, D) per-unit aggregate demand
    bpu: np.ndarray        # (n,) resident blocks per unit
    n_blocks: np.ndarray   # (n,) grid size
    inst: np.ndarray       # (n,) work units per block
    r: np.ndarray          # (n,) intensity R_i
    sort_key: np.ndarray   # (n,) intra-round sort key (paper: N_shm)

    @classmethod
    def build(cls, kernels: Sequence[KernelProfile],
              device: DeviceModel) -> "ProfileTable":
        ks = list(kernels)
        dims = tuple(device.caps)
        n, D = len(ks), len(dims)
        per_unit = np.zeros((n, D), dtype=np.float64)
        bpu = np.zeros(n, dtype=np.float64)
        n_blocks = np.zeros(n, dtype=np.float64)
        inst = np.zeros(n, dtype=np.float64)
        r = np.zeros(n, dtype=np.float64)
        for i, k in enumerate(ks):
            d = k.per_unit_demand(device)
            for j, dim in enumerate(dims):
                per_unit[i, j] = d[dim]
            bpu[i] = k.blocks_per_unit(device)
            n_blocks[i] = k.n_blocks
            inst[i] = k.inst_per_block
            r[i] = k.r
        # The reference's own sort key, per kernel: its fallback (no
        # "shm" dimension) reads the *kernel's* first declared demand,
        # which need not be the first device.caps dimension.
        sort_key = np.asarray([_sort_key(k, device) for k in ks],
                              dtype=np.float64)
        caps = np.asarray([device.cap(d) for d in dims], dtype=np.float64)
        return cls(device=device, kernels=ks, dims=dims, caps=caps,
                   per_unit=per_unit, bpu=bpu, n_blocks=n_blocks,
                   inst=inst, r=r, sort_key=sort_key)

    def __len__(self) -> int:
        return len(self.kernels)


def _combined_ratio_arrays(table: ProfileTable) -> np.ndarray:
    """(n, n) combined-ratio matrix per ``device.combined_r`` —
    operation-for-operation the same arithmetic as
    :func:`repro.core.scorer.combined_ratio`."""
    if table.device.combined_r == "harmonic":
        work = table.inst * table.n_blocks
        byts = work / np.maximum(table.r, 1e-30)
        return (work[:, None] + work[None, :]) / \
            np.maximum(byts[:, None] + byts[None, :], 1e-30)
    nbr = table.n_blocks * table.r
    return (nbr[:, None] + nbr[None, :]) / \
        (table.n_blocks[:, None] + table.n_blocks[None, :])


def pair_score_matrix(table: ProfileTable) -> np.ndarray:
    """Full pairwise ScoreGen matrix, elementwise equal to the
    reference ``score_matrix(ks, ks, device)`` (diagonal included)."""
    dev = table.device
    d = table.per_unit
    sum_d = d[:, None, :] + d[None, :, :]                      # (n,n,D)
    fits = table.bpu[:, None] + table.bpu[None, :] <= dev.max_resident
    fits &= np.all(sum_d <= table.caps, axis=-1)
    # ((cap - da) - db), matching the reference's float association —
    # cap - (da + db) can differ in the last ulp and flip near-ties.
    resid = np.sum(
        dev.residual_weight * np.maximum(
            (table.caps - d[:, None, :] - d[None, :, :]) / table.caps,
            0.0), axis=-1)
    rb = dev.r_balanced
    ri, rj = table.r[:, None], table.r[None, :]
    gate = ((ri <= rb) & (rb <= rj)) | ((rj <= rb) & (rb <= ri))
    with np.errstate(divide="ignore", invalid="ignore"):
        rc = _combined_ratio_arrays(table)
    rterm = dev.r_weight * np.maximum(1.0 - np.abs(rc - rb) / rb, 0.0)
    score = resid + np.where(gate, rterm, 0.0)
    return np.where(fits, score, 0.0)


def score_matrix_fast(kernels: Sequence[KernelProfile],
                      device: DeviceModel) -> np.ndarray:
    """Vectorized ScoreGen(K, K); drop-in for the reference
    ``score_matrix`` on a single kernel set."""
    return pair_score_matrix(ProfileTable.build(kernels, device))


@dataclass
class _CombState:
    """The round's virtual combined profile, in array form.

    Mirrors ``profile_combine`` exactly: per-unit demands add, block
    counts and per-block work add, the ratio combines per
    ``device.combined_r`` sequentially (pair by pair, matching the
    reference's left fold)."""

    demand: np.ndarray   # (D,) aggregated per-unit demand
    bpu: float
    n_blocks: float
    inst: float
    r: float


def _comb_ratio_scalar(dev: DeviceModel, nb_a: float, inst_a: float,
                       r_a: float, nb_b: float, inst_b: float,
                       r_b: float) -> float:
    if dev.combined_r == "harmonic":
        work = inst_a * nb_a + inst_b * nb_b
        byts = (inst_a * nb_a / max(r_a, 1e-30) +
                inst_b * nb_b / max(r_b, 1e-30))
        return work / max(byts, 1e-30)
    return (nb_a * r_a + nb_b * r_b) / (nb_a + nb_b)


def _comb_scores(comb: _CombState, table: ProfileTable,
                 idx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """ScoreGen of the combined profile vs ``table[idx]``: the 1xm
    score vector plus the fits mask, ``O(m * D)``."""
    dev = table.device
    d = table.per_unit[idx]
    sum_d = comb.demand + d                                    # (m, D)
    fits = comb.bpu + table.bpu[idx] <= dev.max_resident
    fits &= np.all(sum_d <= table.caps, axis=-1)
    # ((cap - da) - db) association, as in the reference (a = comb).
    resid = np.sum(
        dev.residual_weight * np.maximum(
            ((table.caps - comb.demand) - d) / table.caps, 0.0),
        axis=-1)
    rb = dev.r_balanced
    rc_ = table.r[idx]
    gate = ((comb.r <= rb) & (rb <= rc_)) | ((rc_ <= rb) & (rb <= comb.r))
    if dev.combined_r == "harmonic":
        work_c = table.inst[idx] * table.n_blocks[idx]
        byts_c = work_c / np.maximum(table.r[idx], 1e-30)
        work = comb.inst * comb.n_blocks + work_c
        byts = comb.inst * comb.n_blocks / max(comb.r, 1e-30) + byts_c
        rc = work / np.maximum(byts, 1e-30)
    else:
        rc = (comb.n_blocks * comb.r +
              table.n_blocks[idx] * table.r[idx]) / \
            (comb.n_blocks + table.n_blocks[idx])
    rterm = dev.r_weight * np.maximum(1.0 - np.abs(rc - rb) / rb, 0.0)
    return resid + np.where(gate, rterm, 0.0), fits


def _absorb(comb: _CombState, table: ProfileTable, c: int,
            dev: DeviceModel) -> _CombState:
    new_r = _comb_ratio_scalar(
        dev, comb.n_blocks, comb.inst, comb.r,
        table.n_blocks[c], table.inst[c], table.r[c])
    return _CombState(demand=comb.demand + table.per_unit[c],
                      bpu=comb.bpu + table.bpu[c],
                      n_blocks=comb.n_blocks + table.n_blocks[c],
                      inst=comb.inst + table.inst[c],
                      r=new_r)


def warm_start_insert(rounds: Sequence[Sequence[KernelProfile]],
                      extra: KernelProfile,
                      device: DeviceModel) -> int:
    """Greedy ScoreGen placement of one extra kernel into an existing
    round composition.

    Returns the index of the best-scoring round whose combined profile
    (ProfileCombine fold, exactly as the incremental greedy maintains
    it) still fits together with ``extra``, or ``-1`` when no round
    fits and the kernel must open a new round.

    This is the ScheduleCache warm-start primitive: a near-miss cached
    composition (one request joined the mix since the cached step) is
    adapted by absorbing the newcomer where Algorithm 1's own scoring
    would put it, instead of recomputing the whole composition from
    scratch.
    """
    rounds = [rd for rd in rounds if rd]
    if not rounds:
        return -1
    all_ks = [k for rd in rounds for k in rd] + [extra]
    table = ProfileTable.build(all_ks, device)
    extra_idx = np.asarray([len(all_ks) - 1])
    best_i, best_s = -1, -np.inf
    base = 0
    for i, rd in enumerate(rounds):
        comb = _CombState(demand=table.per_unit[base].copy(),
                          bpu=float(table.bpu[base]),
                          n_blocks=float(table.n_blocks[base]),
                          inst=float(table.inst[base]),
                          r=float(table.r[base]))
        for c in range(base + 1, base + len(rd)):
            comb = _absorb(comb, table, c, device)
        base += len(rd)
        scores, fits = _comb_scores(comb, table, extra_idx)
        if bool(fits[0]) and float(scores[0]) > best_s:
            best_i, best_s = i, float(scores[0])
    return best_i


def greedy_order_fast(kernels: Sequence[KernelProfile],
                      device: DeviceModel,
                      table: ProfileTable | None = None) -> Schedule:
    """Algorithm 1, incremental: identical schedules to
    ``scheduler.greedy_order`` in ``O(n^2 * D)`` instead of
    ``O(R * n^2)`` Python-level ScoreGen reruns.

    ``table`` accepts an already-built :class:`ProfileTable` for the
    same ``(kernels, device)`` so a greedy + refine pipeline
    (:func:`repro.core.refine.refined_schedule`) packs exactly once."""
    n = len(kernels)
    if n == 0:
        return Schedule([])
    if table is None:
        table = ProfileTable.build(kernels, device)
    mat = pair_score_matrix(table)
    # Mask the lower triangle and diagonal: pair_score(a, b) and
    # pair_score(b, a) can differ in the last ulp (the residual term's
    # float association is order-dependent), so the argmax must scan
    # exactly the i < j entries the reference scan evaluates.  Dead
    # rows/cols are masked the same way as kernels leave.
    mat[np.tril_indices(n)] = -1.0
    alive = np.ones(n, dtype=bool)
    rounds: list[Round] = []
    n_alive = n

    def kill(i: int) -> None:
        nonlocal n_alive
        alive[i] = False
        mat[i, :] = -1.0
        mat[:, i] = -1.0
        n_alive -= 1

    while n_alive:
        rd = Round()
        if n_alive == 1:
            rd.kernels.append(table.kernels[int(np.nonzero(alive)[0][0])])
            rounds.append(rd)
            break
        # Seed pair: first strict maximum over the remaining i < j
        # entries in row-major order — the same pair the reference's
        # i < j scan picks.
        flat = int(np.argmax(mat))
        i, j = divmod(flat, n)
        best = mat[i, j]
        fits_pair = (
            table.bpu[i] + table.bpu[j] <= device.max_resident and
            bool(np.all(table.per_unit[i] + table.per_unit[j] <=
                        table.caps)))
        if best <= 0.0 and not fits_pair:
            # Nothing pairs: the heaviest (sort-key) kernel runs alone.
            idx = np.nonzero(alive)[0]
            solo = int(idx[int(np.argmax(table.sort_key[idx]))])
            kill(solo)
            rd.kernels.append(table.kernels[solo])
            rounds.append(rd)
            continue
        rd.insert_sorted(table.kernels[i], device)
        rd.insert_sorted(table.kernels[j], device)
        comb = _CombState(
            demand=table.per_unit[i] + table.per_unit[j],
            bpu=table.bpu[i] + table.bpu[j],
            n_blocks=table.n_blocks[i] + table.n_blocks[j],
            inst=table.inst[i] + table.inst[j],
            r=_comb_ratio_scalar(device, table.n_blocks[i], table.inst[i],
                                 table.r[i], table.n_blocks[j],
                                 table.inst[j], table.r[j]))
        kill(i)
        kill(j)
        # Absorb best-fitting kernels: only the 1xm combined-vs-rest
        # vector is recomputed per absorption (incremental ScoreGen).
        while n_alive:
            idx = np.nonzero(alive)[0]
            scores, fits = _comb_scores(comb, table, idx)
            if not fits.any():
                break
            scores = np.where(fits, scores, -np.inf)
            c = int(idx[int(np.argmax(scores))])
            rd.insert_sorted(table.kernels[c], device)
            comb = _absorb(comb, table, c, device)
            kill(c)
        rounds.append(rd)
    return Schedule(rounds)
