"""Core contribution: concurrent-kernel launch reordering (Algorithm 1).

Faithful reproduction of Li/Narayana/El-Ghazawi 2015 plus the TPU
adaptation used by the serving and training substrates.
"""

from .resources import (GTX580, TPU_V5E_UNIT, DeviceModel, KernelProfile,
                        bs_kernel, ep_kernel, es_kernel, sw_kernel)
from .scorer import (combined_ratio, fits_alone, fits_together, pair_score,
                     profile_combine, score_matrix, score_vector)
from .scheduler import (Round, Schedule, exhaustive_search, greedy_order,
                        percentile_rank, random_orders)
from .simulator import (EventCheckpoint, EventSimulator, RoundCheckpoint,
                        RoundSimulator, simulate)
from .experiments import EXPERIMENTS, experiment
from .fastscore import (ProfileTable, greedy_order_fast, pair_score_matrix,
                        score_matrix_fast, warm_start_insert)
from .refine import (DeltaEvaluator, DeltaRoundEvaluator, refine_order,
                     refined_schedule)
from .batched import (BatchedEventSim, BatchedRoundSim, PackedKernels,
                      audit_pair_scores, pair_score_matrix_batched,
                      refine_order_batched)
from .tpu import (TpuWorkItem, compose_rounds, decode_profile,
                  make_serving_device, prefill_profile)

__all__ = [
    "GTX580", "TPU_V5E_UNIT", "DeviceModel", "KernelProfile",
    "bs_kernel", "ep_kernel", "es_kernel", "sw_kernel",
    "combined_ratio", "fits_alone", "fits_together", "pair_score",
    "profile_combine", "score_matrix", "score_vector",
    "Round", "Schedule", "exhaustive_search", "greedy_order",
    "percentile_rank", "random_orders",
    "EventCheckpoint", "EventSimulator", "RoundCheckpoint",
    "RoundSimulator", "simulate",
    "EXPERIMENTS", "experiment",
    "ProfileTable", "greedy_order_fast", "pair_score_matrix",
    "score_matrix_fast", "warm_start_insert",
    "DeltaEvaluator", "DeltaRoundEvaluator", "refine_order",
    "refined_schedule",
    "BatchedEventSim", "BatchedRoundSim", "PackedKernels",
    "audit_pair_scores", "pair_score_matrix_batched",
    "refine_order_batched",
    "TpuWorkItem", "compose_rounds", "decode_profile",
    "make_serving_device", "prefill_profile",
]
