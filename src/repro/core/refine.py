"""Beyond-paper: simulator-guided local refinement of the launch order.

Algorithm 1 is profile-greedy — it never consults a timing model.  When
a timing model *is* available at scheduling time (always true for the
TPU serving/training substrates, where the roofline cost of every task
is known), the launch order can be polished by local search around the
greedy solution:

* pairwise swaps,
* single-kernel reinsertions (remove + insert at every position),

accepting strict improvements until a local optimum or the evaluation
budget is reached.  The greedy order is both the starting point and the
fallback, so the refined order is never worse than Algorithm 1's.

This mirrors what the paper's own Fig. 1 suggests: the greedy lands
above the 90th percentile, and a small neighbourhood search closes most
of the remaining gap to the optimum at negligible cost (the simulator
evaluates an 8-kernel order in well under a millisecond, against a
40,320-point design space).
"""

from __future__ import annotations

from typing import Callable, Sequence

from .resources import DeviceModel, KernelProfile
from .scheduler import Schedule, greedy_order
from .simulator import simulate

__all__ = ["refine_order", "refined_schedule"]


def refine_order(
    order: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    time_fn: Callable[[Sequence[KernelProfile]], float] | None = None,
    budget: int = 2000,
    model: str = "event",
) -> tuple[list[KernelProfile], float, int]:
    """Hill-climb ``order`` under ``time_fn``.

    Returns ``(best_order, best_time, evaluations_used)``.
    """
    if time_fn is None:
        time_fn = lambda o: simulate(o, device, model=model)  # noqa: E731
    best = list(order)
    best_t = time_fn(best)
    evals = 1
    improved = True
    n = len(best)
    while improved and evals < budget:
        improved = False
        # Pairwise swaps.
        for i in range(n - 1):
            for j in range(i + 1, n):
                if evals >= budget:
                    break
                cand = list(best)
                cand[i], cand[j] = cand[j], cand[i]
                t = time_fn(cand)
                evals += 1
                if t < best_t - 1e-15:
                    best, best_t, improved = cand, t, True
        # Reinsertions.
        for i in range(n):
            for j in range(n):
                if i == j or evals >= budget:
                    continue
                cand = list(best)
                k = cand.pop(i)
                cand.insert(j, k)
                t = time_fn(cand)
                evals += 1
                if t < best_t - 1e-15:
                    best, best_t, improved = cand, t, True
    return best, best_t, evals


def refined_schedule(
    kernels: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    budget: int = 2000,
    model: str = "event",
) -> tuple[list[KernelProfile], float]:
    """Algorithm 1 followed by local search.  Returns (order, time)."""
    sched: Schedule = greedy_order(kernels, device)
    order, t, _ = refine_order(sched.order, device, budget=budget,
                               model=model)
    return order, t
