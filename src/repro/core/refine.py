"""Beyond-paper: simulator-guided local refinement of the launch order.

Algorithm 1 is profile-greedy — it never consults a timing model.  When
a timing model *is* available at scheduling time (always true for the
TPU serving/training substrates, where the roofline cost of every task
is known), the launch order can be polished by local search around the
greedy solution:

* pairwise swaps,
* single-kernel reinsertions (remove + insert at every position),

accepting strict improvements until a local optimum or the evaluation
budget is reached.  The greedy order is both the starting point and the
fallback, so the refined order is never worse than Algorithm 1's.

This mirrors what the paper's own Fig. 1 suggests: the greedy lands
above the 90th percentile, and a small neighbourhood search closes most
of the remaining gap to the optimum at negligible cost (the simulator
evaluates an 8-kernel order in well under a millisecond, against a
40,320-point design space).

Complexity / when to use which path
-----------------------------------
A naive candidate evaluation re-simulates the whole order: ``O(n)``
rounds per candidate, ``O(n^3)`` per full-neighbourhood sweep.  Two
levers make refinement affordable at serving scale:

* **Delta evaluation** (automatic for ``model="round"`` with no custom
  ``time_fn``): the :class:`DeltaRoundEvaluator` caches the
  RoundSimulator's per-round admission checkpoints for the incumbent
  order, so a candidate differing only at positions >= p re-simulates
  just the suffix of rounds from the last checkpoint before p —
  ``O(n - p)`` instead of ``O(n)``.  The budget is charged in
  full-simulation equivalents (a suffix re-sim costs its fraction), so
  the default serving budget buys roughly an order of magnitude more
  effective moves; on the adjacent move set, moves straddling a round
  boundary are tried first, cheapest (latest suffix) first within each
  class ("early-exit ordering").
* **``neighborhood="adjacent"``**: restrict moves to adjacent swaps
  and short-range reinsertions — ``O(n)`` candidates per sweep instead
  of ``O(n^2)``.  This is the right regime on a serving hot path
  (``n`` in the hundreds): a fixed budget spent on ``(0, j)`` swaps of
  a full sweep barely touches the order, while adjacent moves spread
  it across every round boundary.  ``"auto"`` picks ``"full"`` up to
  128 kernels (where it still dominates the reference within a
  serving budget) and ``"adjacent"`` above; ``"full"`` remains the
  offline default.

Delta-evaluated times are *exactly* equal to full re-simulation
(property-tested in ``tests/test_fastscore.py``): resuming from a
checkpoint replays the identical float accumulation.
"""

from __future__ import annotations

from typing import Callable, Sequence

from .fastscore import greedy_order_fast
from .resources import DeviceModel, KernelProfile
from .scheduler import Schedule
from .simulator import RoundCheckpoint, simulate

__all__ = ["refine_order", "refined_schedule", "DeltaRoundEvaluator"]


class _FastRoundSim:
    """RoundSimulator with per-kernel profile data precomputed once.

    Bit-identical arithmetic to :class:`RoundSimulator._simulate` —
    the same operations on the same floats in the same order — but
    demand dicts, per-unit block counts and per-block memory traffic
    are resolved to flat tuples a single time per kernel object, which
    is what makes thousands of suffix re-simulations per refinement
    affordable."""

    _EPS = 1e-12

    def __init__(self, device: DeviceModel):
        self.device = device
        self._dims = tuple(device.caps)
        self._caps = tuple(device.cap(d) for d in self._dims)
        self._sat_idx = (self._dims.index(device.sat_dim)
                         if device.sat_dim in self._dims else -1)
        self._info: dict[int, tuple] = {}

    def _kinfo(self, k: KernelProfile) -> tuple:
        # Keyed by id(k) — the cached entry holds a strong reference
        # to k so its id can never be recycled by a different profile.
        v = self._info.get(id(k))
        if v is None:
            v = (k, tuple(k.demands[d] for d in self._dims),
                 k.blocks_per_unit(self.device),
                 k.inst_per_block, k.mem_per_block())
            self._info[id(k)] = v
        return v

    def _eff(self, occ: float, sat: float) -> float:
        if self._sat_idx < 0 and not self.device.sat_dim:
            return 1.0
        return min(1.0, occ / sat)

    def simulate(self, order: Sequence[KernelProfile],
                 start_pos: int = 0, head_blocks: int | None = None,
                 t0: float = 0.0, record: bool = False
                 ) -> tuple[float, list[RoundCheckpoint]]:
        dev = self.device
        dims_n = len(self._dims)
        caps = self._caps
        eps = self._EPS
        pending: list[list] = []
        for p in range(start_pos, len(order)):
            k = order[p]
            _, dem, bpu, inst_b, mem_b = self._kinfo(k)
            nb = head_blocks if (p == start_pos and
                                 head_blocks is not None) else bpu
            pending.append([k, nb, p, dem, inst_b, mem_b])
        total = t0
        ckpts: list[RoundCheckpoint] = []
        head = 0
        n_pend = len(pending)
        while head < n_pend:
            if record:
                e = pending[head]
                ckpts.append(RoundCheckpoint(pos=e[2], blocks_left=e[1],
                                             time=total))
            used = [0.0] * dims_n
            blocks, inst, mem = 0, 0.0, 0.0
            while head < n_pend:
                e = pending[head]
                k, nb, _, dem, inst_b, mem_b = e
                fit = nb
                for di in range(dims_n):
                    dv = dem[di]
                    if dv > 0:
                        fit = min(fit, int((caps[di] - used[di] + eps)
                                           // dv))
                fit = max(min(fit, dev.max_resident - blocks), 0)
                if fit == 0:
                    if blocks == 0:
                        fit = 1  # oversized block: runs alone regardless
                    else:
                        break  # strict FIFO: head closes the round
                for di in range(dims_n):
                    used[di] += dem[di] * fit
                blocks += fit
                inst += inst_b * fit
                mem += mem_b * fit
                e[1] -= fit
                if e[1] == 0:
                    head += 1
                if head < n_pend and pending[head][0] is k:
                    break  # partially admitted head: unit is full
            occ = used[self._sat_idx] if self._sat_idx >= 0 else 0.0
            eff_c = max(self._eff(occ, dev.sat_compute), eps)
            eff_m = max(self._eff(occ, dev.sat_memory), eps)
            total += max(inst / (dev.compute_rate * eff_c),
                         mem / (dev.mem_bw * eff_m))
        return total, ckpts


class DeltaRoundEvaluator:
    """Suffix re-simulation of locally modified orders under the
    RoundSimulator, against a cached base order."""

    def __init__(self, device: DeviceModel):
        self.sim = _FastRoundSim(device)
        self._base: list[KernelProfile] = []
        self._ckpts: list[RoundCheckpoint] = []
        self._total = 0.0

    def rebase(self, order: Sequence[KernelProfile]) -> float:
        """Full simulation of ``order``; caches its round checkpoints."""
        self._base = list(order)
        self._total, self._ckpts = self.sim.simulate(self._base,
                                                     record=True)
        return self._total

    def evaluate(self, cand: Sequence[KernelProfile],
                 first_changed: int) -> float:
        """Time of ``cand``, which must equal the base order at every
        position < ``first_changed``.  Equal to
        ``RoundSimulator.simulate(cand)`` exactly."""
        return self.evaluate_costed(cand, first_changed)[0]

    def evaluate_costed(self, cand: Sequence[KernelProfile],
                        first_changed: int) -> tuple[float, float]:
        """As :meth:`evaluate`, plus the evaluation's cost as a
        fraction of a full re-simulation (suffix length / n)."""
        # Only checkpoints strictly before the first changed position
        # are safe: the round preceding a checkpoint at position p
        # closed by examining the kernel at p (failed or partial
        # admission), so a checkpoint at p == first_changed encodes a
        # decision taken against the *old* kernel there.
        best: RoundCheckpoint | None = None
        for cp in self._ckpts:
            if cp.pos < first_changed:
                best = cp
            else:
                break
        if best is None:
            return self.sim.simulate(cand)[0], 1.0
        frac = (len(cand) - best.pos) / max(len(cand), 1)
        t = self.sim.simulate(cand, start_pos=best.pos,
                              head_blocks=best.blocks_left,
                              t0=best.time)[0]
        return t, frac

    def round_boundaries(self) -> list[int]:
        """Order positions at which the base's rounds open."""
        return [cp.pos for cp in self._ckpts]


def _moves(n: int, neighborhood: str) -> list[tuple[int, str, int, int]]:
    """Candidate moves as (first_changed, kind, i, j)."""
    moves: list[tuple[int, str, int, int]] = []
    if neighborhood == "adjacent":
        for i in range(n - 1):
            moves.append((i, "swap", i, i + 1))
        for i in range(n):
            for j in (i - 2, i + 2):
                if 0 <= j < n:
                    moves.append((min(i, j), "move", i, j))
        return moves
    if neighborhood != "full":
        raise ValueError(f"unknown neighborhood {neighborhood!r} "
                         "(expected 'full', 'adjacent' or 'auto')")
    for i in range(n - 1):
        for j in range(i + 1, n):
            moves.append((i, "swap", i, j))
    for i in range(n):
        for j in range(n):
            if i != j:
                moves.append((min(i, j), "move", i, j))
    return moves


def _apply(base: list[KernelProfile], kind: str, i: int,
           j: int) -> list[KernelProfile]:
    cand = list(base)
    if kind == "swap":
        cand[i], cand[j] = cand[j], cand[i]
    else:
        k = cand.pop(i)
        cand.insert(j, k)
    return cand


def refine_order(
    order: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    time_fn: Callable[[Sequence[KernelProfile]], float] | None = None,
    budget: int = 2000,
    model: str = "event",
    neighborhood: str = "full",
) -> tuple[list[KernelProfile], float, int]:
    """Hill-climb ``order`` under ``time_fn``.

    With the default ``time_fn`` and ``model="round"``, candidates are
    delta-evaluated (suffix re-simulation); any custom ``time_fn`` or
    the event model falls back to full evaluation per candidate.

    ``budget`` is charged in *full-simulation equivalents*: a delta
    evaluation that re-simulates only the last k of n positions costs
    ``k/n``, so the same budget buys roughly an order of magnitude
    more candidate moves on the delta path (the count of candidates
    actually tried is the third return value, can exceed ``budget``,
    and is capped at ``10 * budget`` so wall time stays proportional
    to the budget).

    With ``neighborhood="adjacent"`` moves are tried boundary-first:
    only moves that straddle a round boundary of the incumbent order
    can change round composition under the round model, so they are
    evaluated before intra-round shuffles, cheapest (latest suffix)
    first within each class.  The "full" move set keeps plain
    enumeration order so the delta path retraces the reference
    trajectory exactly.

    Returns ``(best_order, best_time, evaluations_used)``.
    """
    n = len(order)
    if neighborhood == "auto":
        # Full neighbourhood while it still dominates the reference
        # within a serving budget; past that, local (adjacent) moves
        # spread a small budget across every round boundary instead of
        # burning it on early-position swaps.
        neighborhood = "full" if n <= 128 else "adjacent"
    use_delta = time_fn is None and model == "round"
    delta = DeltaRoundEvaluator(device) if use_delta else None
    if time_fn is None:
        time_fn = lambda o: simulate(o, device, model=model)  # noqa: E731
    best = list(order)
    best_t = delta.rebase(best) if use_delta else time_fn(best)
    cost = 1.0
    evals = 1
    eval_cap = 10 * budget if use_delta else budget
    improved = True
    while improved and cost < budget and evals < eval_cap:
        improved = False
        moves = _moves(n, neighborhood)
        if use_delta and neighborhood == "adjacent":
            near = [False] * (n + 1)
            for b in delta.round_boundaries():
                for p in (b - 1, b, b + 1):
                    if 0 <= p < n:
                        near[p] = True
            moves.sort(key=lambda m: (not (near[m[2]] or near[m[3]]),
                                      -m[0]))
        for first, kind, i, j in moves:
            if cost >= budget or evals >= eval_cap:
                break
            cand = _apply(best, kind, i, j)
            if use_delta:
                t, frac = delta.evaluate_costed(cand, first)
                cost += frac
            else:
                t = time_fn(cand)
                cost += 1.0
            evals += 1
            if t < best_t - 1e-15:
                best, best_t, improved = cand, t, True
                if use_delta:
                    # Rebasing is not charged: the budget prices
                    # candidate evaluations only, so on the full move
                    # set the delta path's cumulative cost is <= the
                    # reference's at every trajectory point — it
                    # retraces the reference trajectory and then keeps
                    # going, guaranteeing a result no worse.
                    delta.rebase(best)
    return best, best_t, evals


def refined_schedule(
    kernels: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    budget: int = 2000,
    model: str = "event",
    neighborhood: str = "full",
) -> tuple[list[KernelProfile], float]:
    """Algorithm 1 (incremental fast path — identical schedules to the
    reference) followed by local search.  Returns (order, time)."""
    sched: Schedule = greedy_order_fast(kernels, device)
    order, t, _ = refine_order(sched.order, device, budget=budget,
                               model=model, neighborhood=neighborhood)
    return order, t
