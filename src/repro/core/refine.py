"""Beyond-paper: simulator-guided local refinement of the launch order.

Algorithm 1 is profile-greedy — it never consults a timing model.  When
a timing model *is* available at scheduling time (always true for the
TPU serving/training substrates, where the roofline cost of every task
is known), the launch order can be polished by local search around the
greedy solution:

* pairwise swaps,
* single-kernel reinsertions (remove + insert at every position),

accepting strict improvements until a local optimum or the evaluation
budget is reached.  The greedy order is both the starting point and the
fallback, so the refined order is never worse than Algorithm 1's.

This mirrors what the paper's own Fig. 1 suggests: the greedy lands
above the 90th percentile, and a small neighbourhood search closes most
of the remaining gap to the optimum at negligible cost (the simulator
evaluates an 8-kernel order in well under a millisecond, against a
40,320-point design space).

Complexity / when to use which path
-----------------------------------
A naive candidate evaluation re-simulates the whole order: ``O(n)``
rounds (or all dispatch events) per candidate, ``O(n^3)`` per full
neighbourhood sweep.  Two levers make refinement affordable at serving
scale:

* **Delta evaluation** (automatic for ``model="round"`` *and*
  ``model="event"`` with no custom ``time_fn``): the
  :class:`DeltaEvaluator` caches the simulator's admission checkpoints
  for the incumbent order, so a candidate differing only at positions
  >= p re-simulates just the suffix from the last checkpoint before p
  — ``O(n - p)`` instead of ``O(n)``.  Under the round model the
  checkpoints are the :class:`~repro.core.simulator.RoundCheckpoint`
  round boundaries; under the event model every order position gets an
  :class:`~repro.core.simulator.EventCheckpoint` capturing the full
  dispatcher state (per-unit residency, cohort fractions, round-robin
  pointer) at the instant that position is first examined.  The budget
  is charged in full-simulation equivalents (a suffix re-sim costs its
  fraction), so the default serving budget buys roughly an order of
  magnitude more effective moves; on the adjacent move set, moves
  straddling a round boundary are tried first, cheapest (latest
  suffix) first within each class ("early-exit ordering" — under the
  event model every position is a boundary, so moves are simply tried
  cheapest first).
* **``neighborhood="adjacent"``**: restrict moves to adjacent swaps
  and short-range reinsertions — ``O(n)`` candidates per sweep instead
  of ``O(n^2)``.  This is the right regime on a serving hot path
  (``n`` in the hundreds): a fixed budget spent on ``(0, j)`` swaps of
  a full sweep barely touches the order, while adjacent moves spread
  it across every round boundary.  ``"auto"`` picks ``"full"`` up to
  128 kernels (where it still dominates the reference within a
  serving budget) and ``"adjacent"`` above; ``"full"`` remains the
  offline default.

Delta-evaluated times are *exactly* equal to full re-simulation
(property-tested in ``tests/test_fastscore.py`` for the round model
and ``tests/test_event_delta.py`` for the event model): resuming from
a checkpoint replays the identical float accumulation.  The fast
simulators in this module (:class:`_FastRoundSim`,
:class:`_FastEventSim`) are operation-for-operation ports of their
reference oracles with per-kernel profile data resolved to flat tuples
once, which is what makes thousands of suffix re-simulations per
refinement affordable.

Both built-in models here are *flat* — every kernel free to
co-schedule.  Dependency-carrying orders have their own currency (the
ready-set gated dispatcher) and their own evaluator built on this
module's discipline: :class:`repro.graph.delta.GatedDeltaEvaluator`
subclasses :class:`DeltaEvaluator` with a gated fast simulator, and
:func:`repro.graph.constrained.refine_order_dag` (``model="gated"``)
is the precedence-respecting counterpart of :func:`refine_order`.
"""

from __future__ import annotations

import math
from time import perf_counter
from typing import Callable, Sequence

from .fastscore import greedy_order_fast
from .resources import DeviceModel, KernelProfile
from .scheduler import Schedule
from .simulator import EventCheckpoint, RoundCheckpoint, simulate

__all__ = ["refine_order", "refined_schedule", "DeltaEvaluator",
           "DeltaRoundEvaluator"]


class _FastRoundSim:
    """RoundSimulator with per-kernel profile data precomputed once.

    Bit-identical arithmetic to :class:`RoundSimulator.simulate` —
    the same operations on the same floats in the same order — but
    demand dicts, per-unit block counts and per-block memory traffic
    are resolved to flat tuples a single time per kernel object, which
    is what makes thousands of suffix re-simulations per refinement
    affordable."""

    _EPS = 1e-12

    def __init__(self, device: DeviceModel):
        self.device = device
        self._dims = tuple(device.caps)
        self._caps = tuple(device.cap(d) for d in self._dims)
        self._sat_idx = (self._dims.index(device.sat_dim)
                         if device.sat_dim in self._dims else -1)
        self._info: dict[int, tuple] = {}

    def _kinfo(self, k: KernelProfile) -> tuple:
        # Keyed by id(k) — the cached entry holds a strong reference
        # to k so its id can never be recycled by a different profile.
        v = self._info.get(id(k))
        if v is None:
            v = (k, tuple(k.demands[d] for d in self._dims),
                 k.blocks_per_unit(self.device),
                 k.inst_per_block, k.mem_per_block())
            self._info[id(k)] = v
        return v

    def _eff(self, occ: float, sat: float) -> float:
        # Mirrors DeviceModel.compute_efficiency/memory_efficiency
        # exactly: a sat_dim that is not a tracked capacity dimension
        # (_sat_idx < 0 covers both sat_dim == "" and sat_dim not in
        # caps) carries no occupancy signal — run at peak.
        if self._sat_idx < 0:
            return 1.0
        return min(1.0, occ / sat)

    def simulate(self, order: Sequence[KernelProfile],
                 start_pos: int = 0, head_blocks: int | None = None,
                 t0: float = 0.0, record: bool = False, trace=None
                 ) -> tuple[float, list[RoundCheckpoint]]:
        dev = self.device
        dims_n = len(self._dims)
        caps = self._caps
        eps = self._EPS
        pending: list[list] = []
        for p in range(start_pos, len(order)):
            k = order[p]
            _, dem, bpu, inst_b, mem_b = self._kinfo(k)
            nb = head_blocks if (p == start_pos and
                                 head_blocks is not None) else bpu
            pending.append([k, nb, p, dem, inst_b, mem_b])
        total = t0
        ckpts: list[RoundCheckpoint] = []
        head = 0
        n_pend = len(pending)
        r_idx = 0
        while head < n_pend:
            if record:
                e = pending[head]
                ckpts.append(RoundCheckpoint(pos=e[2], blocks_left=e[1],
                                             time=total))
            used = [0.0] * dims_n
            blocks, inst, mem = 0, 0.0, 0.0
            members: list = []
            while head < n_pend:
                e = pending[head]
                k, nb, _, dem, inst_b, mem_b = e
                fit = nb
                for di in range(dims_n):
                    dv = dem[di]
                    if dv > 0:
                        fit = min(fit, int((caps[di] - used[di] + eps)
                                           // dv))
                fit = max(min(fit, dev.max_resident - blocks), 0)
                if fit == 0:
                    if blocks == 0:
                        fit = 1  # oversized block: runs alone regardless
                    else:
                        break  # strict FIFO: head closes the round
                for di in range(dims_n):
                    used[di] += dem[di] * fit
                blocks += fit
                inst += inst_b * fit
                mem += mem_b * fit
                if trace is not None:
                    members.append((k.name, fit))
                e[1] -= fit
                if e[1] == 0:
                    head += 1
                if head < n_pend and pending[head][0] is k:
                    break  # partially admitted head: unit is full
            occ = used[self._sat_idx] if self._sat_idx >= 0 else 0.0
            eff_c = max(self._eff(occ, dev.sat_compute), eps)
            eff_m = max(self._eff(occ, dev.sat_memory), eps)
            r_start = total
            total += max(inst / (dev.compute_rate * eff_c),
                         mem / (dev.mem_bw * eff_m))
            if trace is not None:
                for name, fit_ in members:
                    trace.span(0, name, r_start, total, blocks=fit_,
                               cat="round-member")
                trace.instant(f"round {r_idx}", total, unit=0,
                              cat="round")
                trace.add_busy(0, total - r_start)
            r_idx += 1
        return total, ckpts


class _FastEventSim:
    """EventSimulator with per-kernel profile data precomputed once.

    Bit-identical arithmetic to :class:`EventSimulator.simulate` — the
    same operations on the same floats in the same order — over flat
    tuples instead of demand dicts and dataclasses.  Unit state is a
    list ``[used, n_resident, cohorts, lam]`` (``used`` a list in
    ``device.caps`` order); a cohort is a list ``[kernel, n_blocks,
    frac_left, t_admit, inst_per_block, mem_per_block, demands,
    inst * n_blocks, mem * n_blocks]`` — the two trailing work
    products are refreshed on merge by the same multiplication the
    reference performs inside ``recompute_rate``, so caching them
    changes no float.  Produces and consumes the same
    :class:`EventCheckpoint` format as the reference, so checkpoints
    are interchangeable between the two implementations
    (property-tested in ``tests/test_event_delta.py``).
    """

    _EPS = 1e-12

    def __init__(self, device: DeviceModel):
        self.device = device
        self._dims = tuple(device.caps)
        self._caps = tuple(device.cap(d) for d in self._dims)
        self._sat_idx = (self._dims.index(device.sat_dim)
                         if device.sat_dim in self._dims else -1)
        self._crate = device.compute_rate
        self._mbw = device.mem_bw
        self._satc = device.sat_compute
        self._satm = device.sat_memory
        self._info: dict[int, tuple] = {}

    def _kinfo(self, k: KernelProfile) -> tuple:
        v = self._info.get(id(k))
        if v is None:
            v = (k, tuple(k.demands[d] for d in self._dims),
                 k.n_blocks, k.inst_per_block, k.mem_per_block())
            self._info[id(k)] = v
        return v

    def _eff(self, occ: float, sat: float) -> float:
        if self._sat_idx < 0:
            return 1.0
        return min(1.0, occ / sat)

    def _rate(self, u: list) -> None:
        cohorts = u[2]
        if not cohorts:
            u[3] = 0.0
            return
        eps = self._EPS
        # sum() over a list is the same left fold (0 + x0 + x1 + ...)
        # as the reference's generator sum — identical floats.
        sum_c = sum([c[7] for c in cohorts])
        sum_m = sum([c[8] for c in cohorts])
        si = self._sat_idx
        if si < 0:
            eff_c = eff_m = 1.0
        else:
            occ = u[0][si]
            eff_c = max(min(1.0, occ / self._satc), eps)
            eff_m = max(min(1.0, occ / self._satm), eps)
        u[3] = min(self._crate * eff_c / max(sum_c, eps),
                   self._mbw * eff_m / max(sum_m, eps))

    def simulate(self, order: Sequence[KernelProfile],
                 start_state: EventCheckpoint | None = None,
                 record: bool = False, trace=None
                 ) -> tuple[float, list[EventCheckpoint]]:
        dev = self.device
        dims_n = len(self._dims)
        caps = self._caps
        eps = self._EPS
        n_units = dev.n_units
        max_res = dev.max_resident
        if start_state is None:
            units = [[[0.0] * dims_n, 0, [], 0.0] for _ in range(n_units)]
            start_pos, rr, t = 0, 0, 0.0
        else:
            units = []
            for used, n_res, cohorts in start_state.units:
                cs = []
                for k, nb, fl, ta in cohorts:
                    _, dem, _, inst_b, mem_b = self._kinfo(k)
                    cs.append([k, nb, fl, ta, inst_b, mem_b, dem,
                               inst_b * nb, mem_b * nb])
                u = [list(used), n_res, cs, 0.0]
                self._rate(u)
                units.append(u)
            start_pos, rr, t = (start_state.pos, start_state.rr,
                                start_state.time)
        # Strict-FIFO queue of [kernel, blocks left, pos, dem, inst, mem].
        pending: list[list] = []
        for p in range(start_pos, len(order)):
            k = order[p]
            _, dem, nb, inst_b, mem_b = self._kinfo(k)
            pending.append([k, nb, p, dem, inst_b, mem_b])
        head = 0
        n_pend = len(pending)
        ckpts: list[EventCheckpoint] = []
        next_ckpt = start_pos
        # Total resident blocks across units: an integer mirror of
        # "any unit has cohorts", maintained incrementally so the event
        # loop avoids a per-event generator scan.
        n_res_total = sum(u[1] for u in units)

        def snapshot(pos: int, blocks_left: int) -> EventCheckpoint:
            return EventCheckpoint(
                pos=pos, blocks_left=blocks_left, time=t, rr=rr,
                units=tuple((tuple(u[0]), u[1],
                             tuple((c[0], c[1], c[2], c[3])
                                   for c in u[2]))
                            for u in units))

        def try_admit(pending=pending, units=units, caps=caps,
                      dims_r=range(dims_n), units_r=range(n_units),
                      n_units=n_units, max_res=max_res, eps=eps,
                      record=record, rate=self._rate) -> None:
            # Closure-invariant state is bound as defaults (LOAD_FAST)
            # — this function dominates the suffix re-simulation cost.
            nonlocal rr, head, next_ckpt, n_res_total
            touched: set[int] = set()
            # Within one call, per-unit capacity only shrinks, so a
            # unit that rejected the current head kernel rejects it for
            # the rest of the call: remember and skip (first-fit order
            # is unchanged — skipped units would reject again).
            cur_k = None
            rejected: set[int] = set()
            while head < n_pend:
                e = pending[head]
                k, pos, dem = e[0], e[2], e[3]
                if k is not cur_k:
                    cur_k = k
                    rejected = set()
                if record and pos == next_ckpt:
                    ckpts.append(snapshot(pos, e[1]))
                    next_ckpt = pos + 1
                placed = False
                for off in units_r:
                    ui = rr + off
                    if ui >= n_units:
                        ui -= n_units
                    if ui in rejected:
                        continue
                    u = units[ui]
                    if u[1] + 1 > max_res:
                        rejected.add(ui)
                        continue
                    used = u[0]
                    ok = True
                    for di in dims_r:
                        if not used[di] + dem[di] <= caps[di] + eps:
                            ok = False
                            break
                    if not ok:
                        rejected.add(ui)
                        continue
                    for di in dims_r:
                        used[di] += dem[di]
                    u[1] += 1
                    n_res_total += 1
                    # Merge only into a same-instant cohort; scanned in
                    # reverse because a (kernel, instant) cohort is
                    # unique per unit and recent cohorts sit at the
                    # tail.  The work products (c[7], c[8]) are
                    # refreshed by the same multiplication the
                    # reference's recompute_rate performs.
                    for c in reversed(u[2]):
                        if c[0] is k and c[3] == t:
                            c[1] += 1
                            c[7] = c[4] * c[1]
                            c[8] = c[5] * c[1]
                            break
                    else:
                        u[2].append([k, 1, 1.0, t, e[4], e[5], dem,
                                     e[4], e[5]])
                    touched.add(ui)
                    rr = ui + 1
                    if rr >= n_units:
                        rr -= n_units
                    e[1] -= 1
                    if e[1] == 0:
                        head += 1
                    placed = True
                    break
                if not placed:
                    break  # head blocks the queue (strict FIFO)
            for ui in touched:
                rate(units[ui])

        try_admit()
        guard = 0
        while head < n_pend or n_res_total:
            guard += 1
            if guard > 1_000_000:
                raise RuntimeError("_FastEventSim failed to converge")
            if not n_res_total:
                # Oversized head runs alone (see EventSimulator).
                e = pending[head]
                head += 1
                nb, dem, inst_b, mem_b = e[1], e[3], e[4], e[5]
                occ = dem[self._sat_idx] if self._sat_idx >= 0 else 0.0
                eff_c = max(self._eff(occ, dev.sat_compute), eps)
                eff_m = max(self._eff(occ, dev.sat_memory), eps)
                t1 = max(inst_b / (dev.compute_rate * eff_c),
                         mem_b / (dev.mem_bw * eff_m))
                for p in range(math.ceil(nb / n_units)):
                    t += t1
                    if trace is not None:
                        for ui in range(min(n_units, nb - p * n_units)):
                            trace.span(ui, e[0].name, t - t1, t,
                                       blocks=1, cat="solo")
                            trace.add_busy(ui, t1)
                try_admit()
                continue
            dt = min([c[2] / u[3] for u in units if u[2] for c in u[2]])
            t += dt
            freed = False
            for ui, u in enumerate(units):
                cohorts = u[2]
                if not cohorts:
                    continue
                if trace is not None:
                    trace.add_busy(ui, dt)
                lam = u[3]
                done = []
                for c in cohorts:
                    c[2] -= lam * dt
                    if c[2] <= 1e-9:
                        done.append(c)
                if done:
                    freed = True
                    used = u[0]
                    for c in done:
                        cohorts.remove(c)
                        dem, nb = c[6], c[1]
                        for di in range(dims_n):
                            used[di] -= dem[di] * nb
                        u[1] -= nb
                        n_res_total -= nb
                        if trace is not None:
                            trace.span(ui, c[0].name, c[3], t,
                                       blocks=nb)
                    self._rate(u)
            if freed:
                try_admit()
        return t, ckpts


class DeltaEvaluator:
    """Suffix re-simulation of locally modified orders against a
    cached base order, generic over the timing model.

    ``model="round"`` caches :class:`RoundCheckpoint` round boundaries
    (one per round; a checkpoint at position p is usable for candidates
    changed strictly after p, because the round that closed at p did so
    by examining the old kernel there).  ``model="event"`` caches one
    :class:`EventCheckpoint` per order position, captured before any
    block of that position is dispatched — so the checkpoint *at* the
    first changed position is itself usable, and every move resumes
    from the latest possible dispatcher state.

    The gated DAG currency reuses the event discipline through the
    subclass :class:`repro.graph.delta.GatedDeltaEvaluator` (its
    simulator enforces the ready-set admission gate; checkpoints stay
    plain :class:`EventCheckpoint`).
    """

    def __init__(self, device: DeviceModel, model: str = "round"):
        if model == "round":
            self.sim: _FastRoundSim | _FastEventSim = _FastRoundSim(device)
        elif model == "event":
            self.sim = _FastEventSim(device)
        else:
            raise ValueError(f"unknown model {model!r} "
                             "(expected 'round' or 'event'; for the "
                             "gated DAG model use "
                             "repro.graph.delta.GatedDeltaEvaluator)")
        self.model = model
        #: one checkpoint per order position (event-style models) vs
        #: one per round boundary; subclasses with their own simulator
        #: (repro.graph.delta.GatedDeltaEvaluator) set this directly.
        self._per_position = model == "event"
        self._base: list[KernelProfile] = []
        self._ckpts: list = []
        self._total = 0.0

    def rebase(self, order: Sequence[KernelProfile],
               trace=None) -> float:
        """Full simulation of ``order``; caches its checkpoints.
        ``trace`` forwards to the fast simulator's recorder hook."""
        self._base = list(order)
        self._total, self._ckpts = self.sim.simulate(self._base,
                                                     record=True,
                                                     trace=trace)
        return self._total

    def rebase_incremental(self, order: Sequence[KernelProfile],
                           first_changed: int) -> float:
        """Rebase onto ``order``, which must equal the current base at
        every position < ``first_changed`` (an accepted local move).

        The checkpoint prefix before the resume point is still valid
        for the new base — the simulation up to it examined only
        unchanged positions — so only the suffix is re-simulated with
        recording and the two checkpoint lists are stitched.  Produces
        bit-identical state to a full :meth:`rebase` (property-tested)
        at suffix cost, which keeps accepted moves as cheap as
        evaluating them.
        """
        if self._per_position:
            if first_changed < len(self._ckpts):
                cp = self._ckpts[first_changed]
                t, suffix = self.sim.simulate(order, start_state=cp,
                                              record=True)
                self._base = list(order)
                self._ckpts = self._ckpts[:first_changed] + suffix
                self._total = t
                return t
            return self.rebase(order)
        best: RoundCheckpoint | None = None
        idx = 0
        for i, cp in enumerate(self._ckpts):
            if cp.pos < first_changed:
                best, idx = cp, i
            else:
                break
        if best is None:
            return self.rebase(order)
        t, suffix = self.sim.simulate(order, start_pos=best.pos,
                                      head_blocks=best.blocks_left,
                                      t0=best.time, record=True)
        self._base = list(order)
        self._ckpts = self._ckpts[:idx] + suffix
        self._total = t
        return t

    def evaluate(self, cand: Sequence[KernelProfile],
                 first_changed: int) -> float:
        """Time of ``cand``, which must equal the base order at every
        position < ``first_changed``.  Exactly equal to a full
        re-simulation of ``cand`` under the evaluator's model."""
        return self.evaluate_costed(cand, first_changed)[0]

    def evaluate_costed(self, cand: Sequence[KernelProfile],
                        first_changed: int,
                        trace=None) -> tuple[float, float]:
        """As :meth:`evaluate`, plus the evaluation's cost as a
        fraction of a full re-simulation (suffix length / n).

        ``trace`` forwards to the suffix re-simulation (the batched
        engines' exact verification re-sims attach their recorder
        here); a checkpoint-resumed suffix only records spans from the
        resume point on.
        """
        if self._per_position:
            # One checkpoint per position, captured before any block
            # of that position was dispatched: the checkpoint at
            # first_changed depends only on earlier positions.
            if first_changed < len(self._ckpts):
                cp = self._ckpts[first_changed]
                frac = (len(cand) - cp.pos) / max(len(cand), 1)
                return self.sim.simulate(cand, start_state=cp,
                                         trace=trace)[0], frac
            return self.sim.simulate(cand, trace=trace)[0], 1.0
        # Round model: only checkpoints strictly before the first
        # changed position are safe — the round preceding a checkpoint
        # at position p closed by examining the kernel at p (failed or
        # partial admission), so a checkpoint at p == first_changed
        # encodes a decision taken against the *old* kernel there.
        best: RoundCheckpoint | None = None
        for cp in self._ckpts:
            if cp.pos < first_changed:
                best = cp
            else:
                break
        if best is None:
            return self.sim.simulate(cand, trace=trace)[0], 1.0
        frac = (len(cand) - best.pos) / max(len(cand), 1)
        t = self.sim.simulate(cand, start_pos=best.pos,
                              head_blocks=best.blocks_left,
                              t0=best.time, trace=trace)[0]
        return t, frac

    def boundaries(self) -> list[int] | None:
        """Admission-boundary positions of the base order, or ``None``
        when every position is one (event-style models)."""
        if self._per_position:
            return None
        return [cp.pos for cp in self._ckpts]

    def round_boundaries(self) -> list[int]:
        """Order positions at which the base's rounds open (round
        model; kept for backward compatibility)."""
        return [cp.pos for cp in self._ckpts]


class DeltaRoundEvaluator(DeltaEvaluator):
    """Backward-compatible alias: the round-model delta evaluator."""

    def __init__(self, device: DeviceModel):
        super().__init__(device, model="round")


def _moves(n: int, neighborhood: str) -> list[tuple[int, str, int, int]]:
    """Candidate moves as (first_changed, kind, i, j)."""
    moves: list[tuple[int, str, int, int]] = []
    if neighborhood == "adjacent":
        for i in range(n - 1):
            moves.append((i, "swap", i, i + 1))
        for i in range(n):
            for j in (i - 2, i + 2):
                if 0 <= j < n:
                    moves.append((min(i, j), "move", i, j))
        return moves
    if neighborhood != "full":
        raise ValueError(f"unknown neighborhood {neighborhood!r} "
                         "(expected 'full', 'adjacent' or 'auto')")
    for i in range(n - 1):
        for j in range(i + 1, n):
            moves.append((i, "swap", i, j))
    for i in range(n):
        for j in range(n):
            if i != j:
                moves.append((min(i, j), "move", i, j))
    return moves


def _apply(base: list[KernelProfile], kind: str, i: int,
           j: int) -> list[KernelProfile]:
    cand = list(base)
    if kind == "swap":
        cand[i], cand[j] = cand[j], cand[i]
    else:
        k = cand.pop(i)
        cand.insert(j, k)
    return cand


def refine_order(
    order: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    time_fn: Callable[[Sequence[KernelProfile]], float] | None = None,
    budget: int = 2000,
    model: str = "event",
    neighborhood: str = "full",
    batch_size: int | None = None,
    table=None,
    metrics=None,
) -> tuple[list[KernelProfile], float, int]:
    """Hill-climb ``order`` under ``time_fn``.

    ``metrics`` (a :class:`repro.obs.MetricsRegistry`) records the
    refinement's budget accounting — candidate evaluations under
    ``refine_evals``, charged full-simulation-equivalent cost under
    ``refine_cost``, and the scoring pass's wall clock under the
    ``refine_score_s`` histogram.  Purely additive: the search
    trajectory is unchanged.

    With the default ``time_fn``, candidates are delta-evaluated
    (suffix re-simulation from cached admission checkpoints) under
    both built-in models — ``model="round"`` and ``model="event"``;
    any custom ``time_fn`` falls back to full evaluation per candidate.

    ``batch_size`` routes to the batched evaluator
    (:func:`repro.core.batched.refine_order_batched`): the move
    neighborhood is scored in vectorized ``(B, n)`` passes and the
    improving moves re-verified exactly, same budget accounting.
    Requires the default ``time_fn``.  ``table`` threads an
    already-built :class:`~repro.core.fastscore.ProfileTable` through
    so a greedy + refine pipeline packs the kernel set exactly once.

    ``budget`` is charged in *full-simulation equivalents*: a delta
    evaluation that re-simulates only the last k of n positions costs
    ``k/n``, so the same budget buys roughly an order of magnitude
    more candidate moves on the delta path (the count of candidates
    actually tried is the third return value, can exceed ``budget``,
    and is capped at ``10 * budget`` so wall time stays proportional
    to the budget).

    With ``neighborhood="adjacent"`` moves are tried boundary-first:
    only moves that straddle a round boundary of the incumbent order
    can change round composition under the round model, so they are
    evaluated before intra-round shuffles, cheapest (latest suffix)
    first within each class.  Under the event model every position is
    an admission boundary, so moves are simply tried cheapest first.
    The "full" move set keeps plain enumeration order so the delta
    path retraces the reference trajectory exactly.

    Returns ``(best_order, best_time, evaluations_used)``.
    """
    n = len(order)
    if batch_size is not None and time_fn is None \
            and model in ("round", "event"):
        from repro.core.batched import refine_order_batched

        return refine_order_batched(
            order, device, model=model, budget=budget,
            neighborhood=neighborhood, batch_size=batch_size,
            table=table, metrics=metrics)
    t_wall = perf_counter()
    if neighborhood == "auto":
        # Full neighbourhood while it still dominates the reference
        # within a serving budget; past that, local (adjacent) moves
        # spread a small budget across every round boundary instead of
        # burning it on early-position swaps.
        neighborhood = "full" if n <= 128 else "adjacent"
    use_delta = time_fn is None and model in ("round", "event")
    delta = DeltaEvaluator(device, model=model) if use_delta else None
    if time_fn is None:
        time_fn = lambda o: simulate(o, device, model=model)  # noqa: E731
    best = list(order)
    best_t = delta.rebase(best) if use_delta else time_fn(best)
    cost = 1.0
    evals = 1
    eval_cap = 10 * budget if use_delta else budget
    improved = True
    while improved and cost < budget and evals < eval_cap:
        improved = False
        moves = _moves(n, neighborhood)
        if use_delta and neighborhood == "adjacent":
            bounds = delta.boundaries()
            if bounds is None:
                # Event model: every position is a boundary — try the
                # cheapest (latest-suffix) moves first.
                moves.sort(key=lambda m: -m[0])
            else:
                near = [False] * (n + 1)
                for b in bounds:
                    for p in (b - 1, b, b + 1):
                        if 0 <= p < n:
                            near[p] = True
                moves.sort(key=lambda m: (not (near[m[2]] or near[m[3]]),
                                          -m[0]))
        for first, kind, i, j in moves:
            if cost >= budget or evals >= eval_cap:
                break
            cand = _apply(best, kind, i, j)
            if use_delta:
                t, frac = delta.evaluate_costed(cand, first)
                cost += frac
            else:
                t = time_fn(cand)
                cost += 1.0
            evals += 1
            if t < best_t - 1e-15:
                best, best_t, improved = cand, t, True
                if use_delta:
                    # Rebasing is not charged: the budget prices
                    # candidate evaluations only, so on the full move
                    # set the delta path's cumulative cost is <= the
                    # reference's at every trajectory point — it
                    # retraces the reference trajectory and then keeps
                    # going, guaranteeing a result no worse.  The
                    # incremental rebase stitches the still-valid
                    # checkpoint prefix with a recorded suffix re-sim,
                    # so acceptance costs no more than evaluation did.
                    delta.rebase_incremental(best, first)
    if metrics is not None:
        metrics.counter("refine_evals").inc(evals)
        metrics.counter("refine_cost").inc(cost)
        metrics.histogram("refine_score_s").observe(
            perf_counter() - t_wall)
    return best, best_t, evals


def refined_schedule(
    kernels: Sequence[KernelProfile],
    device: DeviceModel,
    *,
    budget: int = 2000,
    model: str = "event",
    neighborhood: str = "full",
    batch_size: int | None = None,
) -> tuple[list[KernelProfile], float]:
    """Algorithm 1 (incremental fast path — identical schedules to the
    reference) followed by local search.  Returns (order, time).

    The :class:`~repro.core.fastscore.ProfileTable` built for the
    greedy is threaded into the refiner, so the pipeline packs the
    kernel set exactly once (the batched path reuses its cached device
    arrays too)."""
    from .fastscore import ProfileTable

    table = ProfileTable.build(kernels, device)
    sched: Schedule = greedy_order_fast(kernels, device, table=table)
    order, t, _ = refine_order(sched.order, device, budget=budget,
                               model=model, neighborhood=neighborhood,
                               batch_size=batch_size, table=table)
    return order, t
