"""The six concurrent-kernel experiments of the paper (Table 2).

Each experiment is a list of :class:`KernelProfile` for the GTX 580
device model.  Geometry, shared-memory footprints, warp counts and
inst/bytes ratios follow Table 2; absolute instruction counts are
scaled so the standalone times have the same order of magnitude as the
published tables (the algorithm never sees them).
"""

from __future__ import annotations

from typing import Callable

from .resources import (GTX580, KernelProfile, bs_kernel, ep_kernel,
                        es_kernel, sw_kernel)

__all__ = ["EXPERIMENTS", "experiment"]


def _ep_6_shm() -> list[KernelProfile]:
    # Six EP kernels, grid 16 x block 128, shm 8K..48K (per SM == per block).
    return [ep_kernel(f"EP-shm{s // 1024}K", shm=s)
            for s in (8192, 16384, 24576, 32768, 40960, 49152)]


def _ep_6_grid() -> list[KernelProfile]:
    # Warps/SM 4..24 via grid 16..96; work scales with grid size.
    return [ep_kernel(f"EP-g{g}", grid=g, inst=60e6)
            for g in (16, 32, 48, 64, 80, 96)]


def _bs_6_blk() -> list[KernelProfile]:
    # Grid 32 (2 blocks/SM); block size 64..1024 => warps/SM 4..64.
    # Per-block work scales with block size (same per-thread work).
    out = []
    for bs in (64, 128, 256, 512, 768, 1024):
        out.append(bs_kernel(f"BS-b{bs}", grid=32, block=bs,
                             inst=220e6 * bs / 128))
    return out


def _epbs_6() -> list[KernelProfile]:
    eps = [ep_kernel(f"EP{i}", grid=16) for i in range(3)]     # 4 warps/SM
    bss = [bs_kernel(f"BS{i}", grid=32, block=192) for i in range(3)]  # 12 w/SM
    return eps + bss


def _epbs_6_shm() -> list[KernelProfile]:
    shms = (16384, 24576, 49152)
    eps = [ep_kernel(f"EP-shm{s // 1024}K", grid=16, shm=s) for s in shms]
    bss = [bs_kernel(f"BS-shm{s // 1024}K", grid=32, block=192, shm=s)
           for s in shms]
    return eps + bss


def _epbsessw_8() -> list[KernelProfile]:
    # Eight kernels, two per application, varying every resource metric.
    # All footprints are individually feasible on an SM (as the CUDA
    # occupancy calculator reports them to the profiler).
    return [
        ep_kernel("EP0", grid=16), ep_kernel("EP1", grid=32, shm=8192),
        bs_kernel("BS0", grid=32, block=192),
        bs_kernel("BS1", grid=48, block=128, shm=4096),
        es_kernel("ES0"),
        es_kernel("ES1", grid=32, shm=12288, inst=190e6),
        sw_kernel("SW0"),
        sw_kernel("SW1", grid=32, shm=12288, inst=90e6),
    ]


EXPERIMENTS: dict[str, Callable[[], list[KernelProfile]]] = {
    "EP-6-shm": _ep_6_shm,
    "EP-6-grid": _ep_6_grid,
    "BS-6-blk": _bs_6_blk,
    "EpBs-6": _epbs_6,
    "EpBs-6-shm": _epbs_6_shm,
    "EpBsEsSw-8": _epbsessw_8,
}


def experiment(name: str) -> list[KernelProfile]:
    return EXPERIMENTS[name]()
