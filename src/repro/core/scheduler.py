"""Algorithm 1 — Concurrent Kernel Launch Order Algorithm.

Greedy round construction exactly as published:

* pick the highest-scoring *pair* of remaining kernels to seed a round,
* order members within a round by decreasing shared-memory demand (so
  the heaviest shm consumer is launched first and releases earliest),
* virtually combine the round's profile (ProfileCombine) and keep
  absorbing the highest-scoring kernel that still fits,
* when nothing fits, open the next round.

The output is the flat launch order ``Rd_0 ++ Rd_1 ++ ...``.

Two baseline order generators (identity, random) and an exhaustive
permutation search are provided for design-space evaluation.

Complexity / when to use which path
-----------------------------------
This module is the **test-only oracle**: pure Python over
``KernelProfile`` objects, kept deliberately close to the paper's
pseudocode so property tests can diff the production path against it.
Each round re-scans the remaining pairs (``O(n^2)`` ``pair_score``
calls per round, each building per-unit demand dicts), so a full
schedule costs ``O(R * n^2)`` scored pairs — ``O(n^3)`` and beyond in
wall time: minutes at ``n = 1024`` (``BENCH_scheduler_scaling.json``).

:mod:`repro.core.fastscore` is the production path: it packs profiles
into NumPy arrays once, computes the pairwise matrix a single time
with broadcasting (``O(n^2 * D)``), and maintains only the 1xn score
vector of the current round's combined profile between absorptions
(``O(n * D)`` per absorption), for ``O(n^2 * D)`` total.  It produces
*identical* schedules (verified in ``tests/test_fastscore.py``).
Every non-test caller — the serving engine, the TPU round composer,
the train-side overlap scheduler, the examples and the paper-figure
benchmarks — goes through ``fastscore.greedy_order_fast``; new code
should never call :func:`greedy_order` outside a test or an explicit
oracle comparison (``benchmarks/scaling.py``'s reference path).

Both this oracle and the fast path assume every kernel is free to
co-schedule with every other.  When precedence edges exist (per-layer
chains of a traced model graph, producer/consumer kernels), use
:mod:`repro.graph` instead: ``greedy_order_dag`` is the ready-set
variant of the same algorithm (identical to the flat path on an empty
edge set), ``refine_order_dag`` the legal local search — with
``model="gated"`` it optimizes the gated makespan model
(``DagEventSimulator``, checkpointable since PR 5) directly via
``repro.graph.delta.GatedDeltaEvaluator``.  When a workload carries
stages too large to pack at all, go one layer further up to
:mod:`repro.slice` (lazy Kernelet-style slicing over the same greedy).
"""

from __future__ import annotations

import itertools
import random as _random
from dataclasses import dataclass, field
from typing import Callable, Sequence

from .resources import DeviceModel, KernelProfile
from .scorer import (fits_together, pair_score, profile_combine,
                     score_vector)

__all__ = [
    "Round",
    "Schedule",
    "greedy_order",
    "exhaustive_search",
    "random_orders",
    "percentile_rank",
]

#: Resource dimension used for the intra-round sort (paper: N_shm).  For
#: profiles lacking it the first declared dimension is used.
_SORT_DIM = "shm"


def _sort_key(k: KernelProfile, device: DeviceModel):
    d = k.per_unit_demand(device)
    if _SORT_DIM in d:
        return d[_SORT_DIM]
    return next(iter(d.values()), 0.0)


@dataclass
class Round:
    """One execution round: an ordered list of kernels."""

    kernels: list[KernelProfile] = field(default_factory=list)

    def insert_sorted(self, k: KernelProfile, device: DeviceModel) -> None:
        """Insert keeping decreasing shared-memory order (Alg. 1 line 6/10)."""
        key = _sort_key(k, device)
        for i, existing in enumerate(self.kernels):
            if key > _sort_key(existing, device):
                self.kernels.insert(i, k)
                return
        self.kernels.append(k)

    @property
    def names(self) -> list[str]:
        return [k.name for k in self.kernels]


@dataclass
class Schedule:
    rounds: list[Round]

    @property
    def order(self) -> list[KernelProfile]:
        return [k for rd in self.rounds for k in rd.kernels]

    @property
    def names(self) -> list[str]:
        return [k.name for k in self.order]


def greedy_order(kernels: Sequence[KernelProfile],
                 device: DeviceModel) -> Schedule:
    """Algorithm 1 of the paper — test-only oracle.

    Production callers use :func:`repro.core.fastscore.greedy_order_fast`,
    which is property-tested to produce identical schedules in
    ``O(n^2 * D)`` instead of ``O(R * n^2)`` Python ScoreGen reruns.
    """
    remaining = list(kernels)
    rounds: list[Round] = []
    while remaining:
        rd = Round()
        if len(remaining) == 1:
            rd.kernels.append(remaining.pop())
            rounds.append(rd)
            break
        # Seed the round with the highest-scoring pair.  pair_score is
        # symmetric, so scanning i < j only halves the ScoreGen work;
        # the selection is unchanged because the first strict maximum
        # of a symmetric matrix in row-major order always has i < j.
        best, best_pair = -1.0, (0, 1)
        n = len(remaining)
        for i in range(n):
            for j in range(i + 1, n):
                s = pair_score(remaining[i], remaining[j], device)
                if s > best:
                    best, best_pair = s, (i, j)
        i, j = best_pair
        ka, kb = remaining[i], remaining[j]
        if best <= 0.0 and not fits_together(ka, kb, device):
            # Nothing pairs (every kernel saturates a unit on its own):
            # the kernel runs in a round by itself.
            solo = max(remaining, key=lambda k: _sort_key(k, device))
            remaining.remove(solo)
            rd.kernels.append(solo)
            rounds.append(rd)
            continue
        for k in (ka, kb):
            rd.insert_sorted(k, device)
        remaining = [k for t, k in enumerate(remaining) if t not in (i, j)]
        comb = profile_combine(ka, kb, device)
        # Keep absorbing the best-fitting kernel (Alg. 1 lines 8-11).
        while True:
            fits = [k for k in remaining if fits_together(comb, k, device)]
            if not fits:
                break
            scores = score_vector(comb, fits, device)
            kc = fits[max(range(len(fits)), key=scores.__getitem__)]
            rd.insert_sorted(kc, device)
            comb = profile_combine(comb, kc, device)
            remaining.remove(kc)
        rounds.append(rd)
    return Schedule(rounds)


# ---------------------------------------------------------------------------
# Design-space evaluation helpers
# ---------------------------------------------------------------------------

def exhaustive_search(
    kernels: Sequence[KernelProfile],
    time_fn: Callable[[Sequence[KernelProfile]], float],
    limit: int | None = None,
) -> list[tuple[float, tuple[int, ...]]]:
    """Evaluate ``time_fn`` on every permutation (or the first ``limit``).

    Returns ``[(time, perm_indices)]`` sorted ascending by time.
    """
    idx = range(len(kernels))
    out: list[tuple[float, tuple[int, ...]]] = []
    for c, perm in enumerate(itertools.permutations(idx)):
        if limit is not None and c >= limit:
            break
        out.append((time_fn([kernels[p] for p in perm]), perm))
    out.sort(key=lambda t: t[0])
    return out


def random_orders(kernels: Sequence[KernelProfile], n: int,
                  seed: int = 0) -> list[list[KernelProfile]]:
    rng = _random.Random(seed)
    outs = []
    for _ in range(n):
        p = list(kernels)
        rng.shuffle(p)
        outs.append(p)
    return outs


def percentile_rank(value: float, population: Sequence[float]) -> float:
    """Percentile (0-100) of the population that is *no better* (>=)
    than ``value``.

    Matches the paper's usage: a launch order at ``percentile_rank ==
    96.0`` beats 96% of all permutations (lower time is better).  The
    return value is a percentage, **not** a 0-1 fraction — pinned by
    ``tests/test_fastscore.py::test_percentile_rank_convention``.
    """
    population = list(population)
    if not population:
        return 0.0
    worse_or_equal = sum(1 for v in population if v >= value)
    return 100.0 * worse_or_equal / len(population)
