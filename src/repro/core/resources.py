"""Resource models for concurrent-kernel scheduling.

Faithful port of the resource abstraction in

    Li, Narayana, El-Ghazawi, "Reordering GPU Kernel Launches to Enable
    Efficient Concurrent Execution", 2015.

The paper characterises a GPU as a set of identical execution units
("streaming multiprocessors", SMs) with per-unit capacities
(registers, shared memory, warps, resident blocks) and a *balanced*
instructions/bytes ratio ``R_B``.  Each kernel is characterised by a
resource-demand vector and an instructions/bytes ratio ``R_i``.

We generalise the resource vector to a named mapping so the identical
algorithm drives both

* the faithful GPU reproduction (dims: ``shm``, ``reg``, ``warp``), and
* the TPU adaptation (dims: ``vmem``, ``hbm``, ``slots``) used by the
  serving-round composer (see :mod:`repro.core.tpu`).

All capacities are *per execution unit*; kernels report *per block*
demands plus a block count, and the per-unit aggregate demand assumes
the round-robin block distribution described in the paper (``ceil(n_blocks
/ n_units)`` blocks per unit).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Mapping

__all__ = [
    "DeviceModel",
    "KernelProfile",
    "GTX580",
    "TPU_V5E_UNIT",
    "ep_kernel",
    "bs_kernel",
    "es_kernel",
    "sw_kernel",
]


@dataclass(frozen=True)
class DeviceModel:
    """A device made of ``n_units`` identical execution units.

    ``caps`` holds the per-unit capacity of every schedulable resource
    dimension.  ``max_resident`` caps the number of co-resident blocks
    per unit (``N_blk_SM``).  ``compute_rate`` is work-units/sec/unit
    (instructions for the GPU model, FLOPs for the TPU model) and
    ``mem_bw`` is bytes/sec/unit.  ``r_balanced`` is the ratio deemed
    "balanced" by the vendor (``R_B``); for a roofline-consistent model
    it equals ``compute_rate / mem_bw`` in the profile's ratio units.
    """

    name: str
    n_units: int
    caps: Mapping[str, float]
    max_resident: int
    compute_rate: float
    mem_bw: float
    r_balanced: float
    #: Occupancy model: execution units are latency-hiding machines and
    #: only reach peak throughput when enough independent work is
    #: resident.  ``sat_dim`` names the resource dimension that measures
    #: parallel slack (warps on a GPU, token slots on a TPU).  ALU/MXU
    #: pipelines saturate with much less parallelism (``sat_compute``)
    #: than the memory system, whose long latency needs far more
    #: in-flight work to hide (``sat_memory``) — the asymmetry that
    #: makes lone memory-bound kernels the worst co-tenants and is the
    #: physical reason the paper's compute/memory mixing pays off.
    sat_dim: str = ""
    sat_compute: float = 1.0
    sat_memory: float = 1.0
    #: ScoreGen term weights.  The paper weights every residual-capacity
    #: term and the R-mixing term equally (1.0) — keep that for the GPU
    #: reproduction.  The TPU serving device up-weights the R term: with
    #: a single binding capacity (token slots) the residual terms
    #: otherwise dominate and the greedy degenerates to
    #: smallest-items-first, starving compute/memory mixing.
    r_weight: float = 1.0
    residual_weight: float = 1.0
    #: How the combined ratio of co-scheduled kernels is estimated:
    #: "block_mean" is the paper's block-weighted average of R_i;
    #: "harmonic" is the physically-correct total-work/total-bytes
    #: (needed when intensities span orders of magnitude, e.g. the
    #: TPU comm-vs-compute overlap scheduler).
    combined_r: str = "block_mean"

    def cap(self, dim: str) -> float:
        return self.caps[dim]

    def _occupancy(self, used: Mapping[str, float]) -> float:
        return used.get(self.sat_dim, 0.0) if self.sat_dim else float("inf")

    def _has_occupancy_model(self) -> bool:
        # A sat_dim that is not a tracked capacity can never accumulate
        # occupancy, so treating it as an occupancy model would pin
        # every efficiency at 0 (~1e12x slowdowns).  Such a device has
        # no usable occupancy signal: run at peak.  Mirrored exactly by
        # the vectorized simulators (repro.core.refine) and pinned by
        # tests/test_fastscore.py::test_sat_dim_configs_match_reference.
        return bool(self.sat_dim) and self.sat_dim in self.caps

    def compute_efficiency(self, used: Mapping[str, float]) -> float:
        if not self._has_occupancy_model():
            return 1.0
        return min(1.0, self._occupancy(used) / self.sat_compute)

    def memory_efficiency(self, used: Mapping[str, float]) -> float:
        if not self._has_occupancy_model():
            return 1.0
        return min(1.0, self._occupancy(used) / self.sat_memory)


@dataclass(frozen=True)
class KernelProfile:
    """Per-kernel profiling record (one row of the paper's Table 1).

    ``demands`` are per *block*; ``n_blocks`` is the grid size.
    ``inst_per_block`` is total work units per block and ``r`` the
    instructions/bytes ratio, so a block's memory traffic is
    ``inst_per_block / r`` byte-units (the paper measures R in
    instructions per 4-byte transaction; the simulator is agnostic to
    the unit as long as ``mem_bw`` uses the same one).
    """

    name: str
    n_blocks: int
    demands: Mapping[str, float]
    inst_per_block: float
    r: float
    #: When set, ``demands`` is already a per-unit aggregate (virtual
    #: combined kernels produced by ProfileCombine) and holds the number
    #: of resident blocks per unit it represents.
    agg_blocks_per_unit: int | None = None

    def blocks_per_unit(self, device: DeviceModel) -> int:
        if self.agg_blocks_per_unit is not None:
            return self.agg_blocks_per_unit
        return math.ceil(self.n_blocks / device.n_units)

    def per_unit_demand(self, device: DeviceModel) -> dict[str, float]:
        """Aggregate per-unit demand under round-robin distribution."""
        if self.agg_blocks_per_unit is not None:
            return dict(self.demands)
        b = self.blocks_per_unit(device)
        return {k: v * b for k, v in self.demands.items()}

    def mem_per_block(self) -> float:
        return self.inst_per_block / self.r

    def with_name(self, name: str) -> "KernelProfile":
        return replace(self, name=name)


# ---------------------------------------------------------------------------
# Concrete device models
# ---------------------------------------------------------------------------

#: NVIDIA GTX 580 (Fermi GF110) exactly as characterised in the paper:
#: 16 SMs, 32K registers / 48KB shared memory / 48 warps / 8 blocks per SM,
#: R_B = 4.11.  ``compute_rate`` is chosen so the roofline balance point
#: matches R_B with the memory system's per-SM bandwidth (192 GB/s / 16 SMs
#: = 12 GB/s => 3e9 4-byte transactions/s => 4.11 * 3e9 inst/s).
GTX580 = DeviceModel(
    name="gtx580",
    n_units=16,
    caps={"shm": 48 * 1024, "reg": 32 * 1024, "warp": 48},
    max_resident=8,
    compute_rate=4.11 * 3.0e9,
    mem_bw=3.0e9,  # 4-byte transactions/sec/SM (12 GB/s)
    r_balanced=4.11,
    sat_dim="warp",
    sat_compute=12.0,  # ALU pipelines saturate with ~12 resident warps
    sat_memory=30.0,   # DRAM latency needs ~30 warps in flight to hide
)

#: TPU v5e modelled as a single large execution unit for the serving-round
#: composer: 197 TFLOP/s bf16, 819 GB/s HBM, ~128 MiB VMEM.  ``slots`` is a
#: per-round token budget (set by the serving engine), ``hbm`` a per-round
#: working-set budget.  R_B = 197e12 / 819e9 = 240.5 FLOPs/byte.
TPU_V5E_UNIT = DeviceModel(
    name="tpu_v5e",
    n_units=1,
    caps={"vmem": 128 * 1024 * 1024, "hbm": 16 * 1024**3, "slots": 4096},
    max_resident=4096,
    compute_rate=197e12,
    mem_bw=819e9,
    r_balanced=197e12 / 819e9,
    sat_dim="slots",
    sat_compute=512.0,  # MXU wants >=512 row-slots per round
    sat_memory=16.0,    # HBM DMA streams saturate with few requests
)


# ---------------------------------------------------------------------------
# Benchmark kernel profiles (Table 2 of the paper)
# ---------------------------------------------------------------------------
#
# The paper profiles four applications on the GTX 580 with the CUDA
# profiler.  Absolute instruction counts are not published; we pick
# counts that give standalone execution times of the right order of
# magnitude (tens of ms) while preserving the published inst/bytes
# ratios, grid/block geometry and resource footprints.  Everything the
# *algorithm* consumes (demand vectors + R_i) is as published.

def _mk(name: str, *, grid: int, block: int, regs_per_thread: int,
        shm: int, r: float, inst: float) -> KernelProfile:
    warps = block // 32
    return KernelProfile(
        name=name,
        n_blocks=grid,
        demands={"shm": float(shm), "reg": float(regs_per_thread * block),
                 "warp": float(warps)},
        inst_per_block=inst,
        r=r,
    )


def ep_kernel(name: str = "EP", *, grid: int = 16, block: int = 128,
              shm: int = 0, inst: float = 60e6) -> KernelProfile:
    """NPB EP (M=24): memory-bound, R = 3.11 < R_B."""
    return _mk(name, grid=grid, block=block, regs_per_thread=21, shm=shm,
               r=3.11, inst=inst)


def bs_kernel(name: str = "BS", *, grid: int = 32, block: int = 128,
              shm: int = 0, inst: float = 220e6) -> KernelProfile:
    """BlackScholes (4M options): compute-bound, R = 11.1 > R_B."""
    return _mk(name, grid=grid, block=block, regs_per_thread=24, shm=shm,
               r=11.1, inst=inst)


def es_kernel(name: str = "ES", *, grid: int = 48, block: int = 256,
              shm: int = 8 * 1024, inst: float = 150e6) -> KernelProfile:
    """VMD Electrostatics (40K atoms): strongly compute-bound."""
    return _mk(name, grid=grid, block=block, regs_per_thread=28, shm=shm,
               r=20.0, inst=inst)


def sw_kernel(name: str = "SW", *, grid: int = 32, block: int = 128,
              shm: int = 16 * 1024, inst: float = 45e6) -> KernelProfile:
    """Smith-Waterman: strongly memory-bound."""
    return _mk(name, grid=grid, block=block, regs_per_thread=18, shm=shm,
               r=1.6, inst=inst)
