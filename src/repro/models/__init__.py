"""Model zoo: pure-JAX pytree models covering all assigned families."""

from .common import ModelConfig
from . import transformer
from .transformer import (count_params, decode_step, forward, init,
                          init_cache, model_flops, prefill, unit_period)

__all__ = ["ModelConfig", "transformer", "count_params", "decode_step",
           "forward", "init", "init_cache", "model_flops", "prefill",
           "unit_period"]
