"""Shared model config, initialisers and elementary layers (pure JAX).

No flax/haiku: parameters are plain nested dicts of ``jnp.ndarray``;
every layer is an ``init(key, ...) -> params`` / ``apply(params, x)``
pair.  Sharding is assigned separately by path rules
(:mod:`repro.dist.sharding`), keeping model code mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ModelConfig", "Dense", "rmsnorm", "layernorm", "norm",
           "init_norm", "act_fn", "rope_tables", "apply_rope",
           "make_dense", "dense", "PyTree"]

PyTree = Any


@dataclass(frozen=True)
class ModelConfig:
    """One config object covers all ten assigned architectures."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # --- attention ---
    attn_type: str = "gqa"          # "gqa" | "mla"
    qkv_bias: bool = False
    causal: bool = True
    sliding_window: int | None = None
    rope_theta: float = 10_000.0
    use_rope: bool = True

    # --- MLA (deepseek-v2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_layer_period: int = 1        # MoE every k-th layer
    first_dense_layers: int = 0      # leading dense layers (deepseek)
    capacity_factor: float = 1.25

    # --- block pattern, cycled over layers ---
    block_pattern: tuple[str, ...] = ("attn",)   # attn|mamba|mlstm|slstm

    # --- mamba ---
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0           # 0 => ceil(d_model/16)

    # --- xlstm ---
    xlstm_proj_factor: float = 2.0

    # --- misc ---
    act: str = "swiglu"              # "swiglu" | "gelu"
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    input_mode: str = "tokens"       # "tokens" | "embeddings" (stub frontend)
    logit_softcap: float = 0.0

    # ------------------------------------------------------------------
    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0 or i < self.first_dense_layers:
            return False
        return (i - self.first_dense_layers + 1) % self.moe_layer_period == 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Elementary layers
# ---------------------------------------------------------------------------

def make_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: float | None = None, dtype=jnp.float32) -> PyTree:
    scale = 1.0 / math.sqrt(d_in) if scale is None else scale
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


Dense = (make_dense, dense)  # convenience export


def init_norm(d: int, kind: str) -> PyTree:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def rmsnorm(p: PyTree, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["scale"]).astype(dt)


def layernorm(p: PyTree, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p.get("bias", 0.0)).astype(dt)


def norm(p: PyTree, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def act_fn(kind: str):
    if kind == "gelu":
        return jax.nn.gelu
    if kind == "silu":
        return jax.nn.silu
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_tables(positions: jnp.ndarray, head_dim: int,
                theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for the given positions; shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x0, x1) = (even, odd) channels.  x: (..., S, H, D)."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    # cos/sin: (..., S, D/2) -> broadcast over the head axis.
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(dt)
