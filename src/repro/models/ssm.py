"""Mamba-1 selective-state-space block (for jamba-v0.1).

Faithful structure: in-proj to (x, z), depthwise causal conv, selective
(input-dependent) Δ/B/C, diagonal A, gated out-proj.  The scan runs in
fixed-size chunks with ``jax.lax.scan`` carrying only the (B, d_inner,
d_state) state — states are never materialised over the sequence, and
the chunk bodies are remat-friendly.  Decode keeps (conv window, ssm
state) as the cache: constant memory per sequence, which is what makes
SSM decode work items such good symbiotic partners for prefill in the
serving scheduler.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ModelConfig, PyTree, make_dense, dense

__all__ = ["Mamba"]


class Mamba:
    @staticmethod
    def init(key, cfg: ModelConfig) -> PyTree:
        d, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
        dtr, dc = cfg.dt_rank, cfg.mamba_d_conv
        ks = iter(jax.random.split(key, 8))
        # S4D-real initialisation for A.
        a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :],
                     (di, 1))
        dt_init = jnp.exp(
            jax.random.uniform(next(ks), (di,)) *
            (math.log(0.1) - math.log(0.001)) + math.log(0.001))
        inv_softplus = lambda x: jnp.log(jnp.expm1(x))  # noqa: E731
        return {
            "w_in": make_dense(next(ks), d, 2 * di),
            "conv_w": jax.random.normal(next(ks), (dc, di)) / math.sqrt(dc),
            "conv_b": jnp.zeros((di,)),
            "w_x_dbc": make_dense(next(ks), di, dtr + 2 * ds),
            "w_dt": make_dense(next(ks), dtr, di, scale=dtr ** -0.5),
            "dt_bias": inv_softplus(dt_init),
            "a_log": jnp.log(a),
            "d_skip": jnp.ones((di,)),
            "w_out": make_dense(next(ks), di, d,
                                scale=1.0 / math.sqrt(di * 2 * cfg.n_layers)),
        }

    # ------------------------------------------------------------------
    @staticmethod
    def _dbc(p, cfg, xc):
        """xc: (..., di) -> dt (..., di), Bm (..., ds), Cm (..., ds)."""
        dtr, ds = cfg.dt_rank, cfg.mamba_d_state
        dbc = dense(p["w_x_dbc"], xc)
        dt = jax.nn.softplus(
            dense(p["w_dt"], dbc[..., :dtr]) +
            p["dt_bias"].astype(xc.dtype))
        Bm = dbc[..., dtr:dtr + ds]
        Cm = dbc[..., dtr + ds:]
        return dt, Bm, Cm

    @staticmethod
    def fwd(p: PyTree, cfg: ModelConfig, x: jnp.ndarray,
            chunk: int = 128) -> jnp.ndarray:
        """x: (B, S, d) -> (B, S, d).

        Two-level scan: an outer ``lax.scan`` over chunks carries only
        the (B, di, ds) state at chunk boundaries, and the remat'd
        inner scan recomputes within-chunk states during backward — the
        memory shape of Mamba's hardware-aware formulation.
        """
        B, S, d = x.shape
        di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
        xz = dense(p["w_in"], x)
        xi, z = jnp.split(xz, 2, axis=-1)                      # (B,S,di)
        # Depthwise causal conv along S.
        pad = jnp.pad(xi, ((0, 0), (dc - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + S, :] * p["conv_w"][i].astype(x.dtype)
                   for i in range(dc))
        xc = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))
        dt, Bm, Cm = Mamba._dbc(p, cfg, xc)
        A = -jnp.exp(p["a_log"])                               # (di, ds)

        def step(h, inp):
            xc_t, dt_t, B_t, C_t = inp                         # (B,di),(B,di),(B,ds),(B,ds)
            dA = jnp.exp(dt_t[..., None] * A)                  # (B,di,ds)
            dBx = dt_t[..., None] * B_t[:, None, :] * xc_t[..., None]
            h = h * dA + dBx
            y = jnp.einsum("bds,bs->bd", h, C_t)
            return h, y

        ck = min(chunk, S)
        n_chunks = -(-S // ck)
        Sp = n_chunks * ck

        def to_chunks(a):
            a = a.astype(jnp.float32).swapaxes(0, 1)           # (S, B, ...)
            if Sp != S:
                a = jnp.pad(a, ((0, Sp - S),) + ((0, 0),) * (a.ndim - 1))
            return a.reshape(n_chunks, ck, *a.shape[1:])

        seq = tuple(to_chunks(a) for a in (xc, dt, Bm, Cm))

        @jax.checkpoint
        def chunk_body(h, inp):
            return jax.lax.scan(step, h, inp)

        h0 = jnp.zeros((B, di, ds), jnp.float32)
        _, ys = jax.lax.scan(chunk_body, h0, seq)              # (n, ck, B, di)
        y = ys.reshape(Sp, B, di)[:S].swapaxes(0, 1).astype(x.dtype)
        y = y + xc * p["d_skip"].astype(x.dtype)
        y = y * jax.nn.silu(z)
        return dense(p["w_out"], y)

    # ------------------------------------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> PyTree:
        di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
        return {
            "conv": jnp.zeros((batch, dc - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, ds), jnp.float32),
        }

    @staticmethod
    def decode(p: PyTree, cfg: ModelConfig, x: jnp.ndarray, cache: PyTree,
               pos: jnp.ndarray) -> tuple[jnp.ndarray, PyTree]:
        """x: (B, 1, d) one token."""
        B = x.shape[0]
        di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
        xz = dense(p["w_in"], x)[:, 0]                         # (B, 2di)
        xi, z = jnp.split(xz, 2, axis=-1)
        window = jnp.concatenate(
            [cache["conv"].astype(x.dtype), xi[:, None, :]], axis=1)  # (B,dc,di)
        conv = jnp.einsum("bcd,cd->bd", window, p["conv_w"].astype(x.dtype))
        xc = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))
        dt, Bm, Cm = Mamba._dbc(p, cfg, xc)
        A = -jnp.exp(p["a_log"])
        dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)
        dBx = (dt.astype(jnp.float32)[..., None] *
               Bm.astype(jnp.float32)[:, None, :] *
               xc.astype(jnp.float32)[..., None])
        h = cache["ssm"] * dA + dBx
        y = jnp.einsum("bds,bs->bd", h, Cm.astype(jnp.float32)).astype(x.dtype)
        y = y + xc * p["d_skip"].astype(x.dtype)
        y = y * jax.nn.silu(z)
        out = dense(p["w_out"], y)[:, None, :]
        return out, {"conv": window[:, 1:].astype(cache["conv"].dtype),
                     "ssm": h}
