"""Mixture-of-Experts with capacity-based, sort-free static dispatch.

Design constraints:

* static shapes only (jit/pjit friendly): per-expert buffers of
  ``capacity`` slots, overflow tokens dropped (standard Switch/GShard
  semantics, capacity_factor controls the drop rate),
* no O(T*E*C) one-hot tensors: slot indices are computed with a sort
  over token-expert assignments and a segment-relative ranking, then
  tokens are gathered into an (E, C, d) buffer — the Megablocks-style
  grouped-GEMM layout that XLA SPMD shards cleanly,
* experts are sharded over the "model" mesh axis when ``E`` divides it
  (expert parallelism, e.g. deepseek 160/16); otherwise each expert's
  ``d_ff`` is sharded (tensor parallelism inside experts, e.g. mixtral
  8 experts on a 16-way axis),
* router computed in f32 with load-balance + z losses (returned as
  aux so the train step can weight them).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ModelConfig, PyTree, make_dense

__all__ = ["MoE"]


def _expert_ffn(p: PyTree, x: jnp.ndarray, act: str) -> jnp.ndarray:
    """Grouped SwiGLU/GELU ffn over (E, C, d) buffers."""
    wg, wu, wd = (p["w_gate"].astype(x.dtype), p["w_up"].astype(x.dtype),
                  p["w_down"].astype(x.dtype))
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg)) * \
            jnp.einsum("ecd,edf->ecf", x, wu)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, wg))
    return jnp.einsum("ecf,efd->ecd", h, wd)


class MoE:
    @staticmethod
    def init(key, cfg: ModelConfig) -> PyTree:
        d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
        ks = iter(jax.random.split(key, 8))
        s_in = 1.0 / math.sqrt(d)
        s_out = 1.0 / math.sqrt(ff * 2 * cfg.n_layers)
        p = {
            "router": make_dense(next(ks), d, E, scale=s_in),
            "experts": {
                "w_gate": jax.random.normal(next(ks), (E, d, ff)) * s_in,
                "w_up": jax.random.normal(next(ks), (E, d, ff)) * s_in,
                "w_down": jax.random.normal(next(ks), (E, ff, d)) * s_out,
            },
        }
        if cfg.n_shared_experts:
            ff_sh = ff * cfg.n_shared_experts
            p["shared"] = {
                "w_gate": make_dense(next(ks), d, ff_sh, scale=s_in),
                "w_up": make_dense(next(ks), d, ff_sh, scale=s_in),
                "w_down": make_dense(next(ks), ff_sh, d, scale=s_out),
            }
        return p

    @staticmethod
    def capacity(cfg: ModelConfig, n_tokens: int) -> int:
        c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                          / cfg.n_experts))
        return max(8, -(-c // 8) * 8)  # pad to multiple of 8

    @staticmethod
    def fwd(p: PyTree, cfg: ModelConfig, x: jnp.ndarray
            ) -> tuple[jnp.ndarray, dict]:
        """x: (B, S, d) -> (y, aux_losses).

        Dispatches to the distributed path when a tensor/expert-parallel
        mesh axis is installed (see :mod:`repro.dist.context`):

        * ``E % tp == 0``: expert parallelism — local routing, fixed-
          capacity all_to_all to expert shards, grouped GEMM, reverse
          all_to_all (the Switch/GShard schedule, explicit via
          shard_map so SPMD can never replicate token buffers),
        * otherwise: experts replicated over tokens, each shard computes
          a d_ff slice of every expert and psums (tensor parallelism
          inside experts).
        """
        from repro.dist import context as dctx
        tp = dctx.tp_size()
        if tp > 1 and dctx.mesh() is not None:
            if cfg.n_experts % tp == 0:
                return MoE._fwd_ep(p, cfg, x)
            return MoE._fwd_tp(p, cfg, x)
        return MoE._fwd_local(p, cfg, x)

    @staticmethod
    def _fwd_local(p: PyTree, cfg: ModelConfig, x: jnp.ndarray
                   ) -> tuple[jnp.ndarray, dict]:
        B, S, d = x.shape
        E, K = cfg.n_experts, cfg.top_k
        T = B * S
        xt = x.reshape(T, d)
        C = MoE.capacity(cfg, T)

        logits = (xt.astype(jnp.float32)
                  @ p["router"]["w"].astype(jnp.float32))      # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)        # (T, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

        # ---- slot assignment without (T, E) one-hots ------------------
        flat_e = expert_ids.reshape(-1)                        # (T*K,)
        # Priority: earlier tokens win capacity (GShard semantics).
        order = jnp.argsort(flat_e, stable=True)               # group by expert
        sorted_e = flat_e[order]
        # rank within expert group = index - start(expert)
        counts = jnp.bincount(sorted_e, length=E)              # (E,)
        starts = jnp.cumsum(counts) - counts
        ranks_sorted = jnp.arange(T * K) - starts[sorted_e]
        ranks = jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)
        keep = ranks < C                                       # (T*K,)

        slot = flat_e * C + jnp.where(keep, ranks, 0)          # (T*K,)
        token_idx = jnp.repeat(jnp.arange(T), K)
        # Scatter tokens into the (E*C, d) buffer (dropped -> slot 0 masked).
        buf = jnp.zeros((E * C, d), x.dtype)
        contrib = jnp.where(keep[:, None], xt[token_idx], 0.0)
        buf = buf.at[slot].add(contrib, mode="drop")
        buf = buf.reshape(E, C, d)

        y_buf = _expert_ffn(p["experts"], buf, cfg.act)        # (E, C, d)

        # Combine: gather each kept assignment's output and weight by gate.
        y_flat = y_buf.reshape(E * C, d)[slot]                 # (T*K, d)
        w = jnp.where(keep, gate_vals.reshape(-1), 0.0).astype(x.dtype)
        y = jnp.zeros((T, d), x.dtype).at[token_idx].add(y_flat * w[:, None])

        if "shared" in p:
            from .common import dense
            sh = p["shared"]
            if cfg.act == "swiglu":
                h = jax.nn.silu(dense(sh["w_gate"], xt)) * dense(sh["w_up"], xt)
            else:
                h = jax.nn.gelu(dense(sh["w_gate"], xt))
            y = y + dense(sh["w_down"], h)

        # ---- aux losses ----------------------------------------------
        me = jnp.mean(probs, axis=0)                           # (E,)
        ce = jnp.mean(
            (jnp.bincount(flat_e, length=E) / (T * K)).astype(jnp.float32))
        frac = jnp.bincount(flat_e, length=E).astype(jnp.float32) / (T * K)
        lb_loss = E * jnp.sum(frac * me)
        z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
               "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
        return y.reshape(B, S, d), aux

    # ------------------------------------------------------------------
    # Distributed paths (explicit shard_map — SPMD alone mis-shards the
    # dispatch scatter and replicates token buffers).
    # ------------------------------------------------------------------

    @staticmethod
    def _route_local(p, cfg, xt, capacity):
        """Shared routing: top-k, capacity ranks.  xt: (t, d) local."""
        E, K = cfg.n_experts, cfg.top_k
        t = xt.shape[0]
        logits = xt.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
        flat_e = expert_ids.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.bincount(sorted_e, length=E)
        starts = jnp.cumsum(counts) - counts
        ranks_sorted = jnp.arange(t * K) - starts[sorted_e]
        ranks = jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)
        keep = ranks < capacity
        return logits, probs, gate_vals, flat_e, ranks, keep

    @staticmethod
    def _aux_of(cfg, logits, probs, flat_e, keep, axes):
        E, K = cfg.n_experts, cfg.top_k
        t = probs.shape[0]

        def mean_over(v):
            if axes:
                return jax.lax.pmean(v, axes)
            return v

        me = mean_over(jnp.mean(probs, axis=0))
        frac = mean_over(
            jnp.bincount(flat_e, length=E).astype(jnp.float32) / (t * K))
        lb = E * jnp.sum(frac * me)
        z = mean_over(jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1))))
        drop = mean_over(1.0 - jnp.mean(keep.astype(jnp.float32)))
        return {"moe_lb_loss": lb, "moe_z_loss": z, "moe_drop_frac": drop}

    @staticmethod
    def _shared_tp(p, cfg, xt, tp_axis):
        """Shared experts with d_ff tensor-parallel over ``tp_axis``."""
        if "shared" not in p:
            return 0.0
        sh = p["shared"]
        wg = sh["w_gate"]["w"].astype(xt.dtype)
        wu = sh["w_up"]["w"].astype(xt.dtype)
        wd = sh["w_down"]["w"].astype(xt.dtype)
        if cfg.act == "swiglu":
            h = jax.nn.silu(xt @ wg) * (xt @ wu)
        else:
            h = jax.nn.gelu(xt @ wg)
        y = h @ wd
        return jax.lax.psum(y, tp_axis) if tp_axis else y

    @staticmethod
    def _fwd_ep(p: PyTree, cfg: ModelConfig, x: jnp.ndarray
                ) -> tuple[jnp.ndarray, dict]:
        """Expert parallelism: tokens split over (dp, tp); fixed-capacity
        all_to_all dispatch to expert shards; reverse combine."""
        from jax.sharding import PartitionSpec as P
        from repro.dist import context as dctx

        mesh = dctx.mesh()
        dp_ax, tp_ax = dctx.activation_axes()
        dp_axes = tuple(dp_ax) if isinstance(dp_ax, (tuple, list)) else (
            (dp_ax,) if dp_ax else ())
        B, S, d = x.shape
        E, K = cfg.n_experts, cfg.top_k
        m = dctx.tp_size()
        E_loc = E // m
        T = B * S

        # Token sharding must stay aligned with the outer (B, S, d)
        # activation layout or the backward respec replicates the full
        # batch: batch over the DP axes (when divisible) and *sequence*
        # over the model axis (sequence-parallel dispatch).  Remaining
        # replication (tiny decode batches) is correct — each source
        # shard combines only its own slots — at the cost of duplicate
        # routing compute.
        b_axes: tuple = ()
        n_b = 1
        for a in dp_axes:
            sz = mesh.shape[a]
            if B % (n_b * sz) == 0:
                b_axes += (a,)
                n_b *= sz
        s_ax = tp_ax if S % m == 0 else None
        n_tok_shards = n_b * (m if s_ax else 1)
        t_loc = T // n_tok_shards
        c_se = max(4, -(-int(t_loc * K * cfg.capacity_factor / E) // 4) * 4)

        def inner(xb, router_w, wg, wu, wd, shared):
            xt = xb.reshape(-1, d)
            pl = {"router": {"w": router_w},
                  "shared": shared} if shared is not None else {
                      "router": {"w": router_w}}
            logits, probs, gates, flat_e, ranks, keep = MoE._route_local(
                pl, cfg, xt, c_se)
            t = xt.shape[0]
            dest = flat_e // E_loc
            eslot = flat_e % E_loc
            slot = dest * (E_loc * c_se) + eslot * c_se + \
                jnp.where(keep, ranks, 0)
            token_idx = jnp.repeat(jnp.arange(t), K)
            contrib = jnp.where(keep[:, None], xt[token_idx], 0.0)
            send = jnp.zeros((m * E_loc * c_se, d), xt.dtype)
            send = send.at[slot].add(contrib, mode="drop")
            send = send.reshape(m, E_loc * c_se, d)
            recv = jax.lax.all_to_all(send, tp_ax, split_axis=0,
                                      concat_axis=0, tiled=False)
            buf = recv.reshape(m, E_loc, c_se, d).transpose(1, 0, 2, 3)
            buf = buf.reshape(E_loc, m * c_se, d)
            y_buf = _expert_ffn({"w_gate": wg, "w_up": wu, "w_down": wd},
                                buf, cfg.act)
            back = y_buf.reshape(E_loc, m, c_se, d).transpose(1, 0, 2, 3)
            back = back.reshape(m, E_loc * c_se, d)
            ret = jax.lax.all_to_all(back, tp_ax, split_axis=0,
                                     concat_axis=0, tiled=False)
            y_flat = ret.reshape(m * E_loc * c_se, d)[slot]
            w = jnp.where(keep, gates.reshape(-1), 0.0).astype(xt.dtype)
            y = jnp.zeros((t, d), xt.dtype).at[token_idx].add(
                y_flat * w[:, None])
            y = y + MoE._shared_tp(pl, cfg, xt, None)
            aux_axes = b_axes + ((s_ax,) if s_ax else ())
            aux = MoE._aux_of(cfg, logits, probs, flat_e, keep, aux_axes)
            return y.reshape(xb.shape), aux

        shared = p.get("shared")
        shared_spec = None
        if shared is not None:
            shared_spec = jax.tree.map(lambda _: P(None, None), shared)
        tok_spec = P(b_axes if b_axes else None, s_ax, None)
        y, aux = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(tok_spec, P(None, None),
                      P(tp_ax, None, None), P(tp_ax, None, None),
                      P(tp_ax, None, None), shared_spec),
            out_specs=(tok_spec,
                       {k: P() for k in ("moe_lb_loss", "moe_z_loss",
                                         "moe_drop_frac")}),
            check_vma=False,
        )(x, p["router"]["w"], p["experts"]["w_gate"],
          p["experts"]["w_up"], p["experts"]["w_down"], shared)
        return y, aux

    @staticmethod
    def _fwd_tp(p: PyTree, cfg: ModelConfig, x: jnp.ndarray
                ) -> tuple[jnp.ndarray, dict]:
        """Experts too few to shard: replicate routing, shard every
        expert's d_ff over the model axis, psum the combined output."""
        from jax.sharding import PartitionSpec as P
        from repro.dist import context as dctx

        mesh = dctx.mesh()
        dp_ax, tp_ax = dctx.activation_axes()
        dp_axes = tuple(dp_ax) if isinstance(dp_ax, (tuple, list)) else (
            (dp_ax,) if dp_ax else ())
        B, S, d = x.shape
        E, K = cfg.n_experts, cfg.top_k
        T = B * S
        tok_axes: tuple = ()
        n_dp = 1
        for a in dp_axes:
            sz = mesh.shape[a]
            if T % (n_dp * sz) == 0:
                tok_axes += (a,)
                n_dp *= sz
        dp_axes = tok_axes
        t_loc = T // n_dp
        C = max(8, -(-int(t_loc * K * cfg.capacity_factor / E) // 8) * 8)

        def inner(xt, router_w, wg, wu, wd, shared):
            pl = {"router": {"w": router_w}}
            if shared is not None:
                pl["shared"] = shared
            logits, probs, gates, flat_e, ranks, keep = MoE._route_local(
                pl, cfg, xt, C)
            t = xt.shape[0]
            slot = flat_e * C + jnp.where(keep, ranks, 0)
            token_idx = jnp.repeat(jnp.arange(t), K)
            contrib = jnp.where(keep[:, None], xt[token_idx], 0.0)
            buf = jnp.zeros((E * C, d), xt.dtype)
            buf = buf.at[slot].add(contrib, mode="drop").reshape(E, C, d)
            y_buf = _expert_ffn({"w_gate": wg, "w_up": wu, "w_down": wd},
                                buf, cfg.act)
            y_flat = y_buf.reshape(E * C, d)[slot]
            w = jnp.where(keep, gates.reshape(-1), 0.0).astype(xt.dtype)
            y = jnp.zeros((t, d), xt.dtype).at[token_idx].add(
                y_flat * w[:, None])
            y = jax.lax.psum(y, tp_ax)
            y = y + MoE._shared_tp(pl, cfg, xt, tp_ax)
            aux = MoE._aux_of(cfg, logits, probs, flat_e, keep, dp_axes)
            return y, aux

        xt = x.reshape(T, d)
        shared = p.get("shared")
        shared_spec = None
        if shared is not None:
            shared_spec = {
                "w_gate": {"w": P(None, tp_ax)},
                "w_up": {"w": P(None, tp_ax)},
                "w_down": {"w": P(tp_ax, None)},
            }
        y, aux = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(P(dp_axes if dp_axes else None, None), P(None, None),
                      P(None, None, tp_ax), P(None, None, tp_ax),
                      P(None, tp_ax, None), shared_spec),
            out_specs=(P(dp_axes if dp_axes else None, None),
                       {k: P() for k in ("moe_lb_loss", "moe_z_loss",
                                         "moe_drop_frac")}),
            check_vma=False,
        )(xt, p["router"]["w"], p["experts"]["w_gate"],
          p["experts"]["w_up"], p["experts"]["w_down"], shared)
        return y.reshape(B, S, d), aux
