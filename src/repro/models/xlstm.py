"""xLSTM blocks (arXiv:2405.04517): mLSTM and sLSTM.

* ``MLSTM`` — matrix-memory LSTM with exponential gating.  Training and
  prefill use the *parallel* (attention-like) form with the stabilised
  log-gate decay matrix; decode uses the recurrent form with a
  (B, H, dv, dk) matrix state — constant memory per sequence, which is
  why ``xlstm-125m`` runs the ``long_500k`` cell natively.
* ``SLSTM`` — scalar-memory LSTM with exponential gating and head-wise
  block-diagonal recurrence.  Inherently sequential: a chunk-remat'd
  ``lax.scan`` over time.

Both follow the paper's pre-up-projection block layout (no separate FF:
``d_ff = 0`` in the config).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ModelConfig, PyTree, dense, make_dense

__all__ = ["MLSTM", "SLSTM"]


def _proj_dims(cfg: ModelConfig) -> tuple[int, int]:
    di = int(cfg.d_model * cfg.xlstm_proj_factor)
    di = -(-di // cfg.n_heads) * cfg.n_heads
    return di, di // cfg.n_heads


class MLSTM:
    @staticmethod
    def init(key, cfg: ModelConfig) -> PyTree:
        d = cfg.d_model
        di, hd = _proj_dims(cfg)
        ks = iter(jax.random.split(key, 8))
        return {
            "w_up": make_dense(next(ks), d, 2 * di),
            "wq": make_dense(next(ks), di, di),
            "wk": make_dense(next(ks), di, di),
            "wv": make_dense(next(ks), di, di),
            "w_if": make_dense(next(ks), di, 2 * cfg.n_heads, bias=True),
            "ln_scale": jnp.ones((di,), jnp.float32),
            "w_down": make_dense(next(ks), di, d,
                                 scale=1.0 / math.sqrt(di * 2 * cfg.n_layers)),
        }

    @staticmethod
    def _qkv_gates(p, cfg, xu):
        B, S, di = xu.shape
        H = cfg.n_heads
        hd = di // H
        q = dense(p["wq"], xu).reshape(B, S, H, hd)
        k = dense(p["wk"], xu).reshape(B, S, H, hd) / math.sqrt(hd)
        v = dense(p["wv"], xu).reshape(B, S, H, hd)
        gates = dense(p["w_if"], xu).astype(jnp.float32)       # (B,S,2H)
        i_pre, f_pre = gates[..., :H], gates[..., H:]
        return q, k, v, i_pre, f_pre

    @staticmethod
    def fwd(p: PyTree, cfg: ModelConfig, x: jnp.ndarray,
            chunk: int = 256) -> jnp.ndarray:
        """Chunkwise-parallel form: O(S * chunk) memory, O(S * (chunk +
        d_head)) time per head — the TPU-native mLSTM formulation.

        Within a chunk the quadratic stabilised decay matrix is used;
        across chunks a (C, n, m) state identical to the decode
        recurrence is carried, so this matches decode token-for-token.
        """
        B, S, d = x.shape
        H = cfg.n_heads
        xu, z = jnp.split(dense(p["w_up"], x), 2, axis=-1)
        q, k, v, i_pre, f_pre = MLSTM._qkv_gates(p, cfg, xu)
        hd = q.shape[-1]

        ck = min(chunk, S)
        while S % ck:
            ck //= 2
        n_chunks = S // ck

        def to_chunks(a):
            return a.reshape(B, n_chunks, ck, *a.shape[2:]).swapaxes(0, 1)

        qs, ks, vs = map(to_chunks, (q, k, v))
        is_, fs = map(to_chunks, (i_pre, f_pre))                # (n,B,ck,H)

        @jax.checkpoint
        def chunk_body(carry, inp):
            C_a, n_a, m_a = carry      # (B,H,hd,hd), (B,H,hd), (B,H)
            qb, kb, vb, ib, fb = inp   # (B,ck,...)
            logf = jax.nn.log_sigmoid(fb.astype(jnp.float32))   # (B,ck,H)
            F = jnp.cumsum(logf, axis=1)                        # (B,ck,H)
            # Row stabiliser: m_t = F_t + max(m_a, cummax_s(i_s - F_s))
            g = jax.lax.cummax(ib - F, axis=1)                  # cummax
            m_t = F + jnp.maximum(m_a[:, None, :], g)           # (B,ck,H)
            # Inter-chunk contribution (state carries scale exp(m_a)).
            w_inter = jnp.exp(m_a[:, None, :] + F - m_t)        # (B,ck,H)
            qf = qb.astype(jnp.float32)
            num_inter = jnp.einsum("bshd,bhvd->bshv", qf, C_a) * \
                w_inter[..., None]
            den_inter = jnp.einsum("bshd,bhd->bsh", qf, n_a) * w_inter
            # Intra-chunk attention with stabilised decay matrix.
            Dlog = (F[:, :, None, :] - F[:, None, :, :] +
                    ib[:, None, :, :].astype(jnp.float32))      # (B,s,t,H)
            tri = jnp.tril(jnp.ones((ck, ck), bool))
            Dlog = jnp.where(tri[None, :, :, None], Dlog, -jnp.inf)
            Dw = jnp.exp(Dlog - m_t[:, :, None, :])
            logits = jnp.einsum("bshd,bthd->bsth", qf,
                                kb.astype(jnp.float32))
            w = logits * Dw
            num = num_inter + jnp.einsum("bsth,bthd->bshd", w,
                                         vb.astype(jnp.float32))
            den = den_inter + jnp.sum(w, axis=2)
            h = num / jnp.maximum(jnp.abs(den),
                                  jnp.exp(-m_t))[..., None]     # (B,ck,H,hd)
            # End-of-chunk state (same convention as decode()).
            F_L = F[:, -1:, :]                                  # (B,1,H)
            m_b = (F_L + jnp.maximum(m_a[:, None, :], g[:, -1:, :]))[:, 0]
            sc_old = jnp.exp(m_a + F_L[:, 0] - m_b)             # (B,H)
            w_new = jnp.exp(F_L - F + ib - m_b[:, None, :])     # (B,ck,H)
            kf, vf = kb.astype(jnp.float32), vb.astype(jnp.float32)
            C_b = C_a * sc_old[..., None, None] + jnp.einsum(
                "bsh,bshv,bshk->bhvk", w_new, vf, kf)
            n_b = n_a * sc_old[..., None] + jnp.einsum(
                "bsh,bshk->bhk", w_new, kf)
            return (C_b, n_b, m_b), h

        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
        _, hs = jax.lax.scan(chunk_body, (C0, n0, m0),
                             (qs, ks, vs, is_, fs))             # (n,B,ck,H,hd)
        h = hs.swapaxes(0, 1).reshape(B, S, H, hd)
        # Per-head "group norm" (layernorm over head dim), then gate.
        mu = jnp.mean(h, -1, keepdims=True)
        var = jnp.var(h, -1, keepdims=True)
        h = ((h - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, -1)
        h = (h * p["ln_scale"]).astype(x.dtype)
        h = h * jax.nn.silu(z)
        return dense(p["w_down"], h)

    # -- decode --------------------------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> PyTree:
        di, hd = _proj_dims(cfg)
        H = cfg.n_heads
        return {
            "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32),
        }

    @staticmethod
    def decode(p: PyTree, cfg: ModelConfig, x: jnp.ndarray, cache: PyTree,
               pos: jnp.ndarray) -> tuple[jnp.ndarray, PyTree]:
        B = x.shape[0]
        H = cfg.n_heads
        xu, z = jnp.split(dense(p["w_up"], x), 2, axis=-1)
        q, k, v, i_pre, f_pre = MLSTM._qkv_gates(p, cfg, xu)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]                    # (B,H,hd)
        i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]                # (B,H)
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + cache["m"], i_pre)
        f_sc = jnp.exp(logf + cache["m"] - m_new)[..., None]
        i_sc = jnp.exp(i_pre - m_new)[..., None]
        kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
        C = cache["C"] * f_sc[..., None] + \
            i_sc[..., None] * vf[..., :, None] * kf[..., None, :]
        n = cache["n"] * f_sc + i_sc * kf
        qf = q.astype(jnp.float32)
        num = jnp.einsum("bhvk,bhk->bhv", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                          jnp.exp(-m_new))[..., None]
        h = num / den                                          # (B,H,hd)
        mu = jnp.mean(h, -1, keepdims=True)
        var = jnp.var(h, -1, keepdims=True)
        h = ((h - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, 1, -1)
        h = (h * p["ln_scale"]).astype(x.dtype)
        h = h * jax.nn.silu(z)
        return dense(p["w_down"], h), {"C": C, "n": n, "m": m_new}


class SLSTM:
    @staticmethod
    def init(key, cfg: ModelConfig) -> PyTree:
        d = cfg.d_model
        H = cfg.n_heads
        hd = d // H
        ks = iter(jax.random.split(key, 6))
        # 4 gates (i, f, z, o), input + block-diagonal recurrent weights.
        return {
            "w_x": make_dense(next(ks), d, 4 * d, bias=True),
            "r": jax.random.normal(next(ks), (4, H, hd, hd)) / math.sqrt(hd),
            "ln_scale": jnp.ones((d,), jnp.float32),
            "w_up": make_dense(next(ks), d, int(d * 4 / 3) * 2),
            "w_down": make_dense(next(ks), int(d * 4 / 3), d,
                                 scale=1.0 / math.sqrt(d * 2 * cfg.n_layers)),
        }

    @staticmethod
    def _step(p, cfg, carry, wx_t):
        """carry: (c, n, h, m) each (B, H, hd); wx_t: (B, 4d)."""
        c, n, h, m = carry
        B = h.shape[0]
        H = cfg.n_heads
        hd = h.shape[-1]
        rw = p["r"]  # (4, H, hd, hd)
        rec = jnp.einsum("bhk,ghkv->gbhv", h, rw)              # (4,B,H,hd)
        pre = wx_t.reshape(B, 4, H, hd).transpose(1, 0, 2, 3) + rec
        i_pre, f_pre, z_pre, o_pre = pre[0], pre[1], pre[2], pre[3]
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        i_g = jnp.exp(i_pre - m_new)
        f_g = jnp.exp(logf + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(z_pre)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    @staticmethod
    def fwd(p: PyTree, cfg: ModelConfig, x: jnp.ndarray,
            chunk: int = 64) -> jnp.ndarray:
        B, S, d = x.shape
        H = cfg.n_heads
        hd = d // H
        wx = dense(p["w_x"], x).astype(jnp.float32)            # (B,S,4d)
        ck = min(chunk, S)
        n_chunks = -(-S // ck)
        Sp = n_chunks * ck
        seq = wx.swapaxes(0, 1)
        if Sp != S:
            seq = jnp.pad(seq, ((0, Sp - S), (0, 0), (0, 0)))
        seq = seq.reshape(n_chunks, ck, B, 4 * d)

        @jax.checkpoint
        def chunk_body(carry, inp):
            return jax.lax.scan(
                lambda c, t: SLSTM._step(p, cfg, c, t), carry, inp)

        z0 = jnp.zeros((B, H, hd), jnp.float32)
        carry0 = (z0, z0, z0, jnp.full((B, H, hd), -1e30, jnp.float32))
        _, hs = jax.lax.scan(chunk_body, carry0, seq)          # (n,ck,B,H,hd)
        h = hs.reshape(Sp, B, d)[:S].swapaxes(0, 1)
        h = (h * p["ln_scale"]).astype(x.dtype)
        # Post-up-projection FF (proj factor 4/3, GeGLU).
        u, g = jnp.split(dense(p["w_up"], h), 2, axis=-1)
        return dense(p["w_down"], u * jax.nn.gelu(g))

    # -- decode --------------------------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> PyTree:
        H = cfg.n_heads
        hd = cfg.d_model // H
        z = jnp.zeros((batch, H, hd), jnp.float32)
        return {"c": z, "n": z, "h": z,
                "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}

    @staticmethod
    def decode(p: PyTree, cfg: ModelConfig, x: jnp.ndarray, cache: PyTree,
               pos: jnp.ndarray) -> tuple[jnp.ndarray, PyTree]:
        wx = dense(p["w_x"], x).astype(jnp.float32)[:, 0]      # (B,4d)
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
        (c, n, h, m), h_out = SLSTM._step(p, cfg, carry, wx)
        B = x.shape[0]
        hflat = (h_out.reshape(B, 1, -1) * p["ln_scale"]).astype(x.dtype)
        u, g = jnp.split(dense(p["w_up"], hflat), 2, axis=-1)
        y = dense(p["w_down"], u * jax.nn.gelu(g))
        return y, {"c": c, "n": n, "h": h, "m": m}
