"""Model assembly: embedding -> patterned block stack -> head.

Layers are grouped into the minimal repeating *unit* of the config's
block pattern (e.g. jamba's 8-layer attn/mamba/MoE cycle) and scanned
with ``jax.lax.scan`` over unit repetitions, keeping the lowered HLO
small and compile times bounded even for 60-layer MoE models.  A
non-periodic prefix (deepseek's first dense layer) is applied eagerly.

Three entry points:

* ``init(key, cfg)``                      -> params
* ``forward(params, cfg, batch)``         -> logits, aux  (training)
* ``prefill(params, cfg, batch, max_len)``-> logits, cache
* ``decode_step(params, cfg, tok, cache, pos)`` -> logits, cache
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .attention import GQA, MLA
from .common import (ModelConfig, PyTree, act_fn, dense, init_norm,
                     make_dense, norm, rope_tables)
from .moe import MoE
from .ssm import Mamba
from .xlstm import MLSTM, SLSTM

__all__ = ["init", "forward", "prefill", "decode_step", "init_cache",
           "unit_period", "count_params", "model_flops"]

_MIXERS = {"attn": None, "mamba": Mamba, "mlstm": MLSTM, "slstm": SLSTM}


# ---------------------------------------------------------------------------
# Layer plumbing
# ---------------------------------------------------------------------------

def _attn_cls(cfg: ModelConfig):
    return MLA if cfg.attn_type == "mla" else GQA


def _has_ff(cfg: ModelConfig, i: int) -> bool:
    kind = cfg.layer_kind(i)
    return kind in ("attn", "mamba") and (cfg.d_ff > 0 or cfg.is_moe_layer(i))


def _layer_sig(cfg: ModelConfig, i: int) -> tuple:
    return (cfg.layer_kind(i), cfg.is_moe_layer(i), _has_ff(cfg, i))


def unit_period(cfg: ModelConfig) -> tuple[int, int]:
    """(prefix_len, period): layers [prefix:] repeat with ``period``."""
    n = cfg.n_layers
    prefix = cfg.first_dense_layers
    sigs = [_layer_sig(cfg, i) for i in range(prefix, n)]
    m = len(sigs)
    for p in range(1, m + 1):
        if m % p == 0 and all(sigs[i] == sigs[i % p] for i in range(m)):
            return prefix, p
    return prefix, m


def _init_mlp(key, cfg: ModelConfig) -> PyTree:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff * 2 * cfg.n_layers)
    p = {"w_up": make_dense(ks[1], d, ff, scale=s_in),
         "w_down": make_dense(ks[2], ff, d, scale=s_out)}
    if cfg.act == "swiglu":
        p["w_gate"] = make_dense(ks[0], d, ff, scale=s_in)
    return p


def _mlp(p: PyTree, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "swiglu":
        h = jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x)
    else:
        h = jax.nn.gelu(dense(p["w_up"], x))
    return dense(p["w_down"], h)


def _init_layer(key, cfg: ModelConfig, i: int) -> PyTree:
    kind = cfg.layer_kind(i)
    ks = iter(jax.random.split(key, 4))
    p: PyTree = {"norm1": init_norm(cfg.d_model, cfg.norm)}
    if kind == "attn":
        p["mixer"] = _attn_cls(cfg).init(next(ks), cfg)
    else:
        p["mixer"] = _MIXERS[kind].init(next(ks), cfg)
    if _has_ff(cfg, i):
        p["norm2"] = init_norm(cfg.d_model, cfg.norm)
        if cfg.is_moe_layer(i):
            p["moe"] = MoE.init(next(ks), cfg)
        else:
            p["mlp"] = _init_mlp(next(ks), cfg)
    return p


def _zero_aux() -> dict:
    return {"moe_lb_loss": jnp.float32(0), "moe_z_loss": jnp.float32(0),
            "moe_drop_frac": jnp.float32(0)}


def _apply_layer(p: PyTree, cfg: ModelConfig, i: int, x: jnp.ndarray,
                 cos, sin, impl: str) -> tuple[jnp.ndarray, dict, PyTree]:
    """Full-sequence layer.  Returns (x, aux, state) — state for prefill."""
    kind = cfg.layer_kind(i)
    aux = _zero_aux()
    h = norm(p["norm1"], x, cfg.norm)
    state = None
    if kind == "attn":
        y = _attn_cls(cfg).fwd(p["mixer"], cfg, h, cos, sin, impl=impl)
    elif kind == "mamba":
        y = Mamba.fwd(p["mixer"], cfg, h)
    elif kind == "mlstm":
        y = MLSTM.fwd(p["mixer"], cfg, h)
    else:
        y = SLSTM.fwd(p["mixer"], cfg, h)
    x = x + y
    if "norm2" in p:
        h = norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            y, aux = MoE.fwd(p["moe"], cfg, h)
        else:
            y = _mlp(p["mlp"], cfg, h)
        x = x + y
    return x, aux, state


# ---------------------------------------------------------------------------
# Model init / forward
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> PyTree:
    prefix, period = unit_period(cfg)
    reps = (cfg.n_layers - prefix) // period
    k_embed, k_head, k_prefix, k_stack = jax.random.split(key, 4)
    params: PyTree = {"final_norm": init_norm(cfg.d_model, cfg.norm)}
    if cfg.input_mode == "tokens":
        params["embed"] = {
            "w": jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02}
    else:  # stub modality frontend: inputs arrive as embeddings
        params["embed"] = {
            "proj": make_dense(k_embed, cfg.d_model, cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = make_dense(
            k_head, cfg.d_model, cfg.vocab, scale=1.0 / math.sqrt(cfg.d_model))
    params["prefix"] = [
        _init_layer(k, cfg, i) for i, k in enumerate(
            jax.random.split(k_prefix, max(prefix, 1))[:prefix])]
    # Stacked unit params: leaves get a leading (reps,) axis.
    stack = []
    pos_keys = jax.random.split(k_stack, period)
    for u in range(period):
        layer_idx = prefix + u
        rep_keys = jax.random.split(pos_keys[u], reps)
        stack.append(jax.vmap(lambda k: _init_layer(k, cfg, layer_idx))(
            rep_keys))
    params["stack"] = stack
    return params


def _embed(params: PyTree, cfg: ModelConfig, batch) -> jnp.ndarray:
    dt = cfg.compute_dtype
    if cfg.input_mode == "tokens":
        x = params["embed"]["w"].astype(dt)[batch]
    else:
        x = dense(params["embed"]["proj"], batch.astype(dt))
    return x


def _head(params: PyTree, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    from repro.dist.context import constrain
    x = norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["w"].astype(x.dtype).T
    else:
        logits = dense(params["lm_head"], x)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    logits = constrain(logits, ("dp",) + (None,) * (logits.ndim - 2) +
                       ("tp",))
    return logits


def _rope_for(cfg: ModelConfig, positions: jnp.ndarray):
    dim = cfg.qk_rope_head_dim if cfg.attn_type == "mla" else cfg.head_dim
    return rope_tables(positions, dim, cfg.rope_theta)


def forward(params: PyTree, cfg: ModelConfig, batch, *,
            remat: bool = True, impl: str = "xla"
            ) -> tuple[jnp.ndarray, dict]:
    """Training/eval forward.  batch: (B,S) int tokens or (B,S,d) embeds."""
    from repro.dist.context import constrain
    prefix, period = unit_period(cfg)
    x = _embed(params, cfg, batch)
    x = constrain(x, ("dp", None, None))
    S = x.shape[1]
    cos, sin = _rope_for(cfg, jnp.arange(S))
    aux_tot = _zero_aux()

    for i, lp in enumerate(params["prefix"]):
        x, aux, _ = _apply_layer(lp, cfg, i, x, cos, sin, impl)
        aux_tot = jax.tree.map(jnp.add, aux_tot, aux)

    def unit_body(x, unit_params):
        aux_u = _zero_aux()
        for u in range(period):
            x, aux, _ = _apply_layer(unit_params[u], cfg, prefix + u,
                                     x, cos, sin, impl)
            x = constrain(x, ("dp", None, None))
            aux_u = jax.tree.map(jnp.add, aux_u, aux)
        return x, aux_u

    body = jax.checkpoint(unit_body) if remat else unit_body

    def scan_body(carry, unit_params):
        x = carry
        x, aux_u = body(x, unit_params)
        return x, aux_u

    if (cfg.n_layers - prefix) > 0:
        x, aux_stack = jax.lax.scan(scan_body, x, tuple(params["stack"]))
        aux_tot = jax.tree.map(lambda a, b: a + jnp.sum(b), aux_tot,
                               aux_stack)
    logits = _head(params, cfg, x)
    return logits, aux_tot


def forward_features(params: PyTree, cfg: ModelConfig, batch, *,
                     remat: bool = True, impl: str = "xla",
                     unroll: bool = False) -> tuple[jnp.ndarray, dict]:
    """Like :func:`forward` but stops before the LM head, returning the
    final-norm hidden states — lets the loss head run chunked so the
    (tokens, vocab) logits tensor is never materialised at once."""
    prefix, period = unit_period(cfg)
    # Temporarily reuse forward's machinery by replicating its body
    # minus the head.
    from repro.dist.context import constrain
    x = _embed(params, cfg, batch)
    x = constrain(x, ("dp", None, None))
    S = x.shape[1]
    cos, sin = _rope_for(cfg, jnp.arange(S))
    aux_tot = _zero_aux()
    for i, lp in enumerate(params["prefix"]):
        x, aux, _ = _apply_layer(lp, cfg, i, x, cos, sin, impl)
        aux_tot = jax.tree.map(jnp.add, aux_tot, aux)

    def unit_body(x, unit_params):
        aux_u = _zero_aux()
        for u in range(period):
            x, aux, _ = _apply_layer(unit_params[u], cfg, prefix + u,
                                     x, cos, sin, impl)
            x = constrain(x, ("dp", None, None))
            aux_u = jax.tree.map(jnp.add, aux_u, aux)
        return x, aux_u

    body = jax.checkpoint(unit_body) if remat else unit_body

    def scan_body(carry, unit_params):
        return body(carry, unit_params)

    reps = (cfg.n_layers - prefix) // period if period else 0
    if reps > 0 and unroll:
        # python-loop lowering: every layer's ops appear in the HLO
        # (used by the roofline depth-extrapolation validator, where
        # cost_analysis must see each unit's cost)
        for r in range(reps):
            up = tuple(jax.tree.map(lambda a, r=r: a[r], st)
                       for st in params["stack"])
            x, aux_u = body(x, up)
            aux_tot = jax.tree.map(jnp.add, aux_tot, aux_u)
    elif reps > 0:
        x, aux_stack = jax.lax.scan(scan_body, x, tuple(params["stack"]))
        aux_tot = jax.tree.map(lambda a, b: a + jnp.sum(b), aux_tot,
                               aux_stack)
    x = norm(params["final_norm"], x, cfg.norm)
    return x, aux_tot


def head_matrix(params: PyTree, cfg: ModelConfig) -> jnp.ndarray:
    """(d, vocab) projection used by the chunked loss."""
    if cfg.tie_embeddings:
        return params["embed"]["w"].T
    return params["lm_head"]["w"]


def prefill_logits(params: PyTree, cfg: ModelConfig, batch, *,
                   impl: str = "xla") -> jnp.ndarray:
    """Serving prefill: run the prompt through the stack and return the
    LAST position's logits only — (B, vocab).

    The (B, S, vocab) logits tensor never exists: this is what a
    serving engine actually needs before decode starts, and it removes
    the dominant all-gather + 37 GiB/device buffer the naive
    full-logits prefill shows in the dry-run (EXPERIMENTS.md §Perf).
    """
    x, _ = forward_features(params, cfg, batch, remat=False, impl=impl)
    last = x[:, -1, :]                      # features are already normed
    logits = last @ head_matrix(params, cfg).astype(last.dtype)
    if not cfg.tie_embeddings and "b" in params.get("lm_head", {}):
        logits = logits + params["lm_head"]["b"].astype(logits.dtype)
    return logits


# ---------------------------------------------------------------------------
# KV-cache init / prefill / decode
# ---------------------------------------------------------------------------

def _mixer_cache(cfg: ModelConfig, i: int, batch: int, max_len: int,
                 dtype) -> PyTree:
    kind = cfg.layer_kind(i)
    if kind == "attn":
        return _attn_cls(cfg).init_cache(cfg, batch, max_len, dtype)
    return _MIXERS[kind].init_cache(cfg, batch, max_len, dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> PyTree:
    prefix, period = unit_period(cfg)
    reps = (cfg.n_layers - prefix) // period
    cache: PyTree = {
        "prefix": [
            _mixer_cache(cfg, i, batch, max_len, dtype)
            for i in range(prefix)],
        "stack": [],
    }
    for u in range(period):
        one = _mixer_cache(cfg, prefix + u, batch, max_len, dtype)
        cache["stack"].append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), one))
    return cache


def _decode_layer(p: PyTree, cfg: ModelConfig, i: int, x: jnp.ndarray,
                  c: PyTree, pos) -> tuple[jnp.ndarray, PyTree]:
    kind = cfg.layer_kind(i)
    h = norm(p["norm1"], x, cfg.norm)
    cls = _attn_cls(cfg) if kind == "attn" else _MIXERS[kind]
    y, c = cls.decode(p["mixer"], cfg, h, c, pos)
    x = x + y
    if "norm2" in p:
        h = norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            y, _ = MoE.fwd(p["moe"], cfg, h)
        else:
            y = _mlp(p["mlp"], cfg, h)
        x = x + y
    return x, c


def decode_step(params: PyTree, cfg: ModelConfig, tok, cache: PyTree,
                pos, *, unroll: bool = False
                ) -> tuple[jnp.ndarray, PyTree]:
    """One autoregressive step.  tok: (B,) int32 or (B,1,d) embeds;
    pos: scalar int32 count of tokens already in the cache.

    ``unroll=True`` replaces the layer scan with a python loop: the
    per-token HLO is tiny, and unrolling lets resident (serve-mode)
    weights be consumed in place instead of being copied into the
    scan's stacked layout — see EXPERIMENTS.md §Perf."""
    from repro.dist.context import constrain
    prefix, period = unit_period(cfg)
    if cfg.input_mode == "tokens":
        x = _embed(params, cfg, tok[:, None])
    else:
        x = _embed(params, cfg, tok)
    x = constrain(x, ("dp", None, None))
    pos = jnp.asarray(pos, jnp.int32)
    new_prefix = []
    for i, lp in enumerate(params["prefix"]):
        x, c = _decode_layer(lp, cfg, i, x, cache["prefix"][i], pos)
        new_prefix.append(c)

    reps = (cfg.n_layers - prefix) // period if period else 0

    if unroll and reps:
        new_stack_cols = [jax.tree.map(lambda a: [], params["stack"][u])
                          for u in range(period)]
        new_stack = []
        per_rep = []
        for r in range(reps):
            rep_cache = []
            for u in range(period):
                up = jax.tree.map(lambda a, r=r: a[r], params["stack"][u])
                uc = jax.tree.map(lambda a, r=r: a[r], cache["stack"][u])
                x, c = _decode_layer(up, cfg, prefix + u, x, uc, pos)
                x = constrain(x, ("dp", None, None))
                rep_cache.append(c)
            per_rep.append(rep_cache)
        for u in range(period):
            new_stack.append(jax.tree.map(
                lambda *leaves: jnp.stack(leaves),
                *[per_rep[r][u] for r in range(reps)]))
        logits = _head(params, cfg, x)
        return logits[:, 0], {"prefix": new_prefix, "stack": new_stack}

    def scan_body(x, inp):
        unit_params, unit_cache = inp
        new_cache = []
        for u in range(period):
            x, c = _decode_layer(unit_params[u], cfg, prefix + u, x,
                                 unit_cache[u], pos)
            x = constrain(x, ("dp", None, None))
            new_cache.append(c)
        return x, tuple(new_cache)

    if (cfg.n_layers - prefix) > 0:
        x, new_stack = jax.lax.scan(
            scan_body, x, (tuple(params["stack"]), tuple(cache["stack"])))
    else:
        new_stack = ()
    logits = _head(params, cfg, x)
    return logits[:, 0], {"prefix": new_prefix, "stack": list(new_stack)}


def prefill(params: PyTree, cfg: ModelConfig, batch, max_len: int,
            *, impl: str = "xla") -> tuple[jnp.ndarray, PyTree]:
    """Run the prompt through the model, returning (last-token logits,
    cache filled for positions [0, S)).

    Implemented as forward + per-layer state extraction; attention
    layers re-project K/V into the cache layout (cheap relative to the
    attention itself), recurrent layers return their final states.
    """
    # For simplicity and correctness-first: replay tokens through
    # decode_step via lax.scan when S is small, else use the fused path.
    if cfg.input_mode == "tokens":
        B, S = batch.shape
    else:
        B, S = batch.shape[:2]
    cache = init_cache(cfg, B, max_len)

    def step(carry, s):
        cache = carry
        tok = jax.lax.dynamic_index_in_dim(batch, s, axis=1, keepdims=False)
        if cfg.input_mode != "tokens":
            tok = tok[:, None]
        logits, cache = decode_step(params, cfg, tok, cache, s)
        return cache, logits

    cache, logits = jax.lax.scan(step, cache, jnp.arange(S))
    return logits[-1], cache


def count_params(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def model_flops(cfg: ModelConfig, n_params_active: int, n_tokens: int) -> float:
    """MODEL_FLOPS = 6 * N_active * D (the roofline 'useful work' term)."""
    return 6.0 * n_params_active * n_tokens
