"""Attention layers: GQA (with optional QKV bias / sliding window) and
MLA (DeepSeek-V2 multi-head latent attention with compressed KV cache).

Every variant exposes:

* ``init(key, cfg) -> params``
* ``fwd(params, cfg, x, cos, sin) -> y``                (full-sequence)
* ``init_cache(cfg, batch, max_len, dtype) -> cache``
* ``decode(params, cfg, x, cache, pos) -> (y, cache)``  (one new token)

The scaled-dot-product core is pluggable (``impl='xla' | 'pallas'``) so
the Pallas TPU kernels in :mod:`repro.kernels` can be swapped in on
real hardware while the dry-run lowers the pure-XLA path.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .common import ModelConfig, PyTree, apply_rope, dense, make_dense

__all__ = ["GQA", "MLA", "sdpa", "decode_sdpa", "causal_mask_bias"]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Scaled-dot-product cores
# ---------------------------------------------------------------------------

def causal_mask_bias(q_len: int, kv_len: int, *, causal: bool,
                     window: int | None, q_offset: int = 0) -> jnp.ndarray:
    """(q_len, kv_len) additive bias implementing causal + sliding window."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    ki = jnp.arange(kv_len)[None, :]
    ok = jnp.ones((q_len, kv_len), bool)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         bias: jnp.ndarray | None, *, scale: float) -> jnp.ndarray:
    """Reference scaled-dot-product attention with GQA head grouping.

    q: (B, S, H, Dk)   k: (B, T, Hkv, Dk)   v: (B, T, Hkv, Dv)
    bias: (S, T) additive or None.  Softmax in f32.
    """
    B, S, H, Dk = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, S, Hkv, g, Dk)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if bias is not None:
        logits = logits + bias
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", w.astype(v.dtype), v)
    return out.reshape(B, S, H, v.shape[-1])


def blockwise_sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   scale: float, causal: bool, window: int | None,
                   q_chunk: int = 1024, kv_chunk: int = 1024
                   ) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure XLA.

    Peak memory is one (q_chunk, kv_chunk) score tile per head group —
    this is the XLA twin of the Pallas kernel in ``repro.kernels`` and
    the path the dry-run lowers.  For sliding-window attention each
    query chunk only visits a fixed-width KV span (window + q_chunk),
    so SWA prefill stays O(S * window).
    """
    from repro.dist.context import constrain
    B, S, H, Dk = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    while S % q_chunk:
        q_chunk //= 2
    while T % kv_chunk:
        kv_chunk //= 2
    nq = S // q_chunk

    # SPMD propagation through while loops is weak: pin batch (dp) and
    # head (tp) sharding of the loop-invariant operands and every block
    # slice, or the backward replicates (B, S, H, D) cotangents on
    # every device.  Attention is embarrassingly parallel over heads;
    # the tp axis is dropped automatically when it doesn't divide.
    q = constrain(q, ("dp", None, "tp", None))
    k = constrain(k, ("dp", None, "tp", None))
    v = constrain(v, ("dp", None, "tp", None))

    span = None
    if window is not None and causal:
        span = min(T, -(-(window + q_chunk) // kv_chunk) * kv_chunk)

    def q_block(_, qi):
        q_off = qi * q_chunk
        qb = jax.lax.dynamic_slice_in_dim(q, q_off, q_chunk, axis=1)
        qb = constrain(qb, ("dp", None, "tp", None))
        qb = qb.reshape(B, q_chunk, Hkv, g, Dk)
        qb = constrain(qb, ("dp", None, "tp", None, None))

        if span is not None:
            kv_start = jnp.clip(q_off + q_chunk - span, 0, T - span)
            kb_all = jax.lax.dynamic_slice_in_dim(k, kv_start, span, axis=1)
            vb_all = jax.lax.dynamic_slice_in_dim(v, kv_start, span, axis=1)
            nkv = span // kv_chunk
        else:
            kv_start = jnp.int32(0)
            kb_all, vb_all = k, v
            nkv = T // kv_chunk

        def kv_block(carry, ki):
            m_acc, l_acc, o_acc = carry
            kv_off = ki * kv_chunk
            kb = jax.lax.dynamic_slice_in_dim(kb_all, kv_off, kv_chunk,
                                              axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vb_all, kv_off, kv_chunk,
                                              axis=1)
            s_ = jnp.einsum("bshgd,bthd->bhgst", qb, kb,
                            preferred_element_type=jnp.float32) * scale
            qi_idx = q_off + jnp.arange(q_chunk)[:, None]
            ki_idx = kv_start + kv_off + jnp.arange(kv_chunk)[None, :]
            ok = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                ok &= ki_idx <= qi_idx
            if window is not None:
                ok &= ki_idx > qi_idx - window
            s_ = jnp.where(ok, s_, _NEG_INF)
            s_ = constrain(s_, ("dp", "tp", None, None, None))
            m_new = jnp.maximum(m_acc, jnp.max(s_, axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m_acc - m_new)
            l_new = l_acc * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgst,bthd->bhgsd", p.astype(vb.dtype), vb)
            o_new = o_acc * corr[..., None].astype(o_acc.dtype) + pv
            o_new = constrain(o_new, ("dp", "tp", None, None, None))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hkv, g, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, Hkv, g, q_chunk, v.shape[-1]), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            jax.checkpoint(kv_block), (m0, l0, o0), jnp.arange(nkv))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        # (B,Hkv,g,qc,Dv) -> (B,qc,H,Dv)
        o = o.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, v.shape[-1])
        o = constrain(o, ("dp", None, "tp", None))
        return None, o.astype(v.dtype)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq))
    blocks = constrain(blocks, (None, "dp", None, "tp", None))
    # (nq, B, q_chunk, H, Dv) -> (B, S, H, Dv)
    out = blocks.transpose(1, 0, 2, 3, 4).reshape(B, S, H, v.shape[-1])
    return constrain(out, ("dp", None, "tp", None))


def decode_sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                length_mask: jnp.ndarray, *, scale: float) -> jnp.ndarray:
    """Single-position attention against a (possibly oversized) cache.

    q: (B, H, Dk)  k/v: (B, T, Hkv, D*)  length_mask: (B, T) bool valid.
    """
    B, H, Dk = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, Dk)
    logits = jnp.einsum("bhgd,bthd->bhgt", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(length_mask[:, None, None, :], logits, _NEG_INF)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", w.astype(v.dtype), v)
    return out.reshape(B, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

class GQA:
    """Grouped-query attention with RoPE, bias and sliding-window options."""

    @staticmethod
    def init(key, cfg: ModelConfig) -> PyTree:
        d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        ks = jax.random.split(key, 4)
        b = cfg.qkv_bias
        return {
            "wq": make_dense(ks[0], d, H * hd, bias=b),
            "wk": make_dense(ks[1], d, Hkv * hd, bias=b),
            "wv": make_dense(ks[2], d, Hkv * hd, bias=b),
            "wo": make_dense(ks[3], H * hd, d,
                             scale=1.0 / math.sqrt(H * hd * 2 * cfg.n_layers)),
        }

    @staticmethod
    def _qkv(p, cfg, x):
        B, S, _ = x.shape
        q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
        k = dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        v = dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        return q, k, v

    @staticmethod
    def fwd(p: PyTree, cfg: ModelConfig, x: jnp.ndarray,
            cos: jnp.ndarray, sin: jnp.ndarray, *,
            impl: str = "xla") -> jnp.ndarray:
        B, S, _ = x.shape
        q, k, v = GQA._qkv(p, cfg, x)
        if cfg.use_rope:
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        scale = 1.0 / math.sqrt(cfg.head_dim)
        if impl == "pallas":  # pragma: no cover - TPU path
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=cfg.causal,
                                       window=cfg.sliding_window)
        elif S > 2048:
            out = blockwise_sdpa(q, k, v, scale=scale, causal=cfg.causal,
                                 window=cfg.sliding_window)
        else:
            bias = causal_mask_bias(S, S, causal=cfg.causal,
                                    window=cfg.sliding_window)
            out = sdpa(q, k, v, bias, scale=scale)
        return dense(p["wo"], out.reshape(B, S, -1))

    # -- decode -------------------------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> PyTree:
        # Sliding-window models only ever need `window` cache slots
        # (ring buffer); full attention needs max_len.
        slots = min(max_len, cfg.sliding_window or max_len)
        shape = (batch, slots, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    @staticmethod
    def decode(p: PyTree, cfg: ModelConfig, x: jnp.ndarray, cache: PyTree,
               pos: jnp.ndarray) -> tuple[jnp.ndarray, PyTree]:
        """x: (B, 1, d); pos: scalar int32 (tokens already in cache)."""
        from .common import rope_tables
        B = x.shape[0]
        q, k, v = GQA._qkv(p, cfg, x)
        if cfg.use_rope:
            cos, sin = rope_tables(pos[None], cfg.head_dim, cfg.rope_theta)
            q = apply_rope(q, cos[None], sin[None])
            k = apply_rope(k, cos[None], sin[None])
        slots = cache["k"].shape[1]
        slot = pos % slots
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        idx = jnp.arange(slots)
        if cfg.sliding_window is not None and slots == cfg.sliding_window:
            valid = (idx <= slot) | (pos >= slots)  # ring buffer fully warm
            valid = valid & (idx < jnp.minimum(pos + 1, slots))
            valid = jnp.broadcast_to(valid, (B, slots))
        else:
            valid = jnp.broadcast_to(idx <= pos, (B, slots))
        scale = 1.0 / math.sqrt(cfg.head_dim)
        out = decode_sdpa(q[:, 0], ck, cv, valid, scale=scale)
        y = dense(p["wo"], out.reshape(B, 1, -1).astype(x.dtype))
        return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

class MLA:
    """Multi-head latent attention with low-rank compressed KV cache.

    Cache stores only ``c_kv`` (kv_lora_rank) and the shared rope key
    (qk_rope_head_dim) per token.  Decode uses the *absorbed* form so
    the compressed cache is attended to directly.
    """

    @staticmethod
    def init(key, cfg: ModelConfig) -> PyTree:
        d, H = cfg.d_model, cfg.n_heads
        r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        ks = iter(jax.random.split(key, 10))
        p = {
            "w_dkv": make_dense(next(ks), d, r_kv),
            "w_krope": make_dense(next(ks), d, dr),
            "w_uk": make_dense(next(ks), r_kv, H * dn),
            "w_uv": make_dense(next(ks), r_kv, H * dv),
            "wo": make_dense(next(ks), H * dv, d,
                             scale=1.0 / math.sqrt(H * dv * 2 * cfg.n_layers)),
            "kv_norm": {"scale": jnp.ones((r_kv,), jnp.float32)},
        }
        if r_q:
            p["w_dq"] = make_dense(next(ks), d, r_q)
            p["w_uq"] = make_dense(next(ks), r_q, H * (dn + dr))
            p["q_norm"] = {"scale": jnp.ones((r_q,), jnp.float32)}
        else:
            p["wq"] = make_dense(next(ks), d, H * (dn + dr))
        return p

    @staticmethod
    def _q(p, cfg, x):
        from .common import rmsnorm
        B, S, _ = x.shape
        H = cfg.n_heads
        dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
        if "w_dq" in p:
            q = dense(p["w_uq"], rmsnorm(p["q_norm"], dense(p["w_dq"], x)))
        else:
            q = dense(p["wq"], x)
        q = q.reshape(B, S, H, dn + dr)
        return q[..., :dn], q[..., dn:]

    @staticmethod
    def _ckv(p, cfg, x):
        from .common import rmsnorm
        c_kv = rmsnorm(p["kv_norm"], dense(p["w_dkv"], x))
        k_rope = dense(p["w_krope"], x)  # (B, S, dr) shared across heads
        return c_kv, k_rope

    @staticmethod
    def fwd(p: PyTree, cfg: ModelConfig, x: jnp.ndarray,
            cos: jnp.ndarray, sin: jnp.ndarray, *,
            impl: str = "xla") -> jnp.ndarray:
        B, S, _ = x.shape
        H = cfg.n_heads
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        q_nope, q_rope = MLA._q(p, cfg, x)
        c_kv, k_rope = MLA._ckv(p, cfg, x)
        q_rope = apply_rope(q_rope, cos, sin)
        k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (B,S,1,dr)
        k_nope = dense(p["w_uk"], c_kv).reshape(B, S, H, dn)
        v = dense(p["w_uv"], c_kv).reshape(B, S, H, dv)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
        scale = 1.0 / math.sqrt(dn + dr)
        if S > 2048:
            out = blockwise_sdpa(q, k, v, scale=scale, causal=True,
                                 window=None)
        else:
            bias = causal_mask_bias(S, S, causal=True, window=None)
            out = sdpa(q, k, v, bias, scale=scale)
        return dense(p["wo"], out.reshape(B, S, -1))

    # -- decode (absorbed form) ---------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> PyTree:
        return {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        }

    @staticmethod
    def decode(p: PyTree, cfg: ModelConfig, x: jnp.ndarray, cache: PyTree,
               pos: jnp.ndarray) -> tuple[jnp.ndarray, PyTree]:
        from .common import rope_tables
        B = x.shape[0]
        H = cfg.n_heads
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        r_kv = cfg.kv_lora_rank
        q_nope, q_rope = MLA._q(p, cfg, x)          # (B,1,H,dn),(B,1,H,dr)
        c_kv, k_rope = MLA._ckv(p, cfg, x)          # (B,1,r_kv),(B,1,dr)
        cos, sin = rope_tables(pos[None], dr, cfg.rope_theta)
        q_rope = apply_rope(q_rope, cos[None], sin[None])
        k_rope = apply_rope(k_rope[:, :, None, :], cos[None], sin[None])[:, :, 0]
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), pos, axis=1)
        T = ck.shape[1]
        valid = jnp.broadcast_to(jnp.arange(T) <= pos, (B, T))
        # Absorb W_uk into the query: q_c = q_nope @ W_uk^T  (per head).
        w_uk = p["w_uk"]["w"].reshape(r_kv, H, dn)
        q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0],
                         w_uk.astype(q_nope.dtype))        # (B,H,r_kv)
        logits = jnp.einsum("bhr,btr->bht", q_c, ck,
                            preferred_element_type=jnp.float32)
        logits = logits + jnp.einsum(
            "bhd,btd->bht", q_rope[:, 0], cr,
            preferred_element_type=jnp.float32)
        logits = logits / math.sqrt(dn + dr)
        logits = jnp.where(valid[:, None, :], logits, _NEG_INF)
        w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        ctx = jnp.einsum("bht,btr->bhr", w.astype(ck.dtype), ck)  # (B,H,r_kv)
        # Absorb W_uv on the way out.
        w_uv = p["w_uv"]["w"].reshape(r_kv, H, dv)
        out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(ctx.dtype))
        y = dense(p["wo"], out.reshape(B, 1, -1).astype(x.dtype))
        return y, {"c_kv": ck, "k_rope": cr}
