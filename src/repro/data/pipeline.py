"""Deterministic synthetic LM data pipeline.

Host-sharded, checkpointable, with sequence-length bucketing that feeds
the scheduler's heterogeneous-microbatch composer.

The token stream is a seeded Zipfian mixture with local n-gram
structure — enough signal that a ~10M-param model's loss drops
measurably within a few hundred steps (used by the end-to-end example
and the integration tests), while requiring no external data.
"""

from __future__ import annotations

import threading
import queue as _queue
from dataclasses import dataclass, field
from typing import Iterator

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "BucketedBatcher", "Prefetcher"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    zipf_a: float = 1.3
    ngram: int = 3


class SyntheticLM:
    """Infinite deterministic token stream, shardable by host.

    State is the (host-local) step counter — checkpoint/restore is a
    single integer in the training manifest.
    """

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        # Fixed n-gram transition structure derived from the seed.
        rng = np.random.default_rng(cfg.seed)
        self._mix = rng.permutation(cfg.vocab)
        zipf_p = 1.0 / np.arange(1, cfg.vocab + 1) ** cfg.zipf_a
        self._p = zipf_p / zipf_p.sum()

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, self.cfg.host_id, step))

    @property
    def host_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.n_hosts

    def next_batch(self) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._batch_rng(self.step)
        base = rng.choice(cfg.vocab, size=(self.host_batch, cfg.seq_len),
                          p=self._p)
        # n-gram structure: token depends on previous via fixed mixing.
        toks = base.copy()
        for i in range(1, cfg.seq_len):
            carry = self._mix[toks[:, i - 1]]
            mask = rng.random(self.host_batch) < 0.5
            toks[:, i] = np.where(mask, (carry + base[:, i]) % cfg.vocab,
                                  base[:, i])
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        self.step += 1
        return {"inputs": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}

    # -- checkpointing -------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])


@dataclass
class BucketedBatcher:
    """Groups variable-length sequences into per-bucket microbatches.

    Produces (bucket_len, batch) work items whose roofline profiles the
    scheduler (repro.core.tpu) can order — long buckets are compute-
    bound, short buckets memory-bound relative to the step overhead.
    """

    buckets: tuple[int, ...] = (512, 1024, 2048, 4096)
    batch_per_bucket: int = 8

    def assign(self, lengths: np.ndarray) -> dict[int, np.ndarray]:
        out: dict[int, list[int]] = {b: [] for b in self.buckets}
        for i, ln in enumerate(lengths):
            for b in self.buckets:
                if ln <= b:
                    out[b].append(i)
                    break
            else:
                out[self.buckets[-1]].append(i)
        return {b: np.asarray(v, np.int32) for b, v in out.items() if v}


class Prefetcher:
    """Background-thread prefetch with bounded queue (pipeline overlap)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: _queue.Queue = _queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def run():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)
            self._q.put(None)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
