"""Data substrate: deterministic synthetic pipeline, bucketing, prefetch."""

from .pipeline import BucketedBatcher, DataConfig, Prefetcher, SyntheticLM

__all__ = ["BucketedBatcher", "DataConfig", "Prefetcher", "SyntheticLM"]
