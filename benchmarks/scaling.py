"""Schedule-construction scaling: reference vs vectorized paths.

For n in {8, 32, 128, 512, 1024} kernels, on two workload mixes
(GTX580 kernel soup; TPU serving prefill+decode items), measures

* wall time of schedule construction — greedy + default-budget refine
  (200 evaluations, the serving default) — for the pure-Python
  reference path vs the vectorized/incremental fast path, and
* the modelled execution time of the produced order under both the
  round model (the refine objective) and the event simulator,

and emits ``BENCH_scheduler_scaling.json`` for the perf trajectory.
The reference path is O(R * n^2) Python-level ScoreGen reruns and is
skipped above ``--max-ref-n`` (default 512, ~35 s there); pass
``--full`` to run it everywhere.

Run:  PYTHONPATH=src python benchmarks/scaling.py
"""

from __future__ import annotations

import argparse
import json
import random
import time

from repro.core import (GTX580, RoundSimulator, greedy_order,
                        greedy_order_fast, simulate)
from repro.core.refine import refine_order
from repro.core.resources import (KernelProfile, bs_kernel, ep_kernel,
                                  es_kernel, sw_kernel)
from repro.core.tpu import decode_profile, make_serving_device, prefill_profile

REFINE_BUDGET = 200
NS = (8, 32, 128, 512, 1024)
_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]


def gpu_mix(rng: random.Random, n: int) -> list[KernelProfile]:
    return [rng.choice(_FAMS)(f"k{i}",
                              grid=rng.choice([8, 16, 32, 48, 64, 96]),
                              shm=rng.choice([0, 4096, 8192, 16384, 24576]),
                              inst=rng.uniform(1e6, 5e8))
            for i in range(n)]


def tpu_mix(rng: random.Random, n: int) -> list[KernelProfile]:
    out = []
    for i in range(n):
        if rng.random() < 0.3:
            it = prefill_profile(f"p{i}", n_params=7e9,
                                 seq_len=rng.choice([128, 256, 512, 1024]),
                                 kv_bytes_per_token=131072)
        else:
            it = decode_profile(f"d{i}", n_params=7e9,
                                kv_len=rng.randint(64, 8192),
                                kv_bytes_per_token=131072)
        out.append(it.profile())
    return out


SCENARIOS = (
    ("gpu_mix", GTX580, gpu_mix),
    ("tpu_serving", make_serving_device(), tpu_mix),
)


def construct(ks, device, path: str) -> dict:
    """Greedy + default-budget refine; returns wall time + quality."""
    t0 = time.perf_counter()
    if path == "reference":
        sched = greedy_order(ks, device)
        sim = RoundSimulator(device)
        order, t_round, evals = refine_order(
            sched.order, device, time_fn=sim.simulate,
            budget=REFINE_BUDGET)
    else:
        sched = greedy_order_fast(ks, device)
        order, t_round, evals = refine_order(
            sched.order, device, model="round", budget=REFINE_BUDGET,
            neighborhood="auto")
    wall = time.perf_counter() - t0
    return {
        "path": path,
        "wall_s": wall,
        "rounds": len(sched.rounds),
        "refine_evals": evals,
        "modelled_round_time_s": t_round,
        "modelled_event_time_s": simulate(order, device),
    }


def run(max_ref_n: int = 512, seed: int = 0,
        print_fn=print) -> dict:
    results = []
    print_fn("# Scheduler scaling: reference vs vectorized "
             f"(refine budget {REFINE_BUDGET})")
    print_fn("scenario,n,path,wall_s,round_time_s,event_time_s,speedup")
    for name, device, maker in SCENARIOS:
        for n in NS:
            rng = random.Random(seed)
            ks = maker(rng, n)
            fast = construct(ks, device, "fast")
            ref = None
            if n <= max_ref_n:
                ref = construct(ks, device, "reference")
            for rec in filter(None, (ref, fast)):
                speedup = (ref["wall_s"] / fast["wall_s"]
                           if ref is not None and rec is fast else "")
                print_fn(f"{name},{n},{rec['path']},"
                         f"{rec['wall_s']:.4f},"
                         f"{rec['modelled_round_time_s']:.5f},"
                         f"{rec['modelled_event_time_s']:.5f},"
                         f"{speedup if speedup == '' else f'{speedup:.1f}'}")
                results.append({"scenario": name, "n": n, **rec})
    summary = _summary(results)
    out = {"benchmark": "scheduler_scaling",
           "refine_budget": REFINE_BUDGET,
           "ns": list(NS), "max_ref_n": max_ref_n,
           "results": results, "summary": summary}
    print_fn(f"summary: {json.dumps(summary)}")
    return out


def _summary(results: list[dict]) -> dict:
    by = {(r["scenario"], r["n"], r["path"]): r for r in results}
    speedups = {}
    quality_ok = True
    for (scen, n, path), r in by.items():
        if path != "reference":
            continue
        f = by.get((scen, n, "fast"))
        if f is None:
            continue
        speedups[f"{scen}@n={n}"] = r["wall_s"] / f["wall_s"]
        if f["modelled_round_time_s"] > r["modelled_round_time_s"] * (1 + 1e-9):
            quality_ok = False
    s512 = {k: v for k, v in speedups.items() if k.endswith("n=512")}
    return {"speedups": speedups,
            "min_speedup_at_512": min(s512.values()) if s512 else None,
            "quality_no_worse_than_reference": quality_ok}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_scheduler_scaling.json")
    ap.add_argument("--max-ref-n", type=int, default=512)
    ap.add_argument("--full", action="store_true",
                    help="run the reference path at every n")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    max_ref = max(NS) if args.full else args.max_ref_n
    out = run(max_ref_n=max_ref, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
